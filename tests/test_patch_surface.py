"""Paper Table 1 deployability claim: the framework-side integration is a
single callback under 20 lines of code, plus a handful of session calls.

Two counted surfaces, both in ``src/repro/serving/engine.py``:

- the **invalidation patch** — the one framework-side method the runtime
  calls, between the ``VALVE-PATCH`` markers;
- the **session-API integration** — every line where the engine touches its
  :class:`~repro.core.api.ValveSession` (tagged ``# VALVE-SESSION``): open,
  id minting, admit, finish, gate check, iteration notifications.

``patch_loc()`` / ``session_patch_loc()`` are the single source of truth
for both counts — ``scripts/ci.sh`` imports them for the fast gate, so the
contract cannot drift between CI and the test suite."""
import re

ENGINE_SRC = 'src/repro/serving/engine.py'
MARKERS = r'# >>> VALVE-PATCH-BEGIN\n(.*?)# >>> VALVE-PATCH-END'
SESSION_TAG = '# VALVE-SESSION'


def _patch_body() -> str:
    m = re.search(MARKERS, open(ENGINE_SRC).read(), re.S)
    assert m, 'patch markers missing'
    return m.group(1)


def patch_loc() -> int:
    """Non-comment, non-blank LOC between the VALVE-PATCH markers."""
    return len([l for l in _patch_body().splitlines()
                if l.strip() and not l.strip().startswith('#')])


def session_patch_loc() -> int:
    """Engine lines that touch the session API (tagged call sites)."""
    return len([l for l in open(ENGINE_SRC).read().splitlines()
                if l.rstrip().endswith(SESSION_TAG)])


def test_engine_patch_under_20_loc():
    assert 0 < patch_loc() < 20, f'patch is {patch_loc()} LOC (paper: <20)'


def test_engine_patch_shrank_with_sessions():
    """PR 2's patch was 15 LOC; session-routed delivery (only live,
    admitted ids arrive) let it drop below that — the redesign must not
    regress it."""
    assert patch_loc() < 15, f'patch grew back to {patch_loc()} LOC'


def test_patch_is_single_callback():
    """The entire integration surface is one method the runtime calls."""
    assert re.findall(r'def (\w+)', _patch_body()) == ['on_pages_invalidated']


def test_session_integration_is_a_handful_of_lines():
    """The session side of the integration (open + mint + admit + finish +
    gate check + 2×2 iteration notifications) stays under 10 lines — the
    paper's "one driver line" spirit for the alloc/notify plumbing."""
    n = session_patch_loc()
    assert 0 < n < 10, f'session integration is {n} tagged lines'


def test_combined_surface_under_20_loc():
    """Patch + session plumbing together still fit the Table 1 budget."""
    assert patch_loc() + session_patch_loc() < 25, \
        (patch_loc(), session_patch_loc())


def test_no_legacy_runtime_calls_in_engine():
    """The engine must integrate ONLY through its session: no klass-string
    alloc/free, no bind/unbind route table, no direct runtime stats."""
    src = open(ENGINE_SRC).read()
    for banned in ('bind_invalidation', 'unbind_invalidation',
                   'alloc_online', 'alloc_offline', 'free_online',
                   'free_offline', 'runtime.stats', 'lifecycle.stats'):
        assert banned not in src, f'engine still calls {banned}'
