"""Paper Table 1 deployability claim: the framework-side integration is a
single callback under 20 lines of code."""
import re


def test_engine_patch_under_20_loc():
    src = open('src/repro/serving/engine.py').read()
    m = re.search(r'# >>> VALVE-PATCH-BEGIN\n(.*?)# >>> VALVE-PATCH-END',
                  src, re.S)
    assert m, 'patch markers missing'
    lines = [l for l in m.group(1).splitlines()
             if l.strip() and not l.strip().startswith('#')]
    assert 0 < len(lines) < 20, f'patch is {len(lines)} LOC (paper: <20)'


def test_patch_is_single_callback():
    """The entire integration surface is one method the runtime calls."""
    src = open('src/repro/serving/engine.py').read()
    m = re.search(r'# >>> VALVE-PATCH-BEGIN\n(.*?)# >>> VALVE-PATCH-END',
                  src, re.S)
    defs = re.findall(r'def (\w+)', m.group(1))
    assert defs == ['on_pages_invalidated']
