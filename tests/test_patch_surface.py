"""Paper Table 1 deployability claim: the framework-side integration is a
single callback under 20 lines of code.

``patch_loc()`` is the single source of truth for the count — ``scripts/
ci.sh`` imports it for the fast gate, so the contract cannot drift between
CI and the test suite."""
import re

ENGINE_SRC = 'src/repro/serving/engine.py'
MARKERS = r'# >>> VALVE-PATCH-BEGIN\n(.*?)# >>> VALVE-PATCH-END'


def _patch_body() -> str:
    m = re.search(MARKERS, open(ENGINE_SRC).read(), re.S)
    assert m, 'patch markers missing'
    return m.group(1)


def patch_loc() -> int:
    """Non-comment, non-blank LOC between the VALVE-PATCH markers."""
    return len([l for l in _patch_body().splitlines()
                if l.strip() and not l.strip().startswith('#')])


def test_engine_patch_under_20_loc():
    assert 0 < patch_loc() < 20, f'patch is {patch_loc()} LOC (paper: <20)'


def test_patch_is_single_callback():
    """The entire integration surface is one method the runtime calls."""
    assert re.findall(r'def (\w+)', _patch_body()) == ['on_pages_invalidated']
