"""SSE wire-format conformance suite.

The streaming online API's framing contract, pinned as tests: encoder
output shape, incremental parsing under arbitrary chunk splits (including
mid-codepoint), multi-line data joining, CR/CRLF/LF endings, ``[DONE]``
termination, strict-mode malformed-frame rejection — and the end-to-end
bit-identity gate: the token text reassembled from a live SSE stream must
equal the non-streaming drain path's text for the same seed.
"""
import asyncio
import json

import pytest

from repro.serving.frontend.sse import (
    DONE_DATA, DONE_FRAME, SSEParser, SSEProtocolError, encode_sse)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def test_encode_basic_frame():
    assert encode_sse('hello') == b'data: hello\n\n'


def test_encode_with_event_and_id():
    assert encode_sse('x', event='tok', id='r1:0') == \
        b'event: tok\nid: r1:0\ndata: x\n\n'


def test_encode_multiline_data_one_line_per_data_field():
    assert encode_sse('a\nb') == b'data: a\ndata: b\n\n'


def test_encode_retry():
    assert encode_sse('x', retry=250) == b'retry: 250\ndata: x\n\n'


def test_done_frame_constant():
    assert DONE_FRAME == b'data: [DONE]\n\n'


# ---------------------------------------------------------------------------
# Parser: happy path
# ---------------------------------------------------------------------------

def test_parse_single_frame():
    (ev,) = SSEParser().feed(b'data: hello\n\n')
    assert ev.data == 'hello' and ev.event == 'message' and not ev.done


def test_parse_roundtrip_with_fields():
    (ev,) = SSEParser().feed(encode_sse('payload', event='tok', id='a:1'))
    assert (ev.data, ev.event, ev.id) == ('payload', 'tok', 'a:1')


def test_parse_multiple_frames_in_one_chunk():
    evs = SSEParser().feed(encode_sse('one') + encode_sse('two'))
    assert [e.data for e in evs] == ['one', 'two']


def test_multiline_data_joined_with_newline():
    (ev,) = SSEParser().feed(b'data: a\ndata: b\n\n')
    assert ev.data == 'a\nb'


def test_no_space_after_colon():
    (ev,) = SSEParser().feed(b'data:tight\n\n')
    assert ev.data == 'tight'


def test_comment_lines_ignored():
    p = SSEParser()
    assert p.feed(b': keep-alive ping\n\n') == []
    (ev,) = p.feed(b': note\ndata: x\n\n')
    assert ev.data == 'x'


def test_crlf_and_cr_line_endings():
    (ev,) = SSEParser().feed(b'data: a\r\ndata: b\r\n\r\n')
    assert ev.data == 'a\nb'
    p = SSEParser()
    assert p.feed(b'data: a\rdata: b\r\r') == []   # last CR: LF may follow
    (ev,) = p.finish()                             # EOF resolves the CR
    assert ev.data == 'a\nb'


def test_done_sets_closed():
    p = SSEParser()
    (ev,) = p.feed(DONE_FRAME)
    assert ev.done and ev.data == DONE_DATA and p.closed


def test_id_is_sticky_across_frames():
    p = SSEParser()
    (a,) = p.feed(b'id: 7\ndata: x\n\n')
    (b,) = p.feed(b'data: y\n\n')
    assert a.id == '7' and b.id == '7'


# ---------------------------------------------------------------------------
# Parser: split-across-chunks (the incremental contract)
# ---------------------------------------------------------------------------

def _feed_split(frame: bytes, step: int):
    p = SSEParser()
    out = []
    for i in range(0, len(frame), step):
        out += p.feed(frame[i:i + step])
    p.finish()
    return out


def test_byte_by_byte_equals_whole_frame():
    frame = encode_sse(json.dumps({'t': 42}), event='tok', id='r:0') \
        + encode_sse('x') + DONE_FRAME
    whole = SSEParser().feed(frame)
    for step in (1, 2, 3, 5, 7, len(frame)):
        assert _feed_split(frame, step) == whole


def test_split_mid_utf8_codepoint():
    frame = encode_sse('héllo wörld ✓')
    whole = SSEParser().feed(frame)
    assert _feed_split(frame, 1) == whole       # splits every multibyte char


def test_split_between_cr_and_lf():
    # the CR/LF pair split across chunks must not double-break
    p = SSEParser()
    assert p.feed(b'data: a\r') == []
    (ev,) = p.feed(b'\ndata: b\n\n')
    assert ev.data == 'a\nb'


def test_frame_split_at_blank_line():
    p = SSEParser()
    assert p.feed(b'data: x\n') == []
    (ev,) = p.feed(b'\n')
    assert ev.data == 'x'


# ---------------------------------------------------------------------------
# Parser: malformed-frame rejection (strict) vs lenient mode
# ---------------------------------------------------------------------------

def test_unknown_field_rejected_strict():
    with pytest.raises(SSEProtocolError):
        SSEParser().feed(b'bogus: x\ndata: y\n\n')


def test_unknown_field_ignored_lenient():
    (ev,) = SSEParser(strict=False).feed(b'bogus: x\ndata: y\n\n')
    assert ev.data == 'y'


def test_dataless_frame_rejected_strict():
    with pytest.raises(SSEProtocolError):
        SSEParser().feed(b'event: tok\n\n')


def test_dataless_frame_dropped_lenient():
    assert SSEParser(strict=False).feed(b'event: tok\n\n') == []


def test_non_integer_retry_rejected_strict():
    with pytest.raises(SSEProtocolError):
        SSEParser().feed(b'retry: soon\ndata: x\n\n')


def test_invalid_utf8_rejected_strict():
    with pytest.raises(SSEProtocolError):
        SSEParser().feed(b'data: \xff\xfe broken\n\n')


def test_truncated_stream_rejected_at_finish():
    p = SSEParser()
    p.feed(b'data: never terminated')
    with pytest.raises(SSEProtocolError):
        p.finish()


def test_clean_stream_finishes_quietly():
    p = SSEParser()
    p.feed(encode_sse('x') + DONE_FRAME)
    assert p.finish() == []


# ---------------------------------------------------------------------------
# End-to-end: streamed token text ≡ non-streaming drain (same seed)
# ---------------------------------------------------------------------------

def _tiny_node():
    from repro.configs import get_config, reduced
    from repro.core.clock import VirtualClock
    from repro.core.runtime import RuntimeConfig, ValveRuntime
    from repro.launch.node import NodeOrchestrator
    from repro.serving.engine import EngineConfig
    from repro.serving.kvpool import KVPool

    pool = KVPool(8, 4, page_size=4, reserved_handles=1)
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=VirtualClock())
    node = NodeOrchestrator(rt, idle_advance=1e-3)
    node.add_engine(reduced(get_config('qwen3-0.6b'), page_size=4),
                    EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                                 klass='online'), seed=0, name='online')
    return node


def _prompts(node, n, seed=3):
    import numpy as np
    rng = np.random.default_rng(seed)
    return [rng.integers(1, node.online.mcfg.vocab_size, 10).tolist()
            for _ in range(n)]


def test_streamed_text_bit_identical_to_drain():
    """Greedy decoding is deterministic, so the SSE deltas reassembled
    over the wire must equal the drain path's rendered text exactly."""
    from repro.serving.frontend.app import FrontendApp, token_text
    from repro.serving.frontend.driver import AsyncNodeDriver
    from repro.serving.frontend.testing import ASGIClient

    prompts = None

    # reference: direct engine drain, no front-end
    ref_node = _tiny_node()
    prompts = _prompts(ref_node, 3)
    ref_rids = [ref_node.online.submit(p, max_new_tokens=6)
                for p in prompts]
    ref_node.drain(max_steps=5000)
    ref_texts = [token_text(ref_node.online.output_tokens(r))
                 for r in ref_rids]

    async def streamed():
        node = _tiny_node()
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            texts = []
            for p in prompts:
                sr = client.stream('POST', '/v1/completions',
                                   json={'prompt': p, 'max_tokens': 6,
                                         'stream': True})
                parts = []
                async with sr:
                    assert sr.status == 200
                    assert sr.headers['content-type'] == 'text/event-stream'
                    async for ev in sr.events():   # strict parser
                        if ev.done:
                            break
                        chunk = json.loads(ev.data)['choices'][0]
                        if chunk.get('token') is not None:
                            parts.append(chunk['text'])
                texts.append(''.join(parts))
            return texts

    assert asyncio.run(streamed()) == ref_texts


def test_stream_terminates_with_done_after_finish_reason():
    """Wire order: token frames, then exactly one finish_reason frame,
    then [DONE], then EOF."""
    from repro.serving.frontend.app import FrontendApp
    from repro.serving.frontend.driver import AsyncNodeDriver
    from repro.serving.frontend.testing import ASGIClient

    async def run():
        node = _tiny_node()
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            (prompt,) = _prompts(node, 1)
            sr = client.stream('POST', '/v1/completions',
                               json={'prompt': prompt, 'max_tokens': 4,
                                     'stream': True})
            events = []
            async with sr:
                async for ev in sr.events():
                    events.append(ev)
            return events

    events = asyncio.run(run())
    assert events[-1].done
    payloads = [json.loads(e.data)['choices'][0] for e in events[:-1]]
    tokens = [p for p in payloads if p.get('token') is not None]
    finals = [p for p in payloads if p.get('token') is None]
    assert len(tokens) == 4
    assert all(p['finish_reason'] is None for p in tokens)
    assert [p['finish_reason'] for p in finals] == ['length']
