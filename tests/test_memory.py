"""Memory-plane API v1: lease lifecycle, refcounted CoW prefix sharing,
partial (surviving-prefix) invalidation, incremental pool counters, and the
memoized Algorithm 1 variants.

Deliberately jax-free: ``scripts/ci.sh`` runs this file as the fast lease
property smoke.  The deterministic random-ops suites below always run; the
hypothesis section at the bottom deepens them when hypothesis is installed
(declared in pyproject ``[test]``; plain envs skip it, not error).
"""
import random

import pytest

from repro.core import eviction
from repro.core.memory import (KVLease, LeaseInvalidation, MemoryPlane,
                               MigrationRefusal)
from repro.serving.kvpool import KVPool, QUARANTINE_PAGE


def _plane(n_handles=8, pph=4, page=4, reserved=1, **kw):
    pool = KVPool(n_handles, pph, page_size=page, reserved_handles=reserved)
    return MemoryPlane(pool, **kw), pool


# ---------------------------------------------------------------------------
# Lease lifecycle
# ---------------------------------------------------------------------------

def test_lease_basic_lifecycle():
    pl, pool = _plane()
    lease = pl.admit('a', 4, 'offline')
    assert isinstance(lease, KVLease)
    assert len(lease) == 4 and lease.resume_tokens == 0
    assert lease == pool.pages_of_request('a')      # list-compatible
    assert lease.extend(2) and len(lease) == 6
    # admit on a live id is extend-to-target, same lease object
    assert pl.admit('a', 8, 'offline') is lease and len(lease) == 8
    pl.check_invariants()
    lease.release()
    assert lease.released and pl.live_leases() == []
    assert pool.used_pages_for('offline') == 0
    pl.check_invariants()


def test_release_drops_refs_to_exactly_zero():
    pl, pool = _plane()
    prompt = list(range(13))
    a = pl.admit('a', 4, 'offline', prompt=prompt, scope='s')
    a.note_filled(13)                                # publishes pages 0..2
    b = pl.admit('b', 4, 'offline', prompt=prompt, scope='s')
    shared = list(b)[:3]
    assert shared == list(a)[:3] and b.resume_tokens == 12
    for p in shared:
        assert len(pl._page_users[p]) == 2
    a.release()
    for p in shared:
        assert pl._page_users[p] == {'b'}            # exactly one ref left
    b.release()
    for p in shared:
        assert len(pl._page_users[p]) == 0           # zero, retained in cache
        assert p in pl._cache
    pl.check_invariants()
    pl.drop_cache()
    assert pool.used_pages_for('offline') == 0
    pl.check_invariants()


def test_admit_failure_rolls_back_attachments():
    pl, pool = _plane(n_handles=2, pph=4, reserved=1)   # 4 offline pages
    prompt = list(range(13))
    a = pl.admit('a', 4, 'offline', prompt=prompt, scope='s')
    a.note_filled(13)
    # pool exhausted: the second admission must fail WITHOUT leaking the
    # shared-prefix refs it attached before the private alloc failed
    assert pl.admit('b', 4, 'offline', prompt=prompt, scope='s') is None
    assert pl.live_leases() == ['a']
    for p in list(a):
        assert pl._page_users[p] == {'a'}
    pl.check_invariants()


def test_same_id_readmits_after_full_release_with_shared_survivors():
    """A request id whose pages outlive it (shared with another lease) must
    be re-admittable — pool ownership moves to an internal block id."""
    pl, pool = _plane()
    prompt = list(range(13))
    a = pl.admit('a', 4, 'offline', prompt=prompt, scope='s')
    a.note_filled(13)
    b = pl.admit('b', 4, 'offline', prompt=prompt, scope='s')
    a.release()                     # b still refs a's prefix pages
    a2 = pl.admit('a', 4, 'offline', prompt=prompt, scope='s')
    assert a2 is not None and a2.resume_tokens == 12   # re-attached
    pl.check_invariants()


# ---------------------------------------------------------------------------
# CoW / fork
# ---------------------------------------------------------------------------

def test_fork_then_diverge_never_mutates_parent():
    pl, pool = _plane()
    parent = pl.admit('p', 6, 'offline')
    parent.note_filled(8)                       # 2 full pages materialized
    before = list(parent)
    child = parent.fork('c')
    assert list(child)[:2] == before[:2]        # CoW-shared filled prefix
    assert child.resume_tokens == 8
    assert set(list(child)[2:]).isdisjoint(before)   # divergent tail private
    # the child diverges (fills its own tail) — the parent's page list and
    # fill must be untouched
    child.note_filled(24)
    assert list(parent) == before and parent.filled == 8
    child.release()
    assert list(parent) == before
    pl.check_invariants()


# ---------------------------------------------------------------------------
# Partial invalidation
# ---------------------------------------------------------------------------

def test_partial_invalidation_keeps_surviving_prefix():
    pl, pool = _plane(n_handles=6, pph=4)
    a = pl.admit('a', 10, 'offline')            # spans ≥3 offline handles
    a.note_filled(40)                           # fully materialized
    last = pool.handle_of(list(a)[9])           # handle holding the tail
    inv = pl.reclaim_handles([last])
    assert 'a' in inv
    la = inv['a']
    assert isinstance(la, LeaseInvalidation)
    assert 0 < la.keep < 10
    assert la.resume == la.keep * pool.page_size
    assert la.lost_tokens == 40 - la.resume
    assert not la.released
    # the lease was truncated to the surviving prefix and is extendable
    assert len(a) == la.keep and a.filled == la.resume
    assert pl.admit('a', 10, 'offline') is a and len(a) == 10
    assert a.resume_tokens == la.resume         # resume point survived
    pl.check_invariants()


def test_whole_invalidation_when_prefix_dies():
    pl, pool = _plane(n_handles=6, pph=4)
    a = pl.admit('a', 10, 'offline')
    a.note_filled(40)
    first = pool.handle_of(list(a)[0])          # handle holding page 0
    inv = pl.reclaim_handles([first])
    assert inv['a'].keep == 0 and inv['a'].released
    assert a.released and pl.live_leases() == []
    pl.check_invariants()


def test_partial_disabled_reports_no_survivors():
    pl, pool = _plane(n_handles=6, pph=4, partial=False)
    a = pl.admit('a', 10, 'offline')
    a.note_filled(40)
    last = pool.handle_of(list(a)[9])
    inv = pl.reclaim_handles([last])
    assert inv['a'].keep == 0 and inv['a'].released   # legacy semantics
    pl.check_invariants()


def test_shared_page_invalidation_hits_every_user_at_same_position():
    pl, pool = _plane(n_handles=8, pph=4)
    prompt = list(range(13))
    a = pl.admit('a', 6, 'offline', prompt=prompt, scope='s')
    a.note_filled(13)
    b = pl.admit('b', 6, 'offline', prompt=prompt, scope='s')
    b.note_filled(20)
    shared_page = list(a)[1]                    # logical position 1, both
    inv = pl.reclaim_handles([pool.handle_of(shared_page)])
    assert set(inv) >= {'a', 'b'}
    assert inv['a'].keep == inv['b'].keep       # same logical cut
    pl.check_invariants()


def test_legacy_ids_keep_whole_request_semantics():
    """Ids allocated around the plane lose everything, like the old pool."""
    pl, pool = _plane(n_handles=4, pph=4)
    pool.alloc('legacy', 6, 'offline')          # direct, no lease
    h = pool.handles_of_request('legacy')[0]
    inv = pl.reclaim_handles([h])
    assert inv['legacy'].keep == 0 and inv['legacy'].released
    assert 'legacy' not in pool.pages_of        # survivors freed too
    pl.check_invariants()


# ---------------------------------------------------------------------------
# Cross-pool migration: explicit refusals + rescue fall-through
# ---------------------------------------------------------------------------

def test_migrate_refusals_are_explicit_and_leave_source_untouched():
    """``migrate`` answers with a falsy :class:`MigrationRefusal` naming
    the cause — never a silent None — and a refused lease keeps every
    page, ref and fill on the source plane."""
    src, src_pool = _plane()
    dst, _ = _plane()
    prompt = list(range(13))
    p = src.admit('p', 4, 'offline', prompt=prompt, scope='s')
    p.note_filled(13)                            # publishes pages 0..2
    q = src.admit('q', 4, 'offline', prompt=prompt, scope='s')
    assert q.resume_tokens == 12                 # attached the shared prefix

    ref = src.migrate('nope', dst)
    assert isinstance(ref, MigrationRefusal) and not ref
    assert ref.reason == 'unknown-lease' and ref.pinned_pages == ()

    assert src.migrate('p', src).reason == 'self-target'

    before = list(p)
    ref = src.migrate('p', dst)                  # q pins the shared prefix
    assert not ref and ref.reason == 'shared-pages'
    assert set(ref.pinned_pages) == set(before[:3])
    assert 'pinned_pages' in repr(ref)
    assert list(p) == before and not p.released  # source untouched
    assert src.live_leases() == ['p', 'q'] and dst.live_leases() == []
    assert src.stats.migration_refusals == 3
    src.check_invariants()
    dst.check_invariants()


def test_reclaim_rescues_private_leases_and_truncates_pinned_ones():
    """With a migration target set, reclamation rescues what CAN move
    (private lease: ``migrated_to`` set, ``lost_tokens == 0``, alive on
    the destination) and falls through to ordinary truncation for what
    cannot (shared-prefix leases) — charging each victim exactly once."""
    src, src_pool = _plane()
    dst, dst_pool = _plane()
    prompt = list(range(13))
    p = src.admit('p', 4, 'offline', prompt=prompt, scope='s')
    p.note_filled(13)
    q = src.admit('q', 4, 'offline', prompt=prompt, scope='s')
    q.note_filled(13)
    r = src.admit('r', 5, 'offline')             # private: sole user/owner
    r.note_filled(20)
    src.migration_targets = [dst]

    refusals0 = src.stats.migration_refusals
    inv = src.reclaim_handles(src_pool.offline_handles())

    # the private lease was rescued whole: same object, re-homed, no loss
    assert inv['r'].migrated_to == dst_pool.name
    assert inv['r'].lost_tokens == 0 and not inv['r'].released
    assert inv['r'].keep == 5 and inv['r'].resume == 20
    assert src.live_leases() == [] and dst.live_leases() == ['r']
    assert dst.leases['r'] is r and r.plane is dst
    assert r.filled == 20 and r.resume_tokens == 20
    # the pinned leases took the truncation path, counted once, with the
    # shared-page refusal recorded rather than swallowed
    for lid in ('p', 'q'):
        assert inv[lid].migrated_to is None
        assert inv[lid].lost_tokens > 0
    assert src.stats.migration_refusals > refusals0
    assert src.stats.leases_migrated == 1
    src.check_invariants()
    dst.check_invariants()


# ---------------------------------------------------------------------------
# Pool satellite fixes
# ---------------------------------------------------------------------------

def test_noop_free_does_not_count():
    """Regression: ``free`` for an id holding no pages must not count as a
    lifecycle event (reclaim already freed invalidated requests, so the
    engine's terminal free double-counted)."""
    pool = KVPool(4, 4, reserved_handles=1)
    pool.alloc('a', 3, 'offline')
    assert pool.free('a') == 3
    assert pool.stats.frees == 1
    assert pool.free('a') == 0                  # no-op
    assert pool.free('never-existed') == 0
    assert pool.stats.frees == 1                # unchanged
    pool.check_invariants()


def test_pool_incremental_counters_random_ops():
    """free_pages_for / used_pages_for / online_used_handles are O(1)
    counters now; a seeded op soup cross-checks them against the full-scan
    invariants after every operation."""
    rng = random.Random(7)
    pool = KVPool(6, 4, reserved_handles=2)
    live = []
    for i in range(400):
        op = rng.randrange(6)
        if op in (0, 1):
            rid = f'r{i}'
            klass = 'online' if op == 0 else 'offline'
            if pool.alloc(rid, rng.randint(1, 6), klass) is not None:
                live.append(rid)
        elif op == 2 and live:
            pool.free(live.pop(rng.randrange(len(live))))
        elif op == 3:
            offl = pool.offline_handles()
            if offl:
                inv = pool.reclaim_handles([rng.choice(offl)])
                live = [r for r in live if r in pool.pages_of]
        elif op == 4:
            empties = pool.empty_offline_handles()
            if empties:
                pool.reserve_handle(rng.choice(empties))
        else:
            pool.release_reserved_handle()
        pool.check_invariants()     # cross-checks every counter vs scan
    assert pool.owner[QUARANTINE_PAGE] is None


# ---------------------------------------------------------------------------
# Eviction: memoized == naive, partial model prefers tails
# ---------------------------------------------------------------------------

def _random_instance(rng):
    n_handles = rng.randint(2, 10)
    n_reqs = rng.randint(1, 14)
    costs = {f'r{i}': rng.randint(1, 200) for i in range(n_reqs)}
    assign = {h: {r for r in costs if rng.random() < 0.35}
              for h in range(n_handles)}
    return n_handles, costs, assign


def test_memoized_select_handles_equals_naive_seeded():
    rng = random.Random(0)
    for _ in range(300):
        n_handles, costs, assign = _random_instance(rng)
        k = rng.randint(1, n_handles)
        got = eviction.select_handles(
            k, list(range(n_handles)), assign.__getitem__, costs.__getitem__)
        want = eviction._select_handles_naive(
            k, list(range(n_handles)), assign.__getitem__, costs.__getitem__)
        assert got == want, (k, costs, assign)


def test_select_handles_partial_matches_naive_cut_model():
    """The memoized partial selector must equal a brute-force greedy over
    the same marginal-loss model (min-cut semantics)."""
    rng = random.Random(1)
    for _ in range(200):
        n_handles = rng.randint(2, 8)
        n_reqs = rng.randint(1, 8)
        filled = {f'r{i}': rng.randint(0, 64) for i in range(n_reqs)}
        impact = {h: {r: rng.randint(0, 15) for r in filled
                      if rng.random() < 0.4} for h in range(n_handles)}
        pg = 4

        def loss(r, idx):
            return max(0, filled[r] - idx * pg)

        k = rng.randint(1, n_handles)
        got = eviction.select_handles_partial(
            k, list(range(n_handles)), impact.__getitem__, loss)

        # brute-force greedy oracle
        S, cut = [], {}
        for _round in range(k):
            best, best_c = None, None
            for h in range(n_handles):
                if h in S:
                    continue
                c = sum(loss(r, min(cut.get(r, 1 << 30), idx))
                        - loss(r, cut.get(r, 1 << 30))
                        for r, idx in impact[h].items())
                if best_c is None or c < best_c:
                    best, best_c = h, c
            S.append(best)
            for r, idx in impact[best].items():
                cut[r] = min(cut.get(r, 1 << 30), idx)
        assert got == S, (impact, filled)


def test_partial_cost_prefers_tail_and_cached_handles():
    """Algorithm 1 under the plane's cost: a handle holding only a
    request's TAIL pages (small marginal recompute) beats one holding its
    head, and zero-ref cached prefix pages are free to take."""
    pl, pool = _plane(n_handles=8, pph=4)
    a = pl.admit('a', 12, 'offline')
    a.note_filled(48)
    handles = pool.handles_of_request('a')
    # the selector must pick the tail handle (lowest marginal recompute)
    pick = eviction.select_handles_partial(
        1, handles, pl.impact_of, pl.recompute_cost)
    tail_handle = pool.handle_of(list(a)[-1])
    assert pick == [tail_handle], pick
    # a finished request's cached prefix pages cost nothing
    b = pl.admit('b', 4, 'offline', prompt=list(range(17)), scope='s')
    b.note_filled(17)
    b.release()                                 # pages retained, zero-ref
    cached_handle = pool.handle_of(pl._prefix_index[
        next(iter(pl._prefix_index))])
    assert pl.impact_of(cached_handle).get('b') is None
    pl.check_invariants()


# ---------------------------------------------------------------------------
# Deterministic lease-op soup (the ci.sh fast smoke)
# ---------------------------------------------------------------------------

def _lease_soup(seed, steps=300):
    rng = random.Random(seed)
    pl, pool = _plane(n_handles=8, pph=4, page=4, reserved=1)
    prompts = [list(range(20)), list(range(100, 120)), list(range(13))]
    seq = 0
    for _ in range(steps):
        op = rng.randrange(8)
        live = [pl.leases[l] for l in pl.live_leases()]
        if op in (0, 1):
            seq += 1
            klass = 'online' if rng.random() < 0.2 else 'offline'
            prompt = rng.choice(prompts) if rng.random() < 0.7 else None
            pl.admit(f'r{seq}', rng.randint(1, 8), klass,
                     prompt=prompt, scope='s' if klass == 'offline' else 'o')
        elif op == 2 and live:
            lease = rng.choice(live)
            lease.note_filled(rng.randint(0, len(lease) * 4))
        elif op == 3 and live:
            rng.choice(live).extend(rng.randint(1, 3))
        elif op == 4 and live:
            seq += 1
            rng.choice(live).fork(f'f{seq}', rng.randint(1, 8))
        elif op == 5 and live:
            rng.choice(live).release()
        elif op == 6:
            offl = pool.offline_handles()
            if offl:
                pl.reclaim_handles([rng.choice(offl)])
        else:
            if rng.random() < 0.3:
                pl.drop_cache()
            elif pool.empty_offline_handles():
                pool.reserve_handle(pool.empty_offline_handles()[0])
            else:
                pool.release_reserved_handle()
        pl.check_invariants()
    # teardown must return the pool to exactly empty
    for lid in list(pl.live_leases()):
        pl.release_id(lid)
    pl.drop_cache()
    pl.check_invariants()
    assert pool.used_pages_for('online') == 0
    assert pool.used_pages_for('offline') == 0


def test_lease_random_ops_smoke():
    for seed in (0, 1, 2):
        _lease_soup(seed)


# ---------------------------------------------------------------------------
# Hypothesis property suite (skips cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                         # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_lease_soup_property(seed):
        """Invariants hold under arbitrary lease-op sequences: no page
        double-owned, refcounts == user sets, zero-ref pages cached or
        freed, fills within bounds (checked after every op)."""
        _lease_soup(seed, steps=120)

    @settings(max_examples=80, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 10))
    def test_memoized_eviction_equivalence_property(seed, k):
        rng = random.Random(seed)
        n_handles, costs, assign = _random_instance(rng)
        got = eviction.select_handles(
            k, list(range(n_handles)), assign.__getitem__,
            costs.__getitem__)
        want = eviction._select_handles_naive(
            k, list(range(n_handles)), assign.__getitem__,
            costs.__getitem__)
        assert got == want

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_fork_cow_property(seed):
        """fork-then-diverge never mutates the parent's pages; releasing
        the child leaves the parent's refs intact."""
        rng = random.Random(seed)
        pl, pool = _plane(n_handles=8, pph=4)
        parent = pl.admit('p', rng.randint(1, 8), 'offline')
        parent.note_filled(rng.randint(0, len(parent) * 4))
        before, fill_before = list(parent), parent.filled
        child = parent.fork('c', rng.randint(1, 8))
        if child is not None:
            child.note_filled(len(child) * 4)
            assert list(parent) == before
            assert parent.filled == fill_before
            child.release()
        assert list(parent) == before
        for p in before:
            assert 'p' in pl._page_users[p]
        pl.check_invariants()
