"""Shared test fixtures: virtual multi-device CPU topology.

``launch/dryrun.py`` pioneered the trick: XLA's host platform can present
N virtual devices (``--xla_force_host_platform_device_count``) so mesh
code paths — sharded jit, shard_map collectives, gate fanout sized off a
mesh — run on single-CPU CI.  The flag only takes effect if it is set
before the first ``jax`` import anywhere in the process, which is why it
lives at module scope in the root conftest (pytest imports conftest before
any test module).

``tests/test_distributed.py`` is unaffected: it launches subprocesses
with an explicit per-child ``XLA_FLAGS``.
"""
import os

N_VIRTUAL_DEVICES = 8

_flag = f'--xla_force_host_platform_device_count={N_VIRTUAL_DEVICES}'
if 'xla_force_host_platform_device_count' not in os.environ.get('XLA_FLAGS', ''):
    os.environ['XLA_FLAGS'] = f"{os.environ.get('XLA_FLAGS', '')} {_flag}".strip()
os.environ.setdefault('JAX_PLATFORMS', 'cpu')

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope='session')
def virtual_devices():
    """All virtual CPU devices (≥ N_VIRTUAL_DEVICES when the flag landed
    before jax initialized; skip dependents if something beat us to it)."""
    import jax
    devs = jax.devices()
    if len(devs) < 4:
        pytest.skip(f'virtual device flag ineffective ({len(devs)} devices)')
    return devs


@pytest.fixture(scope='session')
def make_virtual_mesh(virtual_devices):
    """Build a Mesh over the first prod(shape) virtual devices.

    ``make_virtual_mesh((4,), ('model',))`` → 4-way tensor-parallel mesh;
    ``make_virtual_mesh((2, 2), ('data', 'model'))`` → 2×2.
    """
    from jax.sharding import Mesh

    def make(shape, axis_names):
        n = int(np.prod(shape))
        if n > len(virtual_devices):
            pytest.skip(f'need {n} devices, have {len(virtual_devices)}')
        devs = np.asarray(virtual_devices[:n]).reshape(shape)
        return Mesh(devs, axis_names)

    return make
