"""Fleet placement plane: GPU catalog + topology model, the placement-
policy strategy interface, and the global optimizer vs the greedy
baseline on identical measured-shape telemetry."""
import numpy as np
import pytest

from repro.core.cluster.harness import make_harvest_jobs
from repro.core.cluster.perfmodel import (
    GPUTelemetry, NodeTelemetry, predict_normalized_throughput,
    profile_workload)
from repro.core.cluster.placement import (
    GPU_CATALOG, GlobalOptConfig, GlobalPlacementPolicy, GreedyEq1Policy,
    PLACEMENT_POLICIES, PlacementPolicy, TopologyModel, make_fleet_profiles,
    resolve_policy)
from repro.core.cluster.scheduler import ClusterScheduler, OfflineJob
from repro.core.sim.colocation import SimConfig
from repro.core.sim.workload import make_fleet_workloads


def _gpu(busy, free_frac=0.8, horizon=100.0, pool=4096, profile=None):
    ts = np.linspace(0, horizon, 16)
    free = np.full_like(ts, free_frac * pool)
    return GPUTelemetry(busy, ts, free, window=(0, horizon),
                        source='nodesim', profile=profile)


def _job(name, sla=0.3, m_req=1024, n_gpus=1):
    return OfflineJob(profile_workload(name, thrput_max=10.0, m_req=m_req,
                                       n_gpus=n_gpus), sla)


# ---------------------------------------------------------------------------
# Catalog + topology
# ---------------------------------------------------------------------------

def test_gpu_profile_scales_sim_config():
    base = SimConfig(total_pages=1024)
    t4 = GPU_CATALOG['T4'].scale_sim(base)
    assert t4.total_pages == int(1024 * 0.375)
    assert t4.t_decode_iter == pytest.approx(base.t_decode_iter / 0.3)
    assert t4.t_prefill_per_token == pytest.approx(
        base.t_prefill_per_token / 0.3)
    assert t4.t_decode_gap == base.t_decode_gap      # host-side, unscaled
    # the reference GPU is a no-op rescale
    assert GPU_CATALOG['A100'].scale_sim(base) == base


def test_heterogeneity_scalar_enters_eq1():
    w = profile_workload('w', thrput_max=10.0, m_req=512)
    ref = predict_normalized_throughput(w, [_gpu([])])
    slow = predict_normalized_throughput(
        w, [_gpu([], profile=GPU_CATALOG['T4'])])
    assert slow == pytest.approx(ref * 0.3)


def test_topology_tiers_and_costs():
    topo = TopologyModel(rack_of={'a': 0, 'b': 0, 'c': 1},
                         intra_link_of={'a': 'nvlink', 'b': 'pcie'})
    assert topo.link_tier('a', 'a') == 'nvlink'
    assert topo.link_tier('b', 'b') == 'pcie'
    assert topo.link_tier('a', 'b') == 'node-local'
    assert topo.link_tier('a', 'c') == 'cross-rack'
    assert topo.link_cost('a', 'b') < topo.link_cost('a', 'c')
    assert topo.intra_efficiency('a') == 1.0
    assert topo.intra_efficiency('b') < 1.0


def test_cheapest_pair_prefers_same_rack_and_is_deterministic():
    topo = TopologyModel(rack_of={'a': 0, 'b': 1, 'c': 0})
    got = topo.cheapest_pair(['a'], ['b', 'c'])
    assert got == ('a', 'c', 'node-local', topo.link_costs['node-local'])
    # src == dst only when it is the single option
    assert topo.cheapest_pair(['a'], ['a'])[:2] == ('a', 'a')
    assert topo.cheapest_pair(['a'], ['a', 'b'])[:2] == ('a', 'b')


def test_make_fleet_profiles_prefix_stable_and_homogeneous_per_node():
    names8 = [f'node{i}' for i in range(8)]
    p8, topo8 = make_fleet_profiles(names8, 2, seed=5, nodes_per_rack=4)
    p4, _ = make_fleet_profiles(names8[:4], 2, seed=5, nodes_per_rack=4)
    for n in names8[:4]:                    # growth never re-rolls a node
        assert p8[n] == p4[n]
    for n, profs in p8.items():
        assert len(set(profs)) == 1         # homogeneous within a node
        assert topo8.intra_link_of[n] == profs[0].intra_link
    assert topo8.rack_of['node0'] == 0 and topo8.rack_of['node7'] == 1


# ---------------------------------------------------------------------------
# Policy registry + greedy equivalence
# ---------------------------------------------------------------------------

def test_registry_resolves_name_class_and_instance():
    assert set(PLACEMENT_POLICIES) >= {'greedy-eq1', 'global-opt'}
    assert isinstance(resolve_policy('greedy-eq1'), GreedyEq1Policy)
    assert isinstance(resolve_policy(GlobalPlacementPolicy),
                      GlobalPlacementPolicy)
    inst = GlobalPlacementPolicy(GlobalOptConfig(max_rounds=1))
    assert resolve_policy(inst) is inst
    assert isinstance(resolve_policy('global-opt'), PlacementPolicy)


def _two_nodes():
    return [NodeTelemetry('n0', [_gpu([(0, 10.0)]), _gpu([(0, 10.0)])]),
            NodeTelemetry('n1', [_gpu([(0, 40.0)]), _gpu([(5.0, 50.0)])])]


def test_greedy_batch_identical_to_sequential_place():
    jobs = [_job(f'j{i}') for i in range(3)]
    a = ClusterScheduler(_two_nodes(), policy='greedy-eq1')
    placed = a.place_all(jobs)
    b = ClusterScheduler(_two_nodes())
    for j in [_job(f'j{i}') for i in range(3)]:
        b.place(j)
    assert {p.job.job_id: (p.node, p.gpu_indices) for p in placed} \
        == {k: (p.node, p.gpu_indices) for k, p in b.placements.items()}


# ---------------------------------------------------------------------------
# Global optimizer
# ---------------------------------------------------------------------------

def _conflict_fixture():
    """Greedy traps itself: job A (submitted first) takes the idle node,
    leaving memory-hungry job B only the memory-starved node, where it
    misses its SLA.  The global solve swaps them and places both."""
    n_idle = NodeTelemetry('idle', [_gpu([(0, 10.0)], free_frac=0.9)])
    n_tight = NodeTelemetry('tight', [_gpu([(0, 20.0)], free_frac=0.125)])
    job_a = _job('a', sla=0.3, m_req=256)       # fits anywhere
    job_b = _job('b', sla=0.5, m_req=2048)      # needs the idle node's mem
    return [n_idle, n_tight], [job_a, job_b]


def test_global_beats_greedy_on_conflict_fixture():
    nodes, jobs = _conflict_fixture()
    g = ClusterScheduler(nodes, policy='greedy-eq1')
    g.place_all(jobs)
    assert set(g.placements) == {'a'}           # greedy strands job b
    nodes, jobs = _conflict_fixture()
    o = ClusterScheduler(nodes, policy='global-opt')
    o.place_all(jobs)
    assert set(o.placements) == {'a', 'b'}
    assert o.placements['b'].node == 'idle'
    assert o.utilization_gain() > g.utilization_gain()
    rep = o.policy.last_report
    assert rep.placed == 2 and rep.value >= rep.warm_start_value
    assert rep.wall_time_s >= 0 and 'warm' in rep.method


def test_global_never_below_greedy_objective():
    """On any shared telemetry the optimizer's predicted objective is ≥
    greedy's (better-of-two-seeds warm start + monotone improvement)."""
    rng = np.random.default_rng(0)
    for trial in range(5):
        nodes = []
        for i in range(4):
            busy = [(0.0, float(rng.uniform(5, 60)))]
            nodes.append(NodeTelemetry(
                f'n{i}', [_gpu(list(busy),
                               free_frac=float(rng.uniform(0.2, 0.9)))
                          for _ in range(2)]))
        jobs = [_job(f'j{k}', sla=float(rng.uniform(0.1, 0.4)),
                     m_req=float(rng.choice([256, 1024, 3000])))
                for k in range(5)]
        g = ClusterScheduler(nodes, policy='greedy-eq1')
        g.place_all(jobs)
        o = ClusterScheduler(nodes, policy='global-opt')
        o.place_all(jobs)
        assert o.utilization_gain() >= g.utilization_gain() - 1e-9, trial


def test_global_policy_deterministic():
    def run():
        nodes, jobs = _conflict_fixture()
        extra = [_job('c', sla=0.2, m_req=512), _job('d', sla=0.2)]
        s = ClusterScheduler(nodes, policy='global-opt')
        s.place_all(jobs + extra)
        return {k: (p.node, p.gpu_indices) for k, p in s.placements.items()}
    assert run() == run()


def test_pruning_knob_limits_candidates():
    nodes = [NodeTelemetry(f'n{i}', [_gpu([])]) for i in range(6)]
    pol = GlobalPlacementPolicy(GlobalOptConfig(max_candidates_per_job=2))
    s = ClusterScheduler(nodes, policy=pol)
    s.place_all([_job('j0'), _job('j1')])
    rep = pol.last_report
    assert rep.candidates == 12                 # 6 nodes × 2 jobs generated
    assert rep.pruned == 8                      # kept 2 per job


def test_retry_pending_avoid_list_with_global_policy():
    """Evicted jobs avoid their old node for exactly one retry under the
    global policy too (the avoid set flows into candidate generation)."""
    s = ClusterScheduler([NodeTelemetry('a', [_gpu([])])],
                         policy='global-opt')
    job = _job('j', sla=0.3)
    s.place_all([job])
    assert s.placements['j'].node == 'a'
    for _ in range(s.cfg.violation_patience):
        s.report_throughput('j', 0.0)
    assert s.evictions == 1
    assert s.retry_pending() == []              # sole node is avoided
    [p] = s.retry_pending()                     # avoid was one-shot
    assert p.node == 'a' and s.reschedules == 1


# ---------------------------------------------------------------------------
# Telemetry-consumption invariant (satellite): swapping policies must not
# change which telemetry fields the scoring path reads
# ---------------------------------------------------------------------------

class _RecordingGPU(GPUTelemetry):
    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.__dict__['_reads'] = set()

    def __getattribute__(self, name):
        if not name.startswith('_') and name != 'idle_fraction':
            object.__getattribute__(self, '__dict__').setdefault(
                '_reads', set()).add(name)
        return object.__getattribute__(self, name)


def _recording_nodes():
    def g(busy):
        ts = np.linspace(0, 100.0, 16)
        return _RecordingGPU(busy, ts, np.full_like(ts, 3000.0),
                             window=(0, 100.0), source='nodesim')
    return [NodeTelemetry('n0', [g([(0, 10.0)]), g([(0, 11.0)])]),
            NodeTelemetry('n1', [g([(0, 60.0)]), g([(30.0, 90.0)])])]


def _reads_for(policy):
    nodes = _recording_nodes()
    s = ClusterScheduler(nodes, policy=policy)
    s.place_all([_job('j0'), _job('j1'), _job('m', n_gpus=2)])
    reads = set()
    for n in nodes:
        for gpu in n.gpus:
            assert gpu.source == 'nodesim'
            reads |= gpu.__dict__['_reads']
    return reads


def test_policy_swap_consumes_identical_telemetry_fields():
    greedy, glob = _reads_for('greedy-eq1'), _reads_for('global-opt')
    assert greedy == glob
    # the scoring path reads exactly the Eq. 1 inputs (+ provenance above)
    assert {'busy_intervals', 'window', 'mem_trace_free',
            'profile'} <= greedy


# ---------------------------------------------------------------------------
# Seeding isolation (satellite): byte-reproducible, prefix-stable fleets
# ---------------------------------------------------------------------------

def test_fleet_workloads_byte_reproducible_and_prefix_stable():
    a = make_fleet_workloads(6, 2, horizon_s=50.0, seed=9)
    b = make_fleet_workloads(6, 2, horizon_s=50.0, seed=9)
    assert a == b                               # frozen dataclasses compare
    small = make_fleet_workloads(3, 2, horizon_s=50.0, seed=9)
    assert a[:3] == small                       # growth never re-rolls


def test_harvest_jobs_prefix_stable_slas():
    sim = SimConfig(total_pages=256)
    big = make_harvest_jobs(6, sim, seed=4)
    small = make_harvest_jobs(3, sim, seed=4)
    assert [h.job.sla for h in big[:3]] == [h.job.sla for h in small]
    again = make_harvest_jobs(6, sim, seed=4)
    assert [h.job.sla for h in big] == [h.job.sla for h in again]
