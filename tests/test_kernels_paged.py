"""Paged-attention kernel vs oracle: shape/dtype sweeps, quarantine-page
masking, ragged lengths — in interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

CASES = [
    # (B, Hq, Hkv, D, pg, maxp, dtype)
    (2, 4, 4, 64, 16, 4, jnp.float32),
    (2, 8, 2, 64, 16, 8, jnp.float32),
    (1, 16, 8, 128, 16, 4, jnp.bfloat16),
    (3, 4, 1, 32, 8, 5, jnp.float32),
    (2, 4, 2, 64, 4, 16, jnp.float32),
]


def _setup(case, seed=0):
    b, hq, hkv, d, pg, maxp, dtype = case
    rng = np.random.default_rng(seed)
    n_pages = b * maxp + 1
    q = jnp.asarray(rng.normal(size=(b, hq, d)) * 0.5, dtype)
    pk = jnp.asarray(rng.normal(size=(n_pages, pg, hkv, d)) * 0.5, dtype)
    pv = jnp.asarray(rng.normal(size=(n_pages, pg, hkv, d)) * 0.5, dtype)
    # each request owns a scattered set of pages (1..), like the real pool
    perm = rng.permutation(n_pages - 1) + 1
    pt = jnp.asarray(perm[: b * maxp].reshape(b, maxp), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, maxp * pg + 1, size=b), jnp.int32)
    return q, pk, pv, pt, lengths


@pytest.mark.parametrize('case', CASES)
def test_paged_matches_ref(case):
    q, pk, pv, pt, lengths = _setup(case, seed=hash(case) % 2**32)
    out = paged_attention(q, pk, pv, pt, lengths, interpret=True)
    ref = paged_attention_ref(q, pk, pv, pt, lengths)
    tol = 3e-2 if q.dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_quarantined_pages_are_harmless_when_masked():
    """Remapping pages past a request's length to quarantine (page 0) must
    not change its output — the Valve no-fault contract for healthy
    requests."""
    case = (2, 4, 2, 64, 8, 6, jnp.float32)
    q, pk, pv, pt, _ = _setup(case, seed=7)
    pg, maxp = 8, 6
    lengths = jnp.asarray([3 * pg, 2 * pg], jnp.int32)  # use 3 / 2 pages
    base = paged_attention(q, pk, pv, pt, lengths, interpret=True)
    pt_reclaimed = np.asarray(pt).copy()
    pt_reclaimed[0, 3:] = 0   # quarantine the unused tail
    pt_reclaimed[1, 2:] = 0
    out = paged_attention(q, pk, pv, jnp.asarray(pt_reclaimed), lengths,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


def test_paged_vs_dense_attention():
    """Paged read path must equal dense attention over the same tokens."""
    from repro.models import common as cm
    b, hq, hkv, d, pg, maxp = 2, 8, 4, 64, 4, 8
    rng = np.random.default_rng(3)
    s = maxp * pg
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)) * 0.5, jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, hq, d)) * 0.5, jnp.float32)
    lengths = jnp.asarray([s, s - 5], jnp.int32)

    # pack into a pool: page p of request r → physical 1 + r*maxp + p
    pool_k = jnp.zeros((1 + b * maxp, pg, hkv, d), jnp.float32)
    pool_v = jnp.zeros_like(pool_k)
    pool_k = pool_k.at[1:].set(
        k.reshape(b, maxp, pg, hkv, d).reshape(b * maxp, pg, hkv, d))
    pool_v = pool_v.at[1:].set(
        v.reshape(b, maxp, pg, hkv, d).reshape(b * maxp, pg, hkv, d))
    pt = jnp.arange(1, 1 + b * maxp, dtype=jnp.int32).reshape(b, maxp)

    out = paged_attention(q, pool_k, pool_v, pt, lengths, interpret=True)
    kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ref = cm.attention(q[:, None], k, v,
                       q_positions=lengths[:, None], kv_positions=kv_pos,
                       kv_valid=kv_pos < lengths[:, None], causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
