"""Paged-attention kernel vs oracle: shape/dtype sweeps, quarantine-page
masking, ragged lengths — in interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

CASES = [
    # (B, Hq, Hkv, D, pg, maxp, dtype)
    (2, 4, 4, 64, 16, 4, jnp.float32),
    (2, 8, 2, 64, 16, 8, jnp.float32),
    (1, 16, 8, 128, 16, 4, jnp.bfloat16),
    (3, 4, 1, 32, 8, 5, jnp.float32),
    (2, 4, 2, 64, 4, 16, jnp.float32),
]


def _setup(case, seed=0):
    b, hq, hkv, d, pg, maxp, dtype = case
    rng = np.random.default_rng(seed)
    n_pages = b * maxp + 1
    q = jnp.asarray(rng.normal(size=(b, hq, d)) * 0.5, dtype)
    pk = jnp.asarray(rng.normal(size=(n_pages, pg, hkv, d)) * 0.5, dtype)
    pv = jnp.asarray(rng.normal(size=(n_pages, pg, hkv, d)) * 0.5, dtype)
    # each request owns a scattered set of pages (1..), like the real pool
    perm = rng.permutation(n_pages - 1) + 1
    pt = jnp.asarray(perm[: b * maxp].reshape(b, maxp), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, maxp * pg + 1, size=b), jnp.int32)
    return q, pk, pv, pt, lengths


@pytest.mark.parametrize('case', CASES)
def test_paged_matches_ref(case):
    q, pk, pv, pt, lengths = _setup(case, seed=hash(case) % 2**32)
    out = paged_attention(q, pk, pv, pt, lengths, interpret=True)
    ref = paged_attention_ref(q, pk, pv, pt, lengths)
    tol = 3e-2 if q.dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_quarantined_pages_are_harmless_when_masked():
    """Remapping pages past a request's length to quarantine (page 0) must
    not change its output — the Valve no-fault contract for healthy
    requests."""
    case = (2, 4, 2, 64, 8, 6, jnp.float32)
    q, pk, pv, pt, _ = _setup(case, seed=7)
    pg, maxp = 8, 6
    lengths = jnp.asarray([3 * pg, 2 * pg], jnp.int32)  # use 3 / 2 pages
    base = paged_attention(q, pk, pv, pt, lengths, interpret=True)
    pt_reclaimed = np.asarray(pt).copy()
    pt_reclaimed[0, 3:] = 0   # quarantine the unused tail
    pt_reclaimed[1, 2:] = 0
    out = paged_attention(q, pk, pv, jnp.asarray(pt_reclaimed), lengths,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


def test_paged_vs_dense_attention():
    """Paged read path must equal dense attention over the same tokens."""
    from repro.models import common as cm
    b, hq, hkv, d, pg, maxp = 2, 8, 4, 64, 4, 8
    rng = np.random.default_rng(3)
    s = maxp * pg
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)) * 0.5, jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, hq, d)) * 0.5, jnp.float32)
    lengths = jnp.asarray([s, s - 5], jnp.int32)

    # pack into a pool: page p of request r → physical 1 + r*maxp + p
    pool_k = jnp.zeros((1 + b * maxp, pg, hkv, d), jnp.float32)
    pool_v = jnp.zeros_like(pool_k)
    pool_k = pool_k.at[1:].set(
        k.reshape(b, maxp, pg, hkv, d).reshape(b * maxp, pg, hkv, d))
    pool_v = pool_v.at[1:].set(
        v.reshape(b, maxp, pg, hkv, d).reshape(b * maxp, pg, hkv, d))
    pt = jnp.arange(1, 1 + b * maxp, dtype=jnp.int32).reshape(b, maxp)

    out = paged_attention(q, pool_k, pool_v, pt, lengths, interpret=True)
    kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ref = cm.attention(q[:, None], k, v,
                       q_positions=lengths[:, None], kv_positions=kv_pos,
                       kv_valid=kv_pos < lengths[:, None], causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# prefix-shared attention: builder + two-phase kernel vs the stock oracle
# ---------------------------------------------------------------------------

from repro.kernels.paged_attention.ops import paged_attention_prefix_shared
from repro.kernels.paged_attention.prefix import (QUARANTINE_PAGE,
                                                 build_shared_runs,
                                                 prefix_shared_ref)


def _shared_setup(b=4, hq=4, hkv=2, d=32, pg=4, maxp=10, n_shared=3,
                  seed=0, ragged=True):
    """A CoW-shaped batch: every row starts with the same ``n_shared``
    published prefix pages, then owns a private tail."""
    rng = np.random.default_rng(seed)
    n_pages = b * maxp + n_shared + 1
    q = jnp.asarray(rng.normal(size=(b, hq, d)) * 0.5, jnp.float32)
    pk = jnp.asarray(rng.normal(size=(n_pages, pg, hkv, d)) * 0.5,
                     jnp.float32)
    pv = jnp.asarray(rng.normal(size=(n_pages, pg, hkv, d)) * 0.5,
                     jnp.float32)
    pt = np.zeros((b, maxp), np.int32)
    pt[:, :n_shared] = np.arange(1, n_shared + 1)
    for i in range(b):
        tail = maxp - n_shared
        pt[i, n_shared:] = np.arange(n_shared + 1 + i * tail,
                                     n_shared + 1 + (i + 1) * tail)
    if ragged:
        lengths = rng.integers(n_shared * pg + 1, maxp * pg + 1, size=b)
    else:
        lengths = np.full(b, maxp * pg)
    return q, pk, pv, pt, lengths.astype(np.int32)


@pytest.mark.parametrize('seed', [0, 1, 2])
def test_prefix_shared_ref_matches_stock_ref(seed):
    q, pk, pv, pt, lengths = _shared_setup(seed=seed)
    runs = build_shared_runs(pt, lengths, 4)
    assert runs['n_slots'] > 0
    out = prefix_shared_ref(q, pk, pv, jnp.asarray(runs['pages']),
                            jnp.asarray(runs['pos']),
                            jnp.asarray(runs['mask']),
                            jnp.asarray(runs['tail_pt']),
                            jnp.asarray(runs['start']),
                            jnp.asarray(lengths))
    ref = paged_attention_ref(q, pk, pv, jnp.asarray(pt),
                              jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_prefix_shared_pallas_matches_ref():
    q, pk, pv, pt, lengths = _shared_setup(seed=5)
    runs = build_shared_runs(pt, lengths, 4)
    args = (q, pk, pv, jnp.asarray(runs['pages']), jnp.asarray(runs['pos']),
            jnp.asarray(runs['mask']), jnp.asarray(runs['tail_pt']),
            jnp.asarray(runs['start']), jnp.asarray(lengths))
    out = paged_attention_prefix_shared(*args, backend='pallas',
                                        interpret=True)
    ref = paged_attention_ref(q, pk, pv, jnp.asarray(pt),
                              jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_shared_runs_zero_sharing_uses_stock_path():
    """Disjoint tables → no slots; the engine falls back to the stock walk."""
    q, pk, pv, pt, lengths = _shared_setup(n_shared=0, seed=2)
    runs = build_shared_runs(pt, lengths, 4)
    assert runs['n_slots'] == 0
    assert (runs['start'] == 0).all()
    np.testing.assert_array_equal(runs['tail_pt'], pt)


def test_shared_runs_partial_page_never_dedups():
    """Only *fully-filled* pages may dedup: a shared page still being
    written (length inside it) must stay in the per-row tail, where the
    length mask guards it."""
    q, pk, pv, pt, _ = _shared_setup(n_shared=3, seed=3)
    pg = 4
    lengths = np.full(pt.shape[0], 2 * pg + 1, np.int32)  # inside page 3
    runs = build_shared_runs(pt, lengths, pg)
    assert runs['n_slots'] == 2                     # pages 1-2 only
    assert (runs['start'] == 2).all()
    out = prefix_shared_ref(q, pk, pv, jnp.asarray(runs['pages']),
                            jnp.asarray(runs['pos']),
                            jnp.asarray(runs['mask']),
                            jnp.asarray(runs['tail_pt']),
                            jnp.asarray(runs['start']),
                            jnp.asarray(lengths))
    ref = paged_attention_ref(q, pk, pv, jnp.asarray(pt),
                              jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_shared_runs_quarantine_never_becomes_a_slot():
    """Quarantine (page 0) appears in every padded table — it must never
    dedup into a shared slot even though it trivially matches across rows."""
    pt = np.zeros((3, 6), np.int32)                 # all-quarantine tables
    lengths = np.full(3, 24, np.int32)
    runs = build_shared_runs(pt, lengths, 4)
    assert runs['n_slots'] == 0
    assert (runs['pages'] == QUARANTINE_PAGE).all()


def test_shared_runs_slot_overflow_clamps_soundly():
    """More distinct share groups than slots: the builder clamps runs at
    the first non-fitting index — overflowing pages stay in tails and the
    output still matches the oracle exactly."""
    q, pk, pv, pt, lengths = _shared_setup(b=4, maxp=10, n_shared=6, seed=4)
    runs = build_shared_runs(pt, lengths, 4, max_slots=3)
    assert 0 < runs['n_slots'] <= 3
    assert (runs['start'] <= 3).all()
    out = prefix_shared_ref(q, pk, pv, jnp.asarray(runs['pages']),
                            jnp.asarray(runs['pos']),
                            jnp.asarray(runs['mask']),
                            jnp.asarray(runs['tail_pt']),
                            jnp.asarray(runs['start']),
                            jnp.asarray(lengths))
    ref = paged_attention_ref(q, pk, pv, jnp.asarray(pt),
                              jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_shared_runs_closure_never_imports_foreign_pages():
    """The kernel-boundary sharing invariant: a slot exists ONLY for a page
    present at the same logical index in >= 2 of the batch's own tables.
    A page unique to one row — e.g. another session's unpublished lease
    that somehow landed in a hand-built table — can never be deduplicated,
    so prefix-shared attention can never be steered into reading unshared
    state wider than the stock kernel would."""
    rng = np.random.default_rng(9)
    for _ in range(50):
        b, maxp, pg = 4, 8, 4
        pt = rng.integers(1, 12, size=(b, maxp)).astype(np.int32)
        pt[rng.random((b, maxp)) < 0.2] = QUARANTINE_PAGE
        lengths = rng.integers(1, maxp * pg + 1, size=b).astype(np.int32)
        runs = build_shared_runs(pt, lengths, pg)
        n_full = lengths // pg
        for si in range(runs['n_slots']):
            p, j = int(runs['pages'][si]), int(runs['pos'][si])
            holders = [i for i in range(b)
                       if pt[i, j] == p and j < n_full[i]]
            assert len(holders) >= 2, (p, j, pt.tolist())
            # and participation is exactly the holders whose leading run
            # reaches this index (mask never includes a non-holder)
            members = np.nonzero(runs['mask'][:, si])[0].tolist()
            assert set(members) <= set(holders)
