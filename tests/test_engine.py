"""Engine integration: continuous batching, chunked prefill correctness,
Valve invalidation → recompute round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.memory import MemoryPlane
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.models.api import build_model
from repro.serving.engine import Engine, EngineConfig, ReqState
from repro.serving.kvpool import KVPool


def _setup(arch='internlm2-1.8b', *, pool_handles=8, pph=4, page=4,
           engine_cfg=None, runtime=False, seed=0):
    cfg = reduced(get_config(arch), page_size=page)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    pool = KVPool(pool_handles, pph, page_size=page, reserved_handles=1)
    clock = VirtualClock()
    rt = None
    if runtime:
        def cb(inv):
            eng.on_pages_invalidated(inv)
        rt = ValveRuntime(pool, RuntimeConfig(), clock=clock, on_invalidate=cb)
    ecfg = engine_cfg or EngineConfig(max_batch=4, max_seq=64,
                                      prefill_chunk=8)
    eng = Engine(model, params, pool, ecfg, runtime=rt, clock=clock)
    return eng, rt, pool, model, params


def test_generate_matches_unchunked_prefill():
    """Greedy generation via chunked prefill + paged decode must equal the
    model's own full-prefill + decode loop."""
    eng, _, pool, model, params = _setup()
    cfg = model.cfg
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=13).tolist()  # odd length
    rid = eng.submit(prompt, max_new_tokens=6)
    eng.run_to_completion()
    got = eng.output_tokens(rid)
    assert len(got) == 6

    # oracle: full prefill (page-aligned prompt slice) + decode loop on a
    # fresh region cache
    from repro.configs.base import ShapeConfig
    total = len(prompt) + 6
    region_tokens = ((total + cfg.page_size - 1) // cfg.page_size
                     ) * cfg.page_size
    shape = ShapeConfig('t', region_tokens, 1, 'prefill')
    cache = model.init_cache(shape)
    maxp = region_tokens // cfg.page_size
    pt = jnp.arange(1, maxp + 1, dtype=jnp.int32)[None]
    # token-granular prefill via the same chunk fn but one token at a time is
    # slow; instead decode the prompt token-by-token after a 1-token "prefill"
    toks = []
    logits = None
    ctx = list(prompt)
    # simple oracle: feed every token through decode_step sequentially
    for pos, tok in enumerate(ctx):
        db = {'tokens': jnp.asarray([tok], jnp.int32),
              'positions': jnp.asarray([pos], jnp.int32),
              'page_table': pt}
        cache, logits = jax.jit(model.decode_fn)(params, cache, db)
    for i in range(6):
        tok = int(jnp.argmax(logits, -1)[0])
        toks.append(tok)
        if i == 5:
            break
        db = {'tokens': jnp.asarray([tok], jnp.int32),
              'positions': jnp.asarray([len(prompt) + i], jnp.int32),
              'page_table': pt}
        cache, logits = jax.jit(model.decode_fn)(params, cache, db)
    assert got == toks, (got, toks)


def test_continuous_batching_two_requests():
    eng, _, pool, model, _ = _setup()
    cfg = model.cfg
    rng = np.random.default_rng(1)
    r1 = eng.submit(rng.integers(1, cfg.vocab_size, size=8).tolist(), 5)
    r2 = eng.submit(rng.integers(1, cfg.vocab_size, size=11).tolist(), 7)
    eng.run_to_completion()
    assert len(eng.output_tokens(r1)) == 5
    assert len(eng.output_tokens(r2)) == 7
    plane = MemoryPlane.of(pool)
    plane.check_invariants()
    assert plane.live_leases() == []            # every lease released
    # finished requests may leave zero-ref prefix pages in the retention
    # cache; dropping it must return the pool to exactly empty
    plane.drop_cache()
    assert pool.used_pages_for('offline') == 0  # all freed on finish


def test_invalidation_recompute_round_trip():
    """Reclaim mid-generation; the engine must recompute and the final output
    must be identical to an undisturbed run (greedy determinism)."""
    eng, _, pool, model, params = _setup(pool_handles=10)
    cfg = model.cfg
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, size=9).tolist()

    # undisturbed reference
    ref_rid = eng.submit(prompt, max_new_tokens=8)
    eng.run_to_completion()
    ref = eng.output_tokens(ref_rid)

    # fresh engine; interrupt after a few decode steps
    eng2, _, pool2, model2, _ = _setup(pool_handles=10, seed=0)
    rid = eng2.submit(prompt, max_new_tokens=8)
    for _ in range(20):
        eng2.step()
        req = eng2.requests[rid]
        if len(req.generated) >= 3:
            break
    # reclaim every handle that holds this request's pages (simulating the
    # runtime's compute-first reclamation; gates are a no-op here).  The
    # plane translates the raw page map into LeaseInvalidations — losing
    # every handle leaves no surviving prefix, the full-restart worst case
    handles = sorted({pool2.handle_of(p) for p in req.pages})
    inv = MemoryPlane.of(pool2).reclaim_handles(handles)
    assert rid in inv
    assert inv[rid].keep == 0 and inv[rid].resume == 0
    eng2.on_pages_invalidated(inv)
    assert eng2.requests[rid].state == ReqState.WAITING
    assert eng2.requests[rid].recomputes == 1
    kept = list(eng2.requests[rid].generated)
    eng2.run_to_completion()
    out = eng2.output_tokens(rid)
    assert out[: len(kept)] == kept          # kept tokens never regenerate
    assert out == ref, (out, ref)            # recompute is exact
    pool2.check_invariants()


def test_double_invalidation_no_duplicate_requeue():
    """Regression: a double invalidation callback must not enqueue the same
    request twice (the duplicate-requeue hazard in the Valve patch)."""
    eng, _, pool, model, _ = _setup(pool_handles=10)
    cfg = model.cfg
    rng = np.random.default_rng(6)
    rid = eng.submit(rng.integers(1, cfg.vocab_size, size=9).tolist(), 8)
    for _ in range(20):
        eng.step()
        if len(eng.requests[rid].generated) >= 2:
            break
    inv = MemoryPlane.of(pool).reclaim_handles(pool.handles_of_request(rid))
    assert rid in inv
    eng.on_pages_invalidated(inv)
    eng.on_pages_invalidated(inv)        # double delivery
    assert eng.queue.count(rid) == 1
    assert eng.requests[rid].state == ReqState.WAITING
    # the duplicate must not double-count stats either
    assert eng.stats.invalidations == 1
    assert eng.requests[rid].recomputes == 1
    assert eng.stats.tokens_recomputed == len(eng.requests[rid].context)
    eng.run_to_completion()
    assert len(eng.output_tokens(rid)) == 8
    pool.check_invariants()


def test_batched_prefill_composes_multiple_requests():
    """One dispatch prefills several waiting requests (the seed did one
    request at batch 1 per step)."""
    eng, _, pool, model, _ = _setup()
    cfg = model.cfg
    rng = np.random.default_rng(4)
    rids = [eng.submit(rng.integers(1, cfg.vocab_size, size=7).tolist(), 3)
            for _ in range(3)]
    assert eng.step() is True
    assert eng.stats.dispatches == 1
    assert eng.stats.prefill_chunks == 3         # three slots, one dispatch
    for rid in rids:
        req = eng.requests[rid]
        assert req.state == ReqState.RUNNING
        assert len(req.generated) == 1           # prefill emits first token
    # next step decodes the whole batch together
    eng.step()
    assert eng.stats.decode_iterations == 1
    assert all(len(eng.requests[r].generated) == 2 for r in rids)
    eng.run_to_completion()
    assert all(len(eng.output_tokens(r)) == 3 for r in rids)


def test_mixed_prefill_decode_single_iteration():
    """A late arrival prefills in the SAME iteration that decodes the
    running batch (piggybacked decode slots)."""
    eng, _, pool, model, _ = _setup()
    cfg = model.cfg
    rng = np.random.default_rng(5)
    r1 = eng.submit(rng.integers(1, cfg.vocab_size, size=7).tolist(), 6)
    eng.step()                                   # r1 prefilled → RUNNING
    r2 = eng.submit(rng.integers(1, cfg.vocab_size, size=7).tolist(), 6)
    mixed_before = eng.stats.mixed_dispatches
    dispatches_before = eng.stats.dispatches
    eng.step()
    assert eng.stats.dispatches == dispatches_before + 1
    assert eng.stats.mixed_dispatches == mixed_before + 1
    assert len(eng.requests[r1].generated) == 2  # decoded in the mix
    assert len(eng.requests[r2].generated) == 1  # prefilled in the mix


def test_batched_prefill_reduces_steps_and_matches_outputs():
    """Scheduler steps-to-completion drops vs the seed one-request-at-a-time
    path, with identical greedy outputs."""
    cfg_seed = EngineConfig(max_batch=4, max_seq=64, prefill_chunk=8,
                            max_prefill_reqs=1, piggyback_decode=False)
    cfg_batched = EngineConfig(max_batch=4, max_seq=64, prefill_chunk=8)
    outs, steps = [], []
    for ecfg in (cfg_seed, cfg_batched):
        eng, _, pool, model, _ = _setup(engine_cfg=ecfg)
        rng = np.random.default_rng(8)
        rids = [eng.submit(rng.integers(1, model.cfg.vocab_size,
                                        size=17).tolist(), 5)
                for _ in range(4)]
        eng.run_to_completion()
        outs.append([eng.output_tokens(r) for r in rids])
        steps.append(eng.stats.steps)
        pool.check_invariants()
    assert outs[0] == outs[1]                    # same greedy outputs
    assert steps[1] < steps[0], steps            # measurably fewer steps


def test_runtime_gating_blocks_offline():
    eng, rt, pool, model, _ = _setup(runtime=True)
    cfg = model.cfg
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(1, cfg.vocab_size, size=8).tolist(), 4)
    # an online request arrives → gates close → offline cannot dispatch
    rt.on_online_request_start('online-0')
    assert not rt.offline_may_dispatch()
    assert eng.step() is False
    assert eng.stats.blocked_dispatches == 1
    # online finishes; wake only after T_cool of continuous idle
    rt.on_online_request_end('online-0')
    rt.tick()
    assert not rt.offline_may_dispatch()     # still inside cooldown
    rt.clock.advance(rt.lifecycle.t_cool + 1e-3)
    rt.tick()
    assert rt.offline_may_dispatch()
    assert eng.step() is True
    rt.check_invariants()


def test_partial_invalidation_resumes_from_surviving_prefix():
    """Reclaiming only a TAIL handle mid-generation must resume prefill
    from the surviving prefix — same final output as an undisturbed run,
    but strictly fewer recomputed tokens than a full restart."""
    eng, _, pool, model, params = _setup(pool_handles=12, pph=2)
    cfg = model.cfg
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, cfg.vocab_size, size=9).tolist()

    ref_rid = eng.submit(prompt, max_new_tokens=8)
    eng.run_to_completion()
    ref = eng.output_tokens(ref_rid)

    eng2, _, pool2, _, _ = _setup(pool_handles=12, pph=2, seed=0)
    rid = eng2.submit(prompt, max_new_tokens=8)
    for _ in range(20):
        eng2.step()
        req = eng2.requests[rid]
        if len(req.generated) >= 3:
            break
    # hit ONLY the handle holding logical page 2 — pages 0-1 survive
    mid_handle = pool2.handle_of(req.pages[2])
    inv = MemoryPlane.of(pool2).reclaim_handles([mid_handle])
    assert inv[rid].keep == 2
    assert inv[rid].resume == 2 * pool2.page_size == 8
    eng2.on_pages_invalidated(inv)
    assert req.state == ReqState.WAITING
    assert req.n_prefilled == 8                  # resume point, not 0
    assert len(req.pages) == 2                   # surviving prefix kept
    full_restart = len(req.context)
    assert eng2.stats.tokens_recomputed == full_restart - 8 < full_restart
    kept = list(req.generated)
    eng2.run_to_completion()
    out = eng2.output_tokens(rid)
    assert out[: len(kept)] == kept
    assert out == ref, (out, ref)                # resume is exact
    MemoryPlane.of(pool2).check_invariants()


def test_prefix_sharing_identical_outputs_and_fewer_chunks():
    """A shared-prefix batch admitted in waves attaches the published
    prompt pages: greedy outputs are bit-identical to the sharing-off run
    while prefill work drops."""
    cfg = reduced(get_config('internlm2-1.8b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prefix = rng.integers(1, cfg.vocab_size, 12).tolist()   # 3 full pages
    tails = [rng.integers(1, cfg.vocab_size, 5).tolist() for _ in range(6)]

    def run(sharing):
        pool = KVPool(16, 4, page_size=4, reserved_handles=1)
        MemoryPlane(pool, sharing=sharing)
        eng = Engine(model, params, pool,
                     EngineConfig(max_batch=3, max_seq=32, prefill_chunk=8))
        rids = [eng.submit(prefix + t, max_new_tokens=5) for t in tails]
        eng.run_to_completion()
        plane = MemoryPlane.of(pool)
        plane.check_invariants()
        return ([eng.output_tokens(r) for r in rids],
                eng.stats.prefill_chunks, plane.stats.shared_pages_attached)

    out_off, chunks_off, shared_off = run(False)
    out_on, chunks_on, shared_on = run(True)
    assert shared_off == 0 and shared_on > 0
    assert out_on == out_off                     # shim-compat: bit-identical
    assert chunks_on < chunks_off                # prefill work actually saved


def test_failed_readmission_keeps_surviving_lease_for_spill():
    """Regression: a failed re-admission of a partial-invalidation victim
    must NOT clobber ``req.lease`` with None — the surviving lease is live
    in the plane, and the spill valve needs the handle to release it."""
    eng, _, pool, model, _ = _setup(pool_handles=6, pph=2)
    rng = np.random.default_rng(13)
    rid = eng.submit(rng.integers(1, model.cfg.vocab_size, 9).tolist(), 8)
    for _ in range(20):
        eng.step()
        if len(eng.requests[rid].generated) >= 2:
            break
    req = eng.requests[rid]
    inv = MemoryPlane.of(pool).reclaim_handles(
        [pool.handle_of(req.pages[2])])          # tail cut: lease survives
    eng.on_pages_invalidated(inv)
    lease = req.lease
    assert lease is not None and not lease.released
    # exhaust offline memory so the re-admission extension fails
    free = pool.free_pages_for('offline')
    if free:
        pool.alloc('hog', free, 'offline')
    assert eng._try_admit(req) is None
    assert req.lease is lease and not lease.released   # not clobbered
    # the spill valve can now actually free the survivors
    eng._spill(req)
    assert lease.released
    assert req.lease is None and req.pages == []


def test_second_hit_while_queued_charges_only_the_shrink():
    """A queued recompute victim hit by a SECOND reclamation shrinks its
    resume point; the recompute metric telescopes to exactly the full
    restart cost (duplicate deliveries still charge zero), and the request
    is never double-requeued."""
    eng, _, pool, model, _ = _setup(pool_handles=12, pph=2)
    rng = np.random.default_rng(21)
    rid = eng.submit(rng.integers(1, model.cfg.vocab_size, 9).tolist(), 8)
    for _ in range(20):
        eng.step()
        if len(eng.requests[rid].generated) >= 3:
            break
    req = eng.requests[rid]
    plane = MemoryPlane.of(pool)
    inv1 = plane.reclaim_handles([pool.handle_of(req.pages[2])])
    eng.on_pages_invalidated(inv1)
    ctx = len(req.context)
    assert req.n_prefilled == 8 and rid in eng.queue
    assert eng.stats.tokens_recomputed == ctx - 8
    # second burst hits the surviving prefix while the victim is queued
    inv2 = plane.reclaim_handles([pool.handle_of(req.pages[0])])
    eng.on_pages_invalidated(inv2)
    assert req.n_prefilled == 0
    assert eng.queue.count(rid) == 1          # still no duplicate requeue
    assert eng.stats.invalidations == 1       # counts requeue events
    assert eng.stats.tokens_recomputed == ctx # telescoped: full restart
    eng.run_to_completion()
    assert len(eng.output_tokens(rid)) == 8


def test_fused_sampling_and_shared_attention_drain_bit_identity():
    """The hot-path variants (fused unembed+sample with lazy on-device
    tokens; prefix-shared attention over CoW pages) must drain a staggered
    shared-prefix batch bit-identically to the stock path — the speed
    claims in BENCH_kernels.json only count with this test green."""
    cfg = reduced(get_config('internlm2-1.8b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab_size, 12).tolist()   # 3 full pages

    def run(fused, shared):
        pool = KVPool(16, 4, page_size=4, reserved_handles=1)
        MemoryPlane(pool, sharing=True)
        eng = Engine(model, params, pool,
                     EngineConfig(max_batch=3, max_seq=40, prefill_chunk=8,
                                  fused_sampling=fused,
                                  prefix_shared_attention=shared))
        rids = [eng.submit(prompt, max_new_tokens=8)]
        for _ in range(20):                  # publish r0's prefix first
            eng.step()
            if eng.requests[rids[0]].generated:
                break
        rids += [eng.submit(prompt, max_new_tokens=8) for _ in range(2)]
        eng.run_to_completion()
        return ([eng.output_tokens(r) for r in rids],
                eng.stats.token_flushes, eng.stats.shared_page_reads_saved)

    base, flushes0, saved0 = run(False, False)
    fused_out, flushes1, _ = run(True, False)
    both_out, _, saved2 = run(True, True)
    assert flushes0 == 0 and flushes1 > 0    # fused really ran lazily
    assert saved0 == 0 and saved2 > 0        # sharing really deduplicated
    assert fused_out == base
    assert both_out == base


def test_shared_attention_only_engages_after_publication():
    """Closure regression: identical prompts admitted in the SAME wave
    have no published prefix to attach, so the prefix-shared read path
    must find zero duplicate pages — sharing can only ever flow through
    the plane's fill-gated publication, never through coincidence."""
    cfg = reduced(get_config('internlm2-1.8b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(8)
    prompt = rng.integers(1, cfg.vocab_size, 12).tolist()

    def run(shared):
        pool = KVPool(16, 4, page_size=4, reserved_handles=1)
        MemoryPlane(pool, sharing=True)
        eng = Engine(model, params, pool,
                     EngineConfig(max_batch=3, max_seq=40, prefill_chunk=8,
                                  prefix_shared_attention=shared))
        rids = [eng.submit(prompt, max_new_tokens=6) for _ in range(3)]
        eng.run_to_completion()
        plane = MemoryPlane.of(pool)
        return ([eng.output_tokens(r) for r in rids],
                eng.stats.shared_page_reads_saved,
                plane.stats.shared_pages_attached)

    out_on, saved, attached = run(True)
    out_off, _, _ = run(False)
    assert attached == 0                 # same-wave: nothing published yet
    assert saved == 0                    # so the kernel saw no shared runs
    assert out_on == out_off
