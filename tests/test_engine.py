"""Engine integration: continuous batching, chunked prefill correctness,
Valve invalidation → recompute round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.models.api import build_model
from repro.serving.engine import Engine, EngineConfig, ReqState
from repro.serving.kvpool import KVPool


def _setup(arch='internlm2-1.8b', *, pool_handles=8, pph=4, page=4,
           engine_cfg=None, runtime=False, seed=0):
    cfg = reduced(get_config(arch), page_size=page)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    pool = KVPool(pool_handles, pph, page_size=page, reserved_handles=1)
    clock = VirtualClock()
    rt = None
    if runtime:
        def cb(inv):
            eng.on_pages_invalidated(inv)
        rt = ValveRuntime(pool, RuntimeConfig(), clock=clock, on_invalidate=cb)
    ecfg = engine_cfg or EngineConfig(max_batch=4, max_seq=64,
                                      prefill_chunk=8)
    eng = Engine(model, params, pool, ecfg, runtime=rt, clock=clock)
    return eng, rt, pool, model, params


def test_generate_matches_unchunked_prefill():
    """Greedy generation via chunked prefill + paged decode must equal the
    model's own full-prefill + decode loop."""
    eng, _, pool, model, params = _setup()
    cfg = model.cfg
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=13).tolist()  # odd length
    rid = eng.submit(prompt, max_new_tokens=6)
    eng.run_to_completion()
    got = eng.output_tokens(rid)
    assert len(got) == 6

    # oracle: full prefill (page-aligned prompt slice) + decode loop on a
    # fresh region cache
    from repro.configs.base import ShapeConfig
    total = len(prompt) + 6
    region_tokens = ((total + cfg.page_size - 1) // cfg.page_size
                     ) * cfg.page_size
    shape = ShapeConfig('t', region_tokens, 1, 'prefill')
    cache = model.init_cache(shape)
    maxp = region_tokens // cfg.page_size
    pt = jnp.arange(1, maxp + 1, dtype=jnp.int32)[None]
    # token-granular prefill via the same chunk fn but one token at a time is
    # slow; instead decode the prompt token-by-token after a 1-token "prefill"
    toks = []
    logits = None
    ctx = list(prompt)
    # simple oracle: feed every token through decode_step sequentially
    for pos, tok in enumerate(ctx):
        db = {'tokens': jnp.asarray([tok], jnp.int32),
              'positions': jnp.asarray([pos], jnp.int32),
              'page_table': pt}
        cache, logits = jax.jit(model.decode_fn)(params, cache, db)
    for i in range(6):
        tok = int(jnp.argmax(logits, -1)[0])
        toks.append(tok)
        if i == 5:
            break
        db = {'tokens': jnp.asarray([tok], jnp.int32),
              'positions': jnp.asarray([len(prompt) + i], jnp.int32),
              'page_table': pt}
        cache, logits = jax.jit(model.decode_fn)(params, cache, db)
    assert got == toks, (got, toks)


def test_continuous_batching_two_requests():
    eng, _, pool, model, _ = _setup()
    cfg = model.cfg
    rng = np.random.default_rng(1)
    r1 = eng.submit(rng.integers(1, cfg.vocab_size, size=8).tolist(), 5)
    r2 = eng.submit(rng.integers(1, cfg.vocab_size, size=11).tolist(), 7)
    eng.run_to_completion()
    assert len(eng.output_tokens(r1)) == 5
    assert len(eng.output_tokens(r2)) == 7
    pool.check_invariants()
    assert pool.used_pages_for('offline') == 0  # all freed on finish


def test_invalidation_recompute_round_trip():
    """Reclaim mid-generation; the engine must recompute and the final output
    must be identical to an undisturbed run (greedy determinism)."""
    eng, _, pool, model, params = _setup(pool_handles=10)
    cfg = model.cfg
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, size=9).tolist()

    # undisturbed reference
    ref_rid = eng.submit(prompt, max_new_tokens=8)
    eng.run_to_completion()
    ref = eng.output_tokens(ref_rid)

    # fresh engine; interrupt after a few decode steps
    eng2, _, pool2, model2, _ = _setup(pool_handles=10, seed=0)
    rid = eng2.submit(prompt, max_new_tokens=8)
    for _ in range(20):
        eng2.step()
        req = eng2.requests[rid]
        if len(req.generated) >= 3:
            break
    # reclaim every handle that holds this request's pages (simulating the
    # runtime's compute-first reclamation; gates are a no-op here)
    handles = sorted({pool2.handle_of(p) for p in req.pages})
    inv = pool2.reclaim_handles(handles)
    assert rid in inv
    eng2.on_pages_invalidated(inv)
    assert eng2.requests[rid].state == ReqState.WAITING
    assert eng2.requests[rid].recomputes == 1
    kept = list(eng2.requests[rid].generated)
    eng2.run_to_completion()
    out = eng2.output_tokens(rid)
    assert out[: len(kept)] == kept          # kept tokens never regenerate
    assert out == ref, (out, ref)            # recompute is exact
    pool2.check_invariants()


def test_double_invalidation_no_duplicate_requeue():
    """Regression: a double invalidation callback must not enqueue the same
    request twice (the duplicate-requeue hazard in the Valve patch)."""
    eng, _, pool, model, _ = _setup(pool_handles=10)
    cfg = model.cfg
    rng = np.random.default_rng(6)
    rid = eng.submit(rng.integers(1, cfg.vocab_size, size=9).tolist(), 8)
    for _ in range(20):
        eng.step()
        if len(eng.requests[rid].generated) >= 2:
            break
    inv = pool.reclaim_handles(pool.handles_of_request(rid))
    assert rid in inv
    eng.on_pages_invalidated(inv)
    eng.on_pages_invalidated(inv)        # double delivery
    assert eng.queue.count(rid) == 1
    assert eng.requests[rid].state == ReqState.WAITING
    # the duplicate must not double-count stats either
    assert eng.stats.invalidations == 1
    assert eng.requests[rid].recomputes == 1
    assert eng.stats.tokens_recomputed == len(eng.requests[rid].context)
    eng.run_to_completion()
    assert len(eng.output_tokens(rid)) == 8
    pool.check_invariants()


def test_batched_prefill_composes_multiple_requests():
    """One dispatch prefills several waiting requests (the seed did one
    request at batch 1 per step)."""
    eng, _, pool, model, _ = _setup()
    cfg = model.cfg
    rng = np.random.default_rng(4)
    rids = [eng.submit(rng.integers(1, cfg.vocab_size, size=7).tolist(), 3)
            for _ in range(3)]
    assert eng.step() is True
    assert eng.stats.dispatches == 1
    assert eng.stats.prefill_chunks == 3         # three slots, one dispatch
    for rid in rids:
        req = eng.requests[rid]
        assert req.state == ReqState.RUNNING
        assert len(req.generated) == 1           # prefill emits first token
    # next step decodes the whole batch together
    eng.step()
    assert eng.stats.decode_iterations == 1
    assert all(len(eng.requests[r].generated) == 2 for r in rids)
    eng.run_to_completion()
    assert all(len(eng.output_tokens(r)) == 3 for r in rids)


def test_mixed_prefill_decode_single_iteration():
    """A late arrival prefills in the SAME iteration that decodes the
    running batch (piggybacked decode slots)."""
    eng, _, pool, model, _ = _setup()
    cfg = model.cfg
    rng = np.random.default_rng(5)
    r1 = eng.submit(rng.integers(1, cfg.vocab_size, size=7).tolist(), 6)
    eng.step()                                   # r1 prefilled → RUNNING
    r2 = eng.submit(rng.integers(1, cfg.vocab_size, size=7).tolist(), 6)
    mixed_before = eng.stats.mixed_dispatches
    dispatches_before = eng.stats.dispatches
    eng.step()
    assert eng.stats.dispatches == dispatches_before + 1
    assert eng.stats.mixed_dispatches == mixed_before + 1
    assert len(eng.requests[r1].generated) == 2  # decoded in the mix
    assert len(eng.requests[r2].generated) == 1  # prefilled in the mix


def test_batched_prefill_reduces_steps_and_matches_outputs():
    """Scheduler steps-to-completion drops vs the seed one-request-at-a-time
    path, with identical greedy outputs."""
    cfg_seed = EngineConfig(max_batch=4, max_seq=64, prefill_chunk=8,
                            max_prefill_reqs=1, piggyback_decode=False)
    cfg_batched = EngineConfig(max_batch=4, max_seq=64, prefill_chunk=8)
    outs, steps = [], []
    for ecfg in (cfg_seed, cfg_batched):
        eng, _, pool, model, _ = _setup(engine_cfg=ecfg)
        rng = np.random.default_rng(8)
        rids = [eng.submit(rng.integers(1, model.cfg.vocab_size,
                                        size=17).tolist(), 5)
                for _ in range(4)]
        eng.run_to_completion()
        outs.append([eng.output_tokens(r) for r in rids])
        steps.append(eng.stats.steps)
        pool.check_invariants()
    assert outs[0] == outs[1]                    # same greedy outputs
    assert steps[1] < steps[0], steps            # measurably fewer steps


def test_runtime_gating_blocks_offline():
    eng, rt, pool, model, _ = _setup(runtime=True)
    cfg = model.cfg
    rng = np.random.default_rng(3)
    eng.submit(rng.integers(1, cfg.vocab_size, size=8).tolist(), 4)
    # an online request arrives → gates close → offline cannot dispatch
    rt.on_online_request_start('online-0')
    assert not rt.offline_may_dispatch()
    assert eng.step() is False
    assert eng.stats.blocked_dispatches == 1
    # online finishes; wake only after T_cool of continuous idle
    rt.on_online_request_end('online-0')
    rt.tick()
    assert not rt.offline_may_dispatch()     # still inside cooldown
    rt.clock.advance(rt.lifecycle.t_cool + 1e-3)
    rt.tick()
    assert rt.offline_may_dispatch()
    assert eng.step() is True
    rt.check_invariants()
