"""Kernels wired into the model paths: the Pallas prefill path must agree
with the jnp oracle path end-to-end through a real model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.models import dense
from repro.models.api import build_model


def test_prefill_pallas_matches_oracle_path():
    cfg = reduced(get_config('internlm2-1.8b'), page_size=8, head_dim=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    # f32 end-to-end: the two paths are mathematically identical and must
    # agree tightly (bf16 params would only test accumulated rounding)
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = np.random.default_rng(1)
    b, s = 2, 64
    shape = ShapeConfig('p', s, b, 'prefill')
    batch = model.make_inputs('prefill', b, s, rng)

    cache0 = model.init_cache(shape)
    cache1 = model.init_cache(shape)
    c_ref, logits_ref = jax.jit(
        lambda p, c, bt: dense.prefill(cfg, p, c, bt))(params, cache0, batch)
    c_pal, logits_pal = jax.jit(
        lambda p, c, bt: dense.prefill(cfg, p, c, bt, use_pallas=True))(
        params, cache1, batch)

    np.testing.assert_allclose(np.asarray(logits_pal, np.float32),
                               np.asarray(logits_ref, np.float32),
                               rtol=2e-4, atol=2e-4)
    # KV written to the pool agrees to bf16 rounding (the pool is bf16;
    # different fusions may round the f32→bf16 cast 1 ulp apart)
    for a, b_ in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_pal)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=1e-2, atol=1e-2)


def test_decode_pallas_matches_oracle_path():
    """The decode-specialized paged kernel path must agree with the
    full-gather oracle through a real model on the engine's global-pool
    layout (one new token per request, scattered pages, quarantine tail)."""
    cfg = reduced(get_config('internlm2-1.8b'), page_size=4, head_dim=32)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = np.random.default_rng(5)
    b, n_pages, maxp = 2, 17, 6
    cache = model.init_cache(None, engine_pages=n_pages)
    # f32 pool: the oracle rounds attention probs to the pool dtype before
    # the PV matmul while the kernel accumulates f32 throughout, so a bf16
    # pool would only test that rounding gap, not the paths
    cache = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape) * 0.5, jnp.float32),
        cache)
    # scattered physical pages, unused tail quarantined (page 0)
    pt = np.zeros((b, maxp), np.int32)
    pt[0, :4] = [3, 9, 1, 12]
    pt[1, :5] = [7, 2, 15, 4, 10]
    positions = np.asarray([4 * 4 - 2, 5 * 4 - 1], np.int32)  # mid/last page
    batch = {'tokens': jnp.asarray(rng.integers(0, cfg.vocab_size, size=b),
                                   jnp.int32),
             'positions': jnp.asarray(positions),
             'page_table': jnp.asarray(pt)}

    c_ref, logits_ref = jax.jit(
        lambda p, c, bt: dense.decode_step(cfg, p, c, bt))(
        params, cache, batch)
    c_pal, logits_pal = jax.jit(
        lambda p, c, bt: dense.decode_step(cfg, p, c, bt, use_pallas=True))(
        params, cache, batch)
    np.testing.assert_allclose(np.asarray(logits_pal, np.float32),
                               np.asarray(logits_ref, np.float32),
                               rtol=2e-4, atol=2e-4)
    # the KV written for the new token is identical on both paths
    for a, b_ in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_pal)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=1e-2, atol=1e-2)


def test_engine_decode_kernel_matches_oracle_engine():
    """Greedy generation must be identical with the engine's decode
    dispatched through the Pallas kernel vs the oracle path."""
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.kvpool import KVPool

    cfg = reduced(get_config('qwen3-0.6b'), page_size=4, head_dim=16)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(3))
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, cfg.vocab_size, size=9).tolist()

    outs = {}
    for use_kernel in (False, True):
        pool = KVPool(8, 4, page_size=4, reserved_handles=1)
        eng = Engine(model, params, pool,
                     EngineConfig(max_batch=2, max_seq=32, prefill_chunk=8,
                                  decode_kernel=use_kernel))
        rid = eng.submit(prompt, max_new_tokens=5)
        eng.run_to_completion()
        outs[use_kernel] = eng.output_tokens(rid)
    assert outs[True] == outs[False], outs


def test_rwkv6_kernel_path_matches_oracle_path():
    cfg = reduced(get_config('rwkv6-3b'))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    batch = model.make_inputs('train', 2, 64)
    from repro.models import rwkv6
    # remat=False: jax.checkpoint around an interpret-mode pallas_call hits
    # a lowering-cache KeyError in jax 0.8 (kernel autodiff uses a custom
    # bwd kernel on hardware anyway)
    loss_ref, _ = jax.jit(
        lambda p, bt: rwkv6.forward_train(cfg, p, bt, use_kernel=False,
                                          remat=False))(params, batch)
    loss_k, _ = jax.jit(
        lambda p, bt: rwkv6.forward_train(cfg, p, bt, use_kernel=True,
                                          remat=False))(params, batch)
    np.testing.assert_allclose(float(loss_k), float(loss_ref),
                               rtol=1e-3, atol=1e-3)
