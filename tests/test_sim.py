"""Colocation-simulator invariants (the §7.2 reproduction substrate)."""
import numpy as np
import pytest

from repro.core.sim.colocation import (NodeSim, OfflineReq, SimConfig,
                                       run_offline_standalone,
                                       run_online_standalone, run_strategy)
from repro.core.sim.strategies import Channel, OurMem, Prism
from repro.core.sim.workload import (OfflineWorkload, OnlineWorkload,
                                     WorkloadPair, make_workload_pairs)

CFG = SimConfig()
PAIRS = make_workload_pairs(4, horizon_s=120.0)


def test_every_online_request_completes():
    for pair in PAIRS[:2]:
        r = run_strategy(pair, 'Channel', 'OurMem', CFG)
        assert set(r.ttft) == {q.req_id for q in pair.online.requests}


def test_valve_at_most_one_preemption_per_request():
    for pair in PAIRS[:2]:
        r = run_strategy(pair, 'Channel', 'OurMem', CFG)
        assert r.max_preempt_per_request <= 1


def test_baselines_preempt_frequently():
    r = run_strategy(PAIRS[0], 'GPreempt', 'UVM', CFG)
    assert r.max_preempt_per_request > 1


def test_valve_interference_below_paper_bounds():
    """Aggregate across pairs: <5% TTFT and <2% TPOT increase."""
    tt_all, tp_all = [], []
    for pair in PAIRS:
        base = run_online_standalone(pair, CFG)
        r = run_strategy(pair, 'Channel', 'OurMem', CFG)
        tt_all += [(r.ttft[k] - base.ttft[k]) / max(base.ttft[k], 1e-9)
                   for k in base.ttft]
        tp_all += [(r.tpot[k] - base.tpot[k]) / max(base.tpot[k], 1e-9)
                   for k in base.tpot]
    assert np.mean(tt_all) * 100 < 5.0
    assert np.mean(tp_all) * 100 < 2.0


def test_valve_never_kills_offline_requests():
    r = run_strategy(PAIRS[0], 'Channel', 'OurMem', CFG)
    assert r.mem_stats.offline_kills == 0
    assert r.offline_tokens_wasted == 0


def test_uvm_kills_offline_on_memory_bursts():
    r = run_strategy(PAIRS[0], 'Channel', 'UVM', CFG)   # memory-bursty pair
    assert r.mem_stats.offline_kills > 0


def test_offline_standalone_upper_bounds_colocated():
    pair = PAIRS[1]
    solo = run_offline_standalone(pair, CFG)
    for cpn, mpn in (('Channel', 'OurMem'), ('Channel', 'Prism')):
        r = run_strategy(pair, cpn, mpn, CFG)
        assert r.offline_throughput <= solo.offline_throughput * 1.001


def test_valve_eviction_recompute_not_worse_than_fifo():
    pair = PAIRS[0]
    rv = run_strategy(pair, 'Channel', 'OurMem', CFG, eviction_policy='valve')
    rf = run_strategy(pair, 'Channel', 'OurMem', CFG, eviction_policy='fifo')
    assert rv.recompute_tokens <= rf.recompute_tokens * 1.05


def test_ourmem_pool_invariants_after_run():
    pair = PAIRS[0]
    mp = OurMem(CFG.total_pages, CFG.page_tokens)
    NodeSim(pair, Channel(), mp, CFG).run()
    mp.pool.check_invariants()
    assert mp.reclaimer.stats.ordering_violations == 0


def _bare_sim(cfg=None):
    cfg = cfg or CFG
    pair = WorkloadPair('bare', OnlineWorkload('empty', [], 10.0),
                        OfflineWorkload('off'))
    return NodeSim(pair, Channel(), Prism(cfg.total_pages, cfg.page_tokens),
                   cfg)


def test_off_preempt_context_save_rounds_up():
    """A context-saved prefill that is 99.9% done must keep ≥1 token of
    remaining work — the dispatch did NOT complete.  Regression: int()
    truncation credited offline with a free prefill on resume."""
    sim = _bare_sim()
    r = OfflineReq('off-0', prefill_tokens=1000, out_remaining=10, pages=4)
    sim.off_pending.append(r)
    dur = 1000 * sim.cfg.t_prefill_per_token
    sim.off_inflight = ('prefill', 0.0, [r])
    sim.off_busy_until = dur
    sim._off_preempt(0.9995 * dur)          # preempt just before completion
    assert r.prefill_tokens >= 1            # pre-fix: int(1000*0.0005) == 0


def test_off_preempt_halfway_rounds_up_not_down():
    sim = _bare_sim()
    r = OfflineReq('off-0', prefill_tokens=101, out_remaining=10, pages=4)
    sim.off_pending.append(r)
    dur = 101 * sim.cfg.t_prefill_per_token
    sim.off_inflight = ('prefill', 0.0, [r])
    sim.off_busy_until = dur
    sim._off_preempt(0.5 * dur)             # 50.5 tokens remain
    assert r.prefill_tokens == 51           # ceil, not trunc


def test_sim_records_busy_intervals_and_mem_trace():
    pair = PAIRS[0]
    r = run_strategy(pair, 'Channel', 'OurMem', CFG)
    assert r.busy_intervals
    assert all(b > a >= 0.0 for a, b in r.busy_intervals)
    # intervals are disjoint and sorted (coalescing keeps them canonical)
    for (a1, b1), (a2, b2) in zip(r.busy_intervals, r.busy_intervals[1:]):
        assert a2 > b1
    assert 0.0 < r.online_busy_fraction() < 1.0
    assert len(r.mem_trace_t) == len(r.mem_trace_free) >= 2
    assert all(t1 > t0 for t0, t1 in zip(r.mem_trace_t, r.mem_trace_t[1:]))
    assert max(r.mem_trace_free) <= CFG.total_pages


def test_oversized_online_request_rejected_not_livelocked():
    """A request whose KV need exceeds the whole pool can never be
    admitted; it must be rejected (max-context error) — pre-fix it blocked
    the head of the queue and the sim spun to the watchdog guard."""
    from repro.core.sim.workload import OnlineRequest
    cfg = SimConfig(total_pages=64)                  # 1024-token pool
    reqs = [OnlineRequest('huge', 0.5, 4096, 8),     # > pool, impossible
            OnlineRequest('ok', 1.0, 256, 8)]
    pair = WorkloadPair('rej', OnlineWorkload('on', reqs, 5.0),
                        OfflineWorkload('off'))
    r = NodeSim(pair, Channel(), Prism(cfg.total_pages, cfg.page_tokens),
                cfg).run()
    assert r.rejected == ['huge']
    assert 'ok' in r.ttft                            # queue kept moving


def test_watchdog_thresholds_come_from_config():
    """The sim watchdogs (guard / stall / forced step) are SimConfig fields
    so long-horizon workloads can tune them instead of tripping asserts."""
    # a tiny guard must trip on a workload that needs more loop iterations
    tight = SimConfig(watchdog_guard_steps=5)
    with pytest.raises(AssertionError, match='did not terminate'):
        run_strategy(PAIRS[0], 'Channel', 'OurMem', tight)
    # a raised guard runs the same pair to completion
    roomy = SimConfig(watchdog_guard_steps=100_000_000)
    r = run_strategy(PAIRS[0], 'Channel', 'OurMem', roomy)
    assert set(r.ttft) == {q.req_id for q in PAIRS[0].online.requests}
