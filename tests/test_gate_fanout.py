"""Gate fan-out across N devices (paper §4.1's 1-line driver change).

Under a VirtualClock the modeled flip latencies are deterministic, so the
paper's serial-vs-fanout scaling claim becomes an exact property:
fanout group latency == max over devices, serial == Σ — and the measured
per-device latencies folded into each PreemptionEvent let the §4.2 bound
(≤ 1 compute preemption per online request) be checked *per device* from
the event log alone.
"""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.events import PreemptionEvent
from repro.core.gate import DeviceGate, GateGroup
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.launch.node import NodeOrchestrator
from repro.serving.engine import EngineConfig
from repro.serving.kvpool import KVPool

ARCH = 'qwen3-0.6b'
N_DEV = 4


def _gates(latencies, clock):
    return [DeviceGate(i, lat, clock=clock)
            for i, lat in enumerate(latencies)]


# ---------------------------------------------------------------------------
# GateGroup latency model (virtual clock: exact, deterministic)
# ---------------------------------------------------------------------------
def test_fanout_latency_is_max_over_devices():
    clock = VirtualClock()
    lats = [0.001 * (i + 1) for i in range(N_DEV)]       # 1..4 ms
    grp = GateGroup(_gates(lats, clock), mode='fanout', clock=clock)
    elapsed = grp.disable_all()
    assert elapsed == pytest.approx(max(lats))
    # each device records ITS OWN modeled flip latency, not the group max
    assert grp.last_flip_latencies == pytest.approx(tuple(lats))
    assert grp.all_disabled
    elapsed = grp.enable_all()
    assert elapsed == pytest.approx(max(lats))
    assert grp.last_flip_latencies == pytest.approx(tuple(lats))


def test_serial_latency_is_sum_over_devices():
    clock = VirtualClock()
    lats = [0.001 * (i + 1) for i in range(N_DEV)]
    grp = GateGroup(_gates(lats, clock), mode='serial', clock=clock)
    elapsed = grp.disable_all()
    assert elapsed == pytest.approx(sum(lats))
    assert grp.last_flip_latencies == pytest.approx(tuple(lats))
    assert grp.all_disabled


def test_fanout_vs_serial_scaling():
    """The paper's >5 ms → <1 ms multi-GPU claim in model form: serial
    grows linearly with device count, fanout stays flat."""
    per_dev = 0.0008
    for n in (1, 2, 4, 8):
        cs, cf = VirtualClock(), VirtualClock()
        serial = GateGroup(_gates([per_dev] * n, cs), mode='serial',
                           clock=cs).disable_all()
        fanout = GateGroup(_gates([per_dev] * n, cf), mode='fanout',
                           clock=cf).disable_all()
        assert serial == pytest.approx(n * per_dev)
        assert fanout == pytest.approx(per_dev)


def test_real_clock_fanout_measures_per_device():
    """Real-clock fanout issues concurrent flips; each worker returns a
    measured wall-time ≥ 0 (exact values are noise, the shape is not)."""
    grp = GateGroup([DeviceGate(i) for i in range(N_DEV)], mode='fanout')
    try:
        grp.disable_all()
        assert len(grp.last_flip_latencies) == N_DEV
        assert all(t >= 0.0 for t in grp.last_flip_latencies)
        assert grp.all_disabled
    finally:
        grp.close()


# ---------------------------------------------------------------------------
# Runtime fold: PreemptionEvent carries per-device measured latencies
# ---------------------------------------------------------------------------
def _burst_node(n_devices):
    pool = KVPool(5, 4, page_size=4, reserved_handles=1)
    rt = ValveRuntime(
        pool, RuntimeConfig(n_devices=n_devices, t_cool_init=0.002,
                            gate_op_latency_s=0.0005),
        clock=VirtualClock())
    node = NodeOrchestrator(rt, idle_advance=1e-3)
    ecfg = EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8)
    cfg = reduced(get_config(ARCH), page_size=4)
    node.add_engine(cfg, EngineConfig(max_batch=4, max_seq=48,
                                      prefill_chunk=8, klass='online'),
                    seed=0, name='online')
    node.add_engine(cfg, ecfg, seed=1, name='off0')
    return node


def test_preemption_event_folds_device_latencies():
    node = _burst_node(N_DEV)
    rng = np.random.default_rng(7)
    eng = node.offline[0]
    for _ in range(2):
        eng.submit(rng.integers(1, eng.mcfg.vocab_size, 12).tolist(),
                   max_new_tokens=8)
    for _ in range(4):
        node.step()
    node.online.submit(
        rng.integers(1, node.online.mcfg.vocab_size, 28).tolist(),
        max_new_tokens=12)
    node.drain(max_steps=5000)

    evs = node.runtime.bus.events(PreemptionEvent)
    assert evs, 'burst produced no preemption'
    for ev in evs:
        # one measured flip latency per mesh device, fanout == max
        assert len(ev.device_latencies_s) == N_DEV
        assert ev.latency_s == pytest.approx(max(ev.device_latencies_s))
        assert all(t == pytest.approx(0.0005) for t in ev.device_latencies_s)

    # §4.2 per-DEVICE bound folded from the log: gates flip as a group, so
    # device d preempts request r once per PreemptionEvent listing r —
    # the bound must hold for every (request, device) pair and node-wide
    per_dev_req = {}
    for ev in evs:
        for rid in ev.requests:
            for d in range(len(ev.device_latencies_s)):
                k = (rid, d)
                per_dev_req[k] = per_dev_req.get(k, 0) + 1
    assert per_dev_req and max(per_dev_req.values()) <= 1
    node.runtime.check_invariants()       # node-wide ≤1 + wakeup parity


def test_runtime_gate_count_follows_mesh(make_virtual_mesh):
    """RuntimeConfig.mesh overrides n_devices: one DeviceGate per mesh
    device, so the fan-out is the real flip across the serving mesh."""
    mesh = make_virtual_mesh((4,), ('model',))
    pool = KVPool(4, 4, page_size=4)
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, mesh=mesh),
                      clock=VirtualClock())
    assert rt.n_devices == 4
    assert len(rt.gates.gates) == 4
