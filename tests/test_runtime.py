"""ValveRuntime invariants: compute-first ordering, at-most-one preemption
per online request, T_cool wake gating, reservation maintenance."""
import pytest

from repro.core.clock import VirtualClock
from repro.core.miad import MIADConfig
from repro.core.reclamation import ReclamationController
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.serving.kvpool import KVPool


def _rt(n_handles=8, pph=4, **kw):
    pool = KVPool(n_handles, pph, reserved_handles=1)
    clock = VirtualClock()
    rt = ValveRuntime(pool, RuntimeConfig(**kw), clock=clock)
    return rt, pool, clock


def test_ordering_violation_raises():
    pool = KVPool(4, 4, reserved_handles=1)
    rc = ReclamationController(pool, gate_is_closed=lambda: False)
    pool.alloc('off', 4, 'offline')
    with pytest.raises(RuntimeError):
        rc.reclaim(1, now=0.0)
    assert rc.stats.ordering_violations == 1


def test_reclaim_requires_gates_closed_and_runtime_closes_them():
    rt, pool, clock = _rt()
    pool.alloc('off-1', 10, 'offline')
    assert rt.offline_may_dispatch()
    got = rt.alloc_online('on-1', 8)      # 8 > 1 reserved handle of 4 pages
    assert got is not None
    assert rt.reclaimer.stats.reclamations == 1
    assert rt.reclaimer.stats.ordering_violations == 0
    rt.check_invariants()


def test_at_most_one_preemption_per_request():
    rt, pool, clock = _rt()
    pool.alloc('off', 4, 'offline')
    for i in range(5):
        rid = f'on-{i}'
        rt.on_online_request_start(rid)
        for _ in range(3):
            rt.on_online_iteration_start()
            clock.advance(0.03)
            rt.on_online_iteration_end()
            clock.advance(0.002)        # decode gap — offline must NOT wake
            rt.tick()
            assert not rt.offline_may_dispatch()
        rt.on_online_request_end(rid)
        clock.advance(rt.lifecycle.t_cool + 1e-3)
        rt.tick()                        # wake after cooldown
        assert rt.offline_may_dispatch()
    rt.check_invariants()                # asserts ≤1 preemption per request
    assert rt.stats.compute_preemptions == 5
    assert rt.stats.offline_wakeups == 5


def test_overlapping_requests_single_preemption():
    rt, pool, clock = _rt()
    rt.on_online_request_start('a')      # preempts offline (gates open)
    rt.on_online_request_start('b')      # gates already closed: no preempt
    clock.advance(0.1)
    rt.on_online_request_end('a')
    rt.on_online_request_end('b')
    assert rt.stats.compute_preemptions == 1
    rt.check_invariants()


def test_memory_pressure_mid_request_does_not_double_preempt():
    rt, pool, clock = _rt()
    pool.alloc('off', 16, 'offline')
    rt.on_online_request_start('a')      # preemption #1
    # memory pressure while gates already closed → reclaim without preempt
    rt.alloc_online('a', 12)
    assert rt.stats.compute_preemptions == 1
    assert rt.reclaimer.stats.reclamations >= 1
    rt.check_invariants()


def test_miad_reservation_grows_and_shrinks():
    # long T so the growth phase isn't immediately released
    rt, pool, clock = _rt(miad=MIADConfig(alpha=2.0, t_init=100.0,
                                          t_min=1.0, t_step=10.0,
                                          target_rate=10.0))
    # online fills the reservation → pressure → H grows
    rt.alloc_online('a', 4)
    for _ in range(4):
        clock.advance(0.3)
        rt.tick()
    assert len(pool.reserved) > 1
    # release: free the online pages, let T decay and MIAD shrink
    rt.free_online('a')
    for _ in range(200):
        clock.advance(1.0)
        rt.tick()
    assert len(pool.reserved) == 1
    rt.check_invariants()


def test_virtual_clock_gate_latencies_deterministic():
    """Sim-driven runtimes must record MODELED gate-flip latencies, not
    wall-clock noise: fanout = max op latency, serial = sum, bit-identical
    across runs (the clock-domain bug this pins: gates used to stamp
    time.monotonic()/time.sleep even under a VirtualClock)."""
    def latencies(mode):
        rt, pool, clock = _rt(n_devices=4, gate_mode=mode,
                              gate_op_latency_s=0.5e-3)
        pool.alloc('off', 4, 'offline')
        for i in range(3):
            rt.on_online_request_start(f'r{i}')   # preempts (gates open)
            clock.advance(0.05)
            rt.on_online_request_end(f'r{i}')
            clock.advance(rt.lifecycle.t_cool + 1e-3)
            rt.tick()                             # wake offline again
        return list(rt.stats.preemption_latencies)

    fan = latencies('fanout')
    ser = latencies('serial')
    assert fan == pytest.approx([0.5e-3] * 3)     # max over 4 devices
    assert ser == pytest.approx([4 * 0.5e-3] * 3)  # sum over 4 devices
    assert latencies('fanout') == fan             # deterministic re-run


def test_gate_timestamps_use_runtime_clock():
    rt, pool, clock = _rt(n_devices=1, gate_op_latency_s=0.0)
    clock.advance_to(42.0)
    rt.on_online_request_start('a')               # gates close at t=42
    g = rt.gates.gates[0]
    assert g.stats.last_disable_t == pytest.approx(42.0)


def test_wakeup_accounting_matches_gate_enables():
    """The reclaim finally-branch re-enable must count as an offline
    wake-up exactly like the tick() path (regression: it used to open the
    gates without touching stats.offline_wakeups)."""
    rt, pool, clock = _rt()
    pool.alloc('off-1', 10, 'offline')
    assert rt.alloc_online('on-1', 8) is not None   # reclaim, idle → rewake
    assert rt.offline_may_dispatch()
    assert rt.stats.offline_wakeups == 1
    assert rt.stats.offline_wakeups == rt.lifecycle.stats.wakeups
    assert all(g.stats.enables == rt.stats.offline_wakeups
               for g in rt.gates.gates)
    rt.check_invariants()                # now also asserts the accounting


def test_gate_fanout_faster_than_serial():
    """Real-thread path: serial flips are O(#devices), fan-out ≈ O(1).
    Best-of-3 and a 2× margin tolerate scheduler noise (nominally ~8 ms vs
    ~1 ms); the exact sum-vs-max latency model is asserted deterministically
    in test_virtual_clock_gate_latencies_deterministic."""
    from repro.core.gate import DeviceGate, GateGroup
    serial = GateGroup([DeviceGate(i, 1e-3) for i in range(8)], 'serial')
    fanout = GateGroup([DeviceGate(i, 1e-3) for i in range(8)], 'fanout')
    fanout.enable_all()                 # warm the thread pool
    ts = min(serial.disable_all() for _ in range(3))
    tf = min(fanout.disable_all() for _ in range(3))
    assert ts > 2 * tf
    serial.close()
    fanout.close()
