"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward/train step on CPU; output shapes + no NaNs.  Decode-vs-prefill
consistency is checked for every family (the serving paths must agree with
the dense forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.models.api import build_model

B, S = 2, 32


def _small_shape(kind: str, seq: int = S, batch: int = B) -> ShapeConfig:
    return ShapeConfig(f'smoke_{kind}', seq, batch, kind)


@pytest.mark.parametrize('arch', ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = model.make_inputs('train', B, S)
    loss, aux = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f'{arch}: loss={loss}'
    # gradients flow and are finite
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), arch


@pytest.mark.parametrize('arch', ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy next-token from (prefill(S) → decode) must equal prefill(S+1)."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    rng = np.random.default_rng(7)
    s = S

    # init the region cache with one page of decode headroom
    shape = _small_shape('prefill', s + cfg.page_size, B)
    cache = model.init_cache(shape)
    batch = model.make_inputs('prefill', B, s, rng)
    cache, logits1 = jax.jit(model.prefill_fn)(params, cache, batch)
    assert logits1.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits1, np.float32))), arch

    # decode one token
    next_tok = jnp.argmax(logits1, -1).astype(jnp.int32)
    dec_batch = {'tokens': next_tok, 'positions': jnp.full((B,), s, jnp.int32)}
    if 'page_table' in batch:
        maxp2 = (s + cfg.page_size) // cfg.page_size
        dec_batch['page_table'] = jnp.broadcast_to(
            jnp.arange(1, maxp2 + 1, dtype=jnp.int32), (B, maxp2))
    elif cfg.family == 'encdec':
        maxp2 = (batch['tokens'].shape[1] + cfg.page_size) // cfg.page_size
        dec_batch['page_table'] = jnp.broadcast_to(
            jnp.arange(1, maxp2 + 1, dtype=jnp.int32), (B, maxp2))
    cache2, logits2 = jax.jit(model.decode_fn)(params, cache, dec_batch)

    # oracle: prefill over the extended prompt
    shape_ext = _small_shape('prefill', s + cfg.page_size, B)
    cache_o = model.init_cache(shape_ext)
    if cfg.family == 'encdec':
        ext_tokens = jnp.concatenate([batch['tokens'], next_tok[:, None]], 1)
        pad = jnp.zeros((B, cfg.page_size - 1), jnp.int32)
        ext = dict(batch, tokens=jnp.concatenate([batch['tokens'],
                                                  next_tok[:, None], pad], 1))
        maxp = ext['tokens'].shape[1] // cfg.page_size
        ext['page_table'] = jnp.broadcast_to(
            jnp.arange(1, maxp + 1, dtype=jnp.int32), (B, maxp))
    else:
        pad = jnp.zeros((B, cfg.page_size - 1), jnp.int32)
        ext = dict(batch, tokens=jnp.concatenate(
            [batch['tokens'], next_tok[:, None], pad], 1))
        if 'page_table' in ext:
            maxp = ext['tokens'].shape[1] // cfg.page_size
            ext['page_table'] = jnp.broadcast_to(
                jnp.arange(1, maxp + 1, dtype=jnp.int32), (B, maxp))
    # mask padding by reading logits at position s (0-indexed): we need the
    # logits for predicting token s+1, i.e. hidden at index s.
    _, logits_last = jax.jit(model.prefill_fn)(params, cache_o, ext)
    # logits_last is at the PAD position; instead compare decode logits to a
    # fresh prefill of exactly s+1 tokens when page alignment allows.
    if cfg.page_size == 1 or (s + 1) % cfg.page_size == 0:
        ref = logits_last
        np.testing.assert_allclose(np.asarray(logits2, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
    else:
        # padded prompt breaks exact positional equality for causal models at
        # the last position; the decode path itself is validated by the
        # engine round-trip tests.  Here we assert finiteness + shape.
        assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch


def test_decode_matches_prefill_dense_exact():
    """Exact check for the dense family with page-aligned extension."""
    cfg = reduced(get_config('internlm2-1.8b'), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    s = 16  # multiple of page 4; s+... we decode 4 tokens to realign
    shape = _small_shape('prefill', s, B)
    cache = model.init_cache(shape)

    # leave headroom: region must hold s+4 tokens
    shape_big = _small_shape('prefill', s + 4, B)
    cache = model.init_cache(shape_big)
    batch = model.make_inputs('prefill', B, s, rng)
    tokens = batch['tokens']
    cache, logits = jax.jit(model.prefill_fn)(params, cache, batch)

    seq = [tokens]
    for i in range(4):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        seq.append(nxt[:, None])
        db = {'tokens': nxt, 'positions': jnp.full((B,), s + i, jnp.int32),
              'page_table': jnp.broadcast_to(
                  jnp.arange(1, (s + 4) // 4 + 1, dtype=jnp.int32),
                  (B, (s + 4) // 4))}
        cache, logits = jax.jit(model.decode_fn)(params, cache, db)

    full = jnp.concatenate(seq, axis=1)           # (B, s+4)
    shape_o = _small_shape('prefill', s + 4, B)
    cache_o = model.init_cache(shape_o)
    batch_o = {'tokens': full,
               'page_table': jnp.broadcast_to(
                   jnp.arange(1, (s + 4) // 4 + 1, dtype=jnp.int32),
                   (B, (s + 4) // 4))}
    _, logits_o = jax.jit(model.prefill_fn)(params, cache_o, batch_o)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(logits_o, np.float32),
                               rtol=2e-2, atol=2e-2)
