"""Cross-pool KV rescue end-to-end at the node level.

A reclamation victim on the runtime pool is *migrated* — whole lease,
surviving every token — to an auxiliary pool instead of truncated, the
orchestrator copies the physical KV rows and hands the Request to an
engine serving that pool, and generation resumes with ZERO recomputed
tokens: the rescued output is bit-equal to an undisturbed run.
"""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.events import PageMigration, ReclamationEvent
from repro.core.memory import MemoryPlane
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.launch.node import NodeOrchestrator
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kvpool import KVPool

ARCH = 'qwen3-0.6b'


def _ecfg(klass):
    return EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                        klass=klass)


def _node(*, aux_pool=True):
    """Runtime pool A (tight: 5×4 pages) + auxiliary pool B (spacious).

    All engines share one architecture and ONE param seed, so a rescued
    request's KV rows are valid under the destination engine's weights and
    greedy continuation is bit-deterministic across the handoff."""
    pool = KVPool(5, 4, page_size=4, reserved_handles=1, name='poolA')
    clock = VirtualClock()
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=clock)
    node = NodeOrchestrator(rt, idle_advance=1e-3)
    cfg = reduced(get_config(ARCH), page_size=4)
    node.add_engine(cfg, _ecfg('online'), seed=0, name='online')
    node.add_engine(cfg, _ecfg('offline'), seed=0, name='offA')
    if aux_pool:
        pool_b = node.add_pool(KVPool(8, 4, page_size=4, name='poolB'))
        node.add_engine(cfg, _ecfg('offline'), seed=0, name='offB',
                        pool=pool_b)
    return node


def _engine_holding(node, rid):
    for eng in node.engines:
        if rid in eng.requests:
            return eng
    raise AssertionError(f'{rid} not held by any engine')


def _run(disturb):
    node = _node()
    rng = np.random.default_rng(7)
    eng = node.names['offA']
    rids = [eng.submit(rng.integers(1, eng.mcfg.vocab_size, 12).tolist(),
                       max_new_tokens=8) for _ in range(2)]
    for _ in range(4):                    # prefill done, decode under way
        node.step()
    if disturb:
        # 28-token prompt + 12 new = 10 pages >> the 4-page reservation →
        # reclamation must take offline handles → rescue to pool B
        on_rid = node.online.submit(
            rng.integers(1, node.online.mcfg.vocab_size, 28).tolist(),
            max_new_tokens=12)
    node.drain(max_steps=5000)
    if disturb:
        assert len(node.online.output_tokens(on_rid)) == 12
    return node, rids


def test_rescue_zero_recompute_bit_equal():
    ref_node, ref_rids = _run(disturb=False)
    ref_out = [_engine_holding(ref_node, r).output_tokens(r)
               for r in ref_rids]

    node, rids = _run(disturb=True)

    # the burst actually forced a cross-pool rescue
    assert node.stats.migrations_seen >= 1
    assert node.stats.requests_rescued >= 1
    assert node.rescues and all(sp == 'poolA' and dp == 'poolB'
                                for _, sp, dp in node.rescues)
    rescued = {rid for rid, _, _ in node.rescues}
    assert rescued <= set(rids)

    # rescued requests finished ON the pool-B engine with the undisturbed
    # outputs — the KV-row copy carried every token across, nothing was
    # recomputed (greedy decode would diverge from ref on any lost page)
    dst = node.names['offB']
    for rid in rescued:
        assert _engine_holding(node, rid) is dst
        req = dst.requests[rid]
        assert req.recomputes == 0
    assert dst.stats.tokens_recomputed == 0
    assert dst.stats.invalidations == 0
    got = [_engine_holding(node, r).output_tokens(r) for r in rids]
    assert got == ref_out

    # telemetry folded the migration from the event stream
    snap = node.runtime.telemetry.snapshot()
    assert snap['pages_migrated'] >= 1
    assert snap['requests_migrated'] == len(node.rescues)
    evs = [e for e in node.runtime.bus.events(PageMigration) if e.cross_pool]
    assert len(evs) == node.stats.migrations_seen
    for ev in evs:
        assert ev.src_pool == 'poolA' and ev.dst_pool == 'poolB'
        assert len(ev.src_pages) == len(ev.dst_pages) == ev.n_pages > 0

    # rescued victims are NOT counted as reclamation damage: the
    # ReclamationEvent lists only truncated requests, never rescued ones —
    # instead each names its rescued victims in the ``rescued`` field, so
    # the log itself witnesses copy-before-reallocation ordering
    recl = node.runtime.bus.events(ReclamationEvent)
    for ev in recl:
        assert not (set(ev.requests) & rescued)
        assert not (set(ev.rescued) & set(ev.requests))
    assert {r for ev in recl for r in ev.rescued} == rescued

    # routes died with the migrated leases; both pools/planes consistent
    assert node.runtime.invalidation_routes() == []
    node.runtime.check_invariants()
    node.pool.check_invariants()
    for p in node.pools:
        p.check_invariants()
        MemoryPlane.of(p).check_invariants()
    node.runtime.memory.check_invariants()


def test_no_aux_pool_falls_back_to_truncation():
    """Without a migration target the same burst degrades to the PR-5
    behavior: victims are truncated and recompute on the source engine."""
    node, rids = _run(disturb=True)
    base, base_rids = None, None
    try:
        base, base_rids = _node(aux_pool=False), None
    finally:
        pass
    rng = np.random.default_rng(7)
    eng = base.names['offA']
    base_rids = [eng.submit(
        rng.integers(1, eng.mcfg.vocab_size, 12).tolist(),
        max_new_tokens=8) for _ in range(2)]
    for _ in range(4):
        base.step()
    base.online.submit(
        rng.integers(1, base.online.mcfg.vocab_size, 28).tolist(),
        max_new_tokens=12)
    base.drain(max_steps=5000)

    assert base.stats.migrations_seen == 0
    assert base.names['offA'].stats.invalidations >= 1
    assert base.names['offA'].stats.tokens_recomputed > 0
    # ... whereas the rescue path recomputed nothing anywhere offline
    assert node.names['offB'].stats.tokens_recomputed == 0
    # both converge to the same outputs (recompute is correct, just wasteful)
    ref = [_engine_holding(node, r).output_tokens(r) for r in rids]
    got = [base.names['offA'].output_tokens(r) for r in base_rids]
    assert got == ref


def test_add_pool_and_register_guards():
    node = _node()
    cfg = reduced(get_config(ARCH), page_size=4)
    with pytest.raises(AssertionError):
        node.add_pool(node.pools[0])              # already registered
    with pytest.raises(AssertionError):
        node.add_pool(node.pool)                  # the runtime pool itself
    with pytest.raises(AssertionError):
        node.add_pool(KVPool(4, 4, page_size=8))  # page-size mismatch
    # pool names key PageMigration provenance and MemoryPlane routing —
    # a duplicate (aux 'poolB' or the runtime pool's own 'poolA') would
    # make cross-pool events ambiguous, so add_pool refuses it
    with pytest.raises(AssertionError):
        node.add_pool(KVPool(4, 4, page_size=4, name='poolB'))
    with pytest.raises(AssertionError):
        node.add_pool(KVPool(4, 4, page_size=4, name='poolA'))
    # pool-backed engines must serve a registered aux pool, offline only
    rogue = KVPool(4, 4, page_size=4)
    from repro.models.api import build_model
    import jax
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(AssertionError):
        node.register(Engine(model, params, rogue, _ecfg('offline'),
                             clock=node.clock))
    with pytest.raises(AssertionError):
        node.register(Engine(model, params, node.pools[0], _ecfg('online'),
                             clock=node.clock))
