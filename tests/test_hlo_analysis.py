"""HLO text analyzer: trip-count multipliers, collective accounting,
dot FLOPs, slice-aware traffic."""
import pytest

from repro.launch import hlo_analysis as ha

MODULE = '''
HloModule test

%body (p: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
  %p = (s32[], f32[128,64]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,64] get-tuple-element(%p), index=1
  %w = f32[64,64] constant({...})
  %dot.1 = f32[128,64] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,64] all-reduce(%dot.1), replica_groups=[16,16]<=[256], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,64]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[128,64])) -> pred[] {
  %p2 = (s32[], f32[128,64]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main (a: f32[128,64]) -> f32[128,64] {
  %a = f32[128,64] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,64]) tuple(%zero, %a)
  %w2 = (s32[], f32[128,64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"},"other":1}
  ROOT %out = f32[128,64] get-tuple-element(%w2), index=1
}
'''


def test_trip_count_multiplies_costs():
    costs = ha.analyze(MODULE)
    # dot: 2 × 128×64 × 64 = 1,048,576 per iteration × 10
    assert costs.flops == pytest.approx(10 * 2 * 128 * 64 * 64)
    # all-reduce payload: 128×64×4 bytes × 10 iterations
    assert costs.coll_payload['all-reduce'] == pytest.approx(
        10 * 128 * 64 * 4)
    # ring wire factor 2(n-1)/n with group size 16
    assert costs.coll_wire == pytest.approx(
        10 * 128 * 64 * 4 * 2 * 15 / 16)
    assert costs.coll_count == 10


def test_type_bytes_tuple_with_comments():
    t = '(s32[], bf16[2,3]{1,0}, /*index=5*/f32[4])'
    assert ha.type_bytes(t) == 4 + 2 * 3 * 2 + 4 * 4


def test_wire_factor():
    assert ha.wire_factor('all-reduce', 2) == pytest.approx(1.0)
    assert ha.wire_factor('all-gather', 4) == pytest.approx(0.75)
    assert ha.wire_factor('collective-permute', 8) == 1.0
    assert ha.wire_factor('all-reduce', 1) == 0.0


FUSION_MODULE = '''
HloModule f

%fused_computation (param_0: f32[32,128,64], param_1: s32[]) -> f32[1,128,64] {
  %param_0 = f32[32,128,64] parameter(0)
  %param_1 = s32[] parameter(1)
  %z = s32[] constant(0)
  ROOT %ds = f32[1,128,64] dynamic-slice(%param_0, %param_1, %z, %z), dynamic_slice_sizes={1,128,64}
}

ENTRY %main (stack: f32[32,128,64], idx: s32[]) -> f32[1,128,64] {
  %stack = f32[32,128,64] parameter(0)
  %idx = s32[] parameter(1)
  ROOT %fu = f32[1,128,64] fusion(%stack, %idx), kind=kLoop, calls=%fused_computation
}
'''


def test_fusion_slice_aware_traffic():
    costs = ha.analyze(FUSION_MODULE)
    slice_bytes = 1 * 128 * 64 * 4
    # read the slice region (NOT the 32× stack) + write the result
    # (+4 bytes for the scalar index parameter)
    assert costs.traffic_bytes == pytest.approx(2 * slice_bytes + 4)
