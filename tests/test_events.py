"""Typed event stream + unified telemetry: §5 ordering visible in the log,
T_cool respected by every WakeupEvent, counters derived (not hand-synced),
bounded preemption-latency summary."""
import pytest

from repro.core.clock import VirtualClock
from repro.core.events import (
    EventBus, MemoryPressureEvent, PageMigration, PreemptionEvent,
    ReclamationEvent, ReservationChangeEvent, WakeupEvent,
    check_event_ordering)
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.core.sim.colocation import NodeSim, SimConfig, run_strategy
from repro.core.sim.workload import make_workload_pairs
from repro.core.telemetry import LatencySummary, TelemetryRegistry
from repro.serving.kvpool import KVPool


def _rt(n_handles=8, pph=4, **kw):
    pool = KVPool(n_handles, pph, reserved_handles=1)
    clock = VirtualClock()
    return ValveRuntime(pool, RuntimeConfig(**kw), clock=clock), pool, clock


# ---------------------------------------------------------------------------
# EventBus basics
# ---------------------------------------------------------------------------

def test_bus_orders_filters_and_counts():
    bus = EventBus(VirtualClock())
    seen, pre_only = [], []
    unsub = bus.subscribe(seen.append)
    bus.subscribe(pre_only.append, PreemptionEvent)
    bus.publish(PreemptionEvent, latency_s=1e-3)
    bus.publish(WakeupEvent)
    assert [type(e).__name__ for e in seen] == ['PreemptionEvent',
                                                'WakeupEvent']
    assert len(pre_only) == 1
    assert [e.seq for e in bus.log] == [0, 1]
    assert bus.count(PreemptionEvent) == 1
    unsub()
    bus.publish(WakeupEvent)
    assert len(seen) == 2                       # unsubscribed
    assert len(pre_only) == 1


def test_bus_log_is_bounded_but_counts_are_cumulative():
    bus = EventBus(VirtualClock(), log_maxlen=8)
    for _ in range(20):
        bus.publish(WakeupEvent)
    assert len(bus.log) == 8
    assert bus.count(WakeupEvent) == 20


# ---------------------------------------------------------------------------
# Runtime event stream: the paper's ordering as log properties
# ---------------------------------------------------------------------------

def test_runtime_reclamation_events_are_gate_closed():
    """§5: every ReclamationEvent in a runtime log must carry
    gate_closed=True, preceded by a memory-trigger PreemptionEvent when the
    gates were open at pressure time."""
    rt, pool, clock = _rt()
    pool.alloc('off', 28, 'offline')            # every offline handle live
    assert rt.alloc_online('on-1', 8) is not None
    evs = rt.bus.events()
    kinds = [type(e).__name__ for e in evs]
    assert kinds == ['MemoryPressureEvent', 'PreemptionEvent',
                     'ReclamationEvent', 'WakeupEvent']
    pre, rec = evs[1], evs[2]
    assert pre.trigger == 'memory'
    assert rec.gate_closed and rec.n_handles >= 1 and rec.requests == ('off',)
    check_event_ordering(evs)                   # seq/t/ordering all hold
    rt.check_invariants()


def test_runtime_wakeups_respect_t_cool():
    rt, pool, clock = _rt()
    pool.alloc('off', 4, 'offline')
    for i in range(3):
        rt.on_online_request_start(f'r{i}')
        clock.advance(0.05)
        rt.on_online_request_end(f'r{i}')
        clock.advance(rt.lifecycle.t_cool + 1e-3)
        rt.tick()
    wakes = rt.bus.events(WakeupEvent)
    assert len(wakes) == 3
    for w in wakes:
        assert w.idle_for_s >= w.t_cool_s
    check_event_ordering(rt.bus.events())
    rt.check_invariants()


def test_runtime_stats_are_derived_from_events():
    """The legacy counters are a registry fold over the stream — publish
    counts and stats fields cannot disagree."""
    rt, pool, clock = _rt()
    pool.alloc('off', 20, 'offline')
    rt.alloc_online('on-1', 8)
    rt.on_online_request_start('r0')
    clock.advance(0.05)
    rt.on_online_request_end('r0')
    clock.advance(rt.lifecycle.t_cool + 1e-3)
    rt.tick()
    assert rt.stats.compute_preemptions == rt.bus.count(PreemptionEvent)
    assert rt.stats.offline_wakeups == rt.bus.count(WakeupEvent)
    assert rt.stats.memory_pressure_events == rt.bus.count(MemoryPressureEvent)
    assert rt.telemetry.counters.reclamations == rt.bus.count(ReclamationEvent)
    assert len(rt.stats.preemption_latencies) == rt.stats.compute_preemptions
    snap = rt.telemetry.snapshot()
    assert snap['compute_preemptions'] == rt.stats.compute_preemptions
    assert snap['preemption_latency']['count'] == rt.stats.compute_preemptions


def test_reservation_change_events():
    from repro.core.miad import MIADConfig
    rt, pool, clock = _rt(miad=MIADConfig(alpha=2.0, t_init=100.0,
                                          t_min=1.0, t_step=10.0,
                                          target_rate=10.0))
    rt.alloc_online('a', 4)
    for _ in range(4):
        clock.advance(0.3)
        rt.tick()
    changes = rt.bus.events(ReservationChangeEvent)
    assert changes, 'MIAD growth must publish ReservationChangeEvents'
    for ev in changes:
        assert ev.h_after != ev.h_before
    assert changes[-1].h_after == len(pool.reserved)


# ---------------------------------------------------------------------------
# Copy-before-reallocation: rescued victims need a migration witness
# ---------------------------------------------------------------------------

def test_rescued_victim_with_prior_migration_passes_ordering():
    """A ReclamationEvent may name a victim as ``rescued`` only if an
    earlier cross-pool PageMigration in the same log moved its pages —
    the data-plane copy runs at that publish, so log order proves the KV
    left the pool before the reclamation freed the source."""
    bus = EventBus(VirtualClock())
    bus.publish(PageMigration, owner='r1', src_pool='A', dst_pool='B',
                cross_pool=True, n_pages=2)
    bus.publish(ReclamationEvent, n_handles=1, rescued=('r1',))
    check_event_ordering(bus.events())


def test_rescued_victim_without_witness_fails_ordering():
    bus = EventBus(VirtualClock())
    bus.publish(ReclamationEvent, n_handles=1, rescued=('r1',))
    with pytest.raises(AssertionError):
        check_event_ordering(bus.events())
    # the witness rule is not a §5 gate property — relaxing the gate
    # check (baseline strategies) must NOT relax it
    with pytest.raises(AssertionError):
        check_event_ordering(bus.events(), require_gate_closed=False)


def test_migration_after_reclamation_is_no_witness():
    """Order matters: a copy published AFTER the reclamation came too
    late — the freed source pages could already be reallocated."""
    bus = EventBus(VirtualClock())
    bus.publish(ReclamationEvent, n_handles=1, rescued=('r1',))
    bus.publish(PageMigration, owner='r1', src_pool='A', dst_pool='B',
                cross_pool=True, n_pages=2)
    with pytest.raises(AssertionError):
        check_event_ordering(bus.events())


def test_intra_pool_rekey_is_no_witness():
    """cross_pool=False is an ownership re-key inside one pool — no KV
    escaped, so it cannot justify a rescue claim."""
    bus = EventBus(VirtualClock())
    bus.publish(PageMigration, owner='r1', src_pool='A', dst_pool='A',
                cross_pool=False, n_pages=2)
    bus.publish(ReclamationEvent, n_handles=1, rescued=('r1',))
    with pytest.raises(AssertionError):
        check_event_ordering(bus.events())


# ---------------------------------------------------------------------------
# NodeSim event stream (same ordered facts as the live runtime)
# ---------------------------------------------------------------------------

def _short_pair():
    return make_workload_pairs(1, horizon_s=40.0, seed=3)[0]


def test_sim_valve_strategy_log_satisfies_paper_ordering():
    res = run_strategy(_short_pair(), 'Channel', 'OurMem',
                       SimConfig(total_pages=256))
    assert res.telemetry is not None
    evs = res.events
    assert any(isinstance(e, ReclamationEvent) for e in evs), \
        'workload too tame: no reclamation exercised'
    # §5 + §4.2 as log properties (gate_closed on every reclamation,
    # idle ≥ T_cool on every wake-up, monotone seq/t)
    check_event_ordering(evs)
    # every reclamation is preceded by closed-gate state: the nearest
    # earlier Preemption/Wakeup boundary is not a wake (gates stay closed
    # from the preemption until the next WakeupEvent)
    state_closed = False
    for ev in evs:
        if isinstance(ev, PreemptionEvent):
            state_closed = True
        elif isinstance(ev, WakeupEvent):
            state_closed = False
        elif isinstance(ev, ReclamationEvent):
            assert ev.gate_closed
    # telemetry fold agrees with the legacy per-policy stat objects
    assert res.telemetry.counters.preemptions == res.compute_stats.preemptions
    assert res.telemetry.counters.reclamations == res.mem_stats.reclamations
    assert res.telemetry.max_preemptions_per_request \
        == res.max_preempt_per_request <= 1


def test_sim_uvm_baseline_exposes_ordering_violation():
    """UVM moves pages under running offline compute; its events say so —
    the §5 check must fail on its log and pass when not required."""
    res = run_strategy(_short_pair(), 'KernelPreempt', 'UVM',
                       SimConfig(total_pages=256))
    recl = [e for e in res.events if isinstance(e, ReclamationEvent)]
    assert recl and all(not e.gate_closed for e in recl)
    assert any(e.killed for e in recl)          # UVM kills its victims
    with pytest.raises(AssertionError):
        check_event_ordering(res.events)
    check_event_ordering(res.events, require_gate_closed=False)


def test_sim_events_off_is_clean():
    pair = _short_pair()
    from repro.core.sim.strategies import Channel, OurMem
    sim = NodeSim(pair, Channel(), OurMem(256, 16),
                  SimConfig(total_pages=256), events=False)
    res = sim.run()
    assert res.telemetry is None and res.events == []


# ---------------------------------------------------------------------------
# LatencySummary (bounded preemption-latency record)
# ---------------------------------------------------------------------------

def test_latency_summary_exact_below_cap():
    s = LatencySummary(cap=16)
    xs = [0.5e-3, 1.0e-3, 2.0e-3]
    for x in xs:
        s.record(x)
    assert list(s) == xs and len(s) == 3 and s.raw == xs
    assert s == xs                              # list-compat equality
    assert s.mean == pytest.approx(sum(xs) / 3)
    assert s.max == 2.0e-3 and s.p50 == 1.0e-3
    assert s.exact


def test_latency_summary_bounded_beyond_cap():
    s = LatencySummary(cap=64)
    n = 10_000
    for i in range(n):
        s.record(float(i))
    assert len(s.raw) == 64                     # memory stays bounded
    assert s.count == n and not s.exact
    assert s.mean == pytest.approx((n - 1) / 2)
    assert s.max == float(n - 1)
    # reservoir quantiles are estimates of the uniform stream
    assert 0.2 * n < s.p50 < 0.8 * n
    d = s.summary()
    assert d['count'] == n and d['max'] == float(n - 1)


def test_latency_summary_is_deterministic():
    def fill():
        s = LatencySummary(cap=8)
        for i in range(100):
            s.record(i * 0.1)
        return s.raw
    assert fill() == fill()


def test_registry_invariant_check_catches_excess_preemptions():
    bus = EventBus(VirtualClock())
    reg = TelemetryRegistry(bus)
    bus.publish(PreemptionEvent, requests=('r1',))
    reg.check_invariants()
    bus.publish(PreemptionEvent, requests=('r1',))
    with pytest.raises(AssertionError):
        reg.check_invariants()                  # r1 preempted twice
