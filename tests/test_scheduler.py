"""Batch-composition scheduler policy: budgeted multi-request prefill,
mixed prefill+decode dispatches, FIFO fairness under invalidation churn.

Pure policy tests — no JAX, no tensors: the scheduler layer is engine-
agnostic by construction."""
from repro.serving.scheduler import (
    BatchScheduler, Request, ReqState, SchedulerConfig)


def _requests(*lens):
    """n requests with the given context lengths, already submitted FIFO."""
    reqs = {}
    for i, n in enumerate(lens):
        rid = f'r{i}'
        reqs[rid] = Request(rid, list(range(1, n + 1)), max_new_tokens=4)
    return reqs


def _admit_all(req):
    return [1] * 2          # pages; tests here never inspect them


def _sched(requests, cfg):
    s = BatchScheduler(cfg)
    for rid in requests:
        s.submit(rid)
    return s


def test_budget_fills_across_multiple_requests():
    """The per-dispatch prefill budget is split FIFO across waiting
    requests — not one request per step (the seed behavior)."""
    reqs = _requests(40, 40, 40)
    s = _sched(reqs, SchedulerConfig(max_batch=8, chunk=16,
                                     max_prefill_reqs=4))
    b = s.schedule(reqs, _admit_all)
    assert [(p.req_id, p.start, p.length) for p in b.prefill] == \
        [('r0', 0, 16), ('r1', 0, 16), ('r2', 0, 16)]
    assert not b.decode
    assert b.prefill_tokens == 48


def test_budget_cap_truncates_tail_request():
    reqs = _requests(40, 40)
    s = _sched(reqs, SchedulerConfig(max_batch=8, chunk=16,
                                     max_prefill_reqs=4, prefill_budget=24))
    b = s.schedule(reqs, _admit_all)
    assert [(p.req_id, p.length) for p in b.prefill] == \
        [('r0', 16), ('r1', 8)]


def test_max_prefill_reqs_caps_rows():
    reqs = _requests(8, 8, 8, 8)
    s = _sched(reqs, SchedulerConfig(max_batch=8, chunk=16,
                                     max_prefill_reqs=2))
    b = s.schedule(reqs, _admit_all)
    assert len(b.prefill) == 2
    assert {p.req_id for p in b.prefill} == {'r0', 'r1'}


def test_chunk_progress_across_steps():
    """Successive dispatches continue each request where it left off."""
    reqs = _requests(40)
    s = _sched(reqs, SchedulerConfig(max_batch=4, chunk=16))
    b = s.schedule(reqs, _admit_all)
    assert b.prefill[0].start == 0 and b.prefill[0].length == 16
    reqs['r0'].n_prefilled = 16          # the engine would do this
    b = s.compose(reqs)
    assert b.prefill[0].start == 16 and b.prefill[0].length == 16
    reqs['r0'].n_prefilled = 32
    b = s.compose(reqs)
    assert b.prefill[0].start == 32 and b.prefill[0].length == 8


def test_decode_piggybacks_on_prefill_dispatch():
    """RUNNING requests ride along in the same iteration as prefill rows."""
    reqs = _requests(8, 8, 40)
    s = _sched(reqs, SchedulerConfig(max_batch=8, chunk=16))
    s.schedule(reqs, _admit_all)
    reqs['r0'].state = ReqState.RUNNING   # finished prefill, now decoding
    reqs['r1'].state = ReqState.RUNNING
    reqs['r0'].n_prefilled = reqs['r1'].n_prefilled = 8
    b = s.compose(reqs)
    assert [p.req_id for p in b.prefill] == ['r2']
    assert {d.req_id for d in b.decode} == {'r0', 'r1'}
    assert b.n_slots == 3


def test_piggyback_disabled_reproduces_seed_alternation():
    reqs = _requests(8, 40)
    s = _sched(reqs, SchedulerConfig(max_batch=8, chunk=16,
                                     max_prefill_reqs=1,
                                     piggyback_decode=False))
    s.schedule(reqs, _admit_all)
    reqs['r0'].state = ReqState.RUNNING
    reqs['r0'].n_prefilled = 8
    b = s.compose(reqs)
    assert [p.req_id for p in b.prefill] == ['r1']
    assert not b.decode                  # prefill XOR decode, as the seed
    reqs['r1'].state = ReqState.RUNNING
    reqs['r1'].n_prefilled = 40
    b = s.compose(reqs)
    assert not b.prefill and len(b.decode) == 2


def test_decode_only_batch_when_nothing_to_prefill():
    reqs = _requests(8, 8)
    s = _sched(reqs, SchedulerConfig(max_batch=4, chunk=16))
    s.schedule(reqs, _admit_all)
    for r in reqs.values():
        r.state = ReqState.RUNNING
        r.n_prefilled = 8
    b = s.compose(reqs)
    assert not b.prefill
    assert [d.req_id for d in b.decode] == ['r0', 'r1']


def test_admission_head_of_line_blocks_fifo():
    """A memory-blocked head request blocks the whole queue (FIFO — no
    starvation of big requests by small late arrivals)."""
    reqs = _requests(8, 8, 8)
    s = _sched(reqs, SchedulerConfig(max_batch=8, chunk=16))

    def admit(req):
        return None if req.req_id == 'r1' else [1, 2]

    n = s.admit(reqs, admit)
    assert n == 1
    assert s.running == ['r0']
    assert s.queue == ['r1', 'r2']       # r2 NOT admitted around r1


def test_admission_respects_max_batch():
    reqs = _requests(*([8] * 6))
    s = _sched(reqs, SchedulerConfig(max_batch=4, chunk=16))
    s.admit(reqs, _admit_all)
    assert len(s.running) == 4 and len(s.queue) == 2


def test_fifo_fairness_under_invalidation_churn():
    """An invalidated request requeued at the head (the Valve patch's
    behavior) is re-admitted and re-prefilled before later arrivals."""
    reqs = _requests(16, 16, 16, 16)
    s = _sched(reqs, SchedulerConfig(max_batch=2, chunk=16,
                                     max_prefill_reqs=2))
    s.schedule(reqs, _admit_all)         # r0, r1 admitted (max_batch=2)
    assert s.running == ['r0', 'r1']
    for rid in ('r0', 'r1'):
        reqs[rid].state = ReqState.RUNNING
        reqs[rid].n_prefilled = 16
    # invalidation hits r1: what Engine.on_pages_invalidated does
    reqs['r1'].state = ReqState.WAITING
    reqs['r1'].pages = []
    reqs['r1'].n_prefilled = 0
    s.running.remove('r1')
    s.queue.insert(0, 'r1')
    assert s.queue == ['r1', 'r2', 'r3']
    b = s.schedule(reqs, _admit_all)
    # r1 re-admitted ahead of r2/r3 and gets the prefill slot; the
    # surviving r0 keeps decoding in the same dispatch
    assert s.running == ['r0', 'r1']
    assert [p.req_id for p in b.prefill] == ['r1']
    assert [d.req_id for d in b.decode] == ['r0']


def test_budget_defaults_to_rows_times_chunk():
    cfg = SchedulerConfig(chunk=16, max_prefill_reqs=3)
    assert cfg.budget == 48
    assert SchedulerConfig(chunk=16, prefill_budget=20).budget == 20


def test_spill_after_sustained_head_blocking():
    """Partial KV retention must not deadlock admission: after
    ``spill_after_blocked`` consecutive failures of the queue head, waiting
    requests' surviving-prefix pages are spilled (head first) one at a time
    until the head fits."""
    reqs = _requests(8, 8)
    s = _sched(reqs, SchedulerConfig(max_batch=4, chunk=16,
                                     spill_after_blocked=3))
    reqs['r0'].pages = [1, 2]            # waiting, holding survivors
    reqs['r1'].pages = [3]
    spilled = []

    def admit(req):
        # memory frees only once BOTH survivors are spilled
        return [9, 9] if len(spilled) == 2 else None

    def spill(r):
        spilled.append(r.req_id)
        r.pages = []

    for _ in range(2):                   # below the threshold: no spill
        s.admit(reqs, admit, spill)
        assert spilled == []
    s.admit(reqs, admit, spill)          # 3rd failure → incremental spill
    assert spilled == ['r0', 'r1']
    # head admitted after the spills (and r1 right behind it, now that
    # memory is free)
    assert s.running == ['r0', 'r1']
    assert reqs['r0'].pages == [9, 9]
    assert reqs['r0'].blocked_admits == 0


def test_admit_resumes_at_lease_resume_tokens():
    """Admission takes the resume point from the lease (shared prefix on a
    fresh admit, surviving prefix on a re-admit) instead of resetting the
    prefill cursor to 0."""
    class FakeLease(list):
        resume_tokens = 8

    reqs = _requests(16)
    s = _sched(reqs, SchedulerConfig(max_batch=2, chunk=16,
                                     max_prefill_reqs=2))
    b = s.schedule(reqs, lambda r: FakeLease([1, 2, 3, 4]))
    assert reqs['r0'].n_prefilled == 8
    # the composed prefill row starts at the resume point
    assert [(p.req_id, p.start, p.length) for p in b.prefill] == \
        [('r0', 8, 8)]
