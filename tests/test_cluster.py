"""Cluster performance model (Eq. 1–2), scheduler (§6), and the closed-loop
NodeSim-telemetry harness."""
import numpy as np
import pytest

from repro.core.cluster.harness import (
    HarnessConfig, make_harness, profile_workload_from_sim,
    telemetry_from_sim)
from repro.core.cluster.perfmodel import (
    GPUTelemetry, NodeTelemetry, _union_intersection, admissible, p_compute,
    p_memory, p_multi, predict_normalized_throughput, profile_workload,
    profile_workload_from_curve)
from repro.core.cluster.scheduler import ClusterScheduler, OfflineJob
from repro.core.sim.colocation import SimConfig, run_online_standalone
from repro.core.sim.workload import (
    OfflineWorkload, WorkloadPair, make_fleet_workloads, make_online_trace,
    slice_trace)


def _gpu(busy, free_frac=0.8, horizon=100.0):
    ts = np.linspace(0, horizon, 16)
    free = np.full_like(ts, free_frac * 4096)
    return GPUTelemetry(busy, ts, free, window=(0, horizon))


def test_p_compute_idle_fraction():
    g = _gpu([(0, 25.0), (50.0, 75.0)])
    assert p_compute(g) == pytest.approx(0.5)


def test_p_memory_monotone_in_free_memory():
    w = profile_workload('w', thrput_max=100.0, m_req=2048)
    lo = p_memory(w, _gpu([], free_frac=0.2))
    hi = p_memory(w, _gpu([], free_frac=0.9))
    assert hi > lo
    assert 0.0 <= lo <= hi <= 1.0


def test_p_memory_deficit_penalty():
    """Dipping below M_req costs MAC_w · E[ΔM] (Eq. 2)."""
    w = profile_workload('w', thrput_max=100.0, m_req=4000)
    tight = p_memory(w, _gpu([], free_frac=0.5))   # 2048 < m_req
    ample = p_memory(w, _gpu([], free_frac=1.0))
    assert tight < ample


def test_p_multi_alignment():
    a = [(0, 10.0), (20.0, 30.0)]
    aligned = [_gpu(a), _gpu(list(a))]
    assert p_multi(aligned) == pytest.approx(1.0)
    disjoint = [_gpu([(0, 10.0)]), _gpu([(10.0, 20.0)])]
    assert p_multi(disjoint) == pytest.approx(0.0)
    # partial overlap
    part = [_gpu([(0, 10.0)]), _gpu([(5.0, 15.0)])]
    assert p_multi(part) == pytest.approx(5.0 / 15.0)


def test_admission_gate_requires_alignment():
    w = profile_workload('mp', thrput_max=100.0, m_req=1024, n_gpus=2)
    misaligned = [_gpu([(0, 10.0)]), _gpu([(40.0, 50.0)])]
    assert not admissible(w, misaligned)
    aligned = [_gpu([(0, 10.0)]), _gpu([(0, 10.0)])]
    assert admissible(w, aligned)


def test_eq1_product_form():
    w = profile_workload('w', thrput_max=100.0, m_req=1024)
    g = _gpu([(0, 50.0)], free_frac=0.9)
    pred = predict_normalized_throughput(w, [g])
    assert pred == pytest.approx(p_compute(g) * p_memory(w, g) * 1.0)


def test_scheduler_places_on_best_node_and_evicts_violators():
    idle = NodeTelemetry('idle', [_gpu([])])
    busy = NodeTelemetry('busy', [_gpu([(0, 90.0)])])
    sched = ClusterScheduler([busy, idle])
    job = OfflineJob(profile_workload('j', thrput_max=10.0, m_req=1024),
                     sla=0.3)
    p = sched.place(job)
    assert p is not None and p.node == 'idle'
    # persistent SLA violation → eviction + requeue
    for _ in range(3):
        sched.report_throughput(job.job_id, achieved_norm=0.1)
    assert sched.evictions == 1
    assert job in sched.pending
    assert job.job_id not in sched.placements


def test_scheduler_queues_unplaceable_jobs():
    busy = NodeTelemetry('busy', [_gpu([(0, 99.0)])])
    sched = ClusterScheduler([busy])
    job = OfflineJob(profile_workload('j', thrput_max=10.0, m_req=1024),
                     sla=0.9)
    assert sched.place(job) is None
    assert job in sched.pending


def test_profile_from_measured_curve_knee_and_monotone():
    mems = [100, 200, 400, 800, 1600]
    thrs = [50, 120, 190, 200, 198]      # tiny inversion at the tail
    w = profile_workload_from_curve('w', mems, thrs, sat_frac=0.95)
    assert w.thrput_max == pytest.approx(200.0)
    assert w.m_req == 400.0              # first point ≥ 0.95 × peak
    assert w.mac > 0
    assert np.all(np.diff(w.thrput_points) >= 0)   # inversion clamped


def test_scheduler_update_node_and_eviction_avoids_old_node():
    sched = ClusterScheduler([NodeTelemetry('a', [_gpu([])])])
    job = OfflineJob(profile_workload('j', thrput_max=10.0, m_req=1024),
                     sla=0.3)
    assert sched.place(job, avoid={'a'}) is None    # only node avoided
    [p] = sched.retry_pending()                     # one-shot: retries may use it
    assert p.node == 'a'
    # evict via persistent violation; FIRST retry avoids the violated node
    for _ in range(sched.cfg.violation_patience):
        sched.report_throughput(job.job_id, 0.0)
    assert sched.evictions == 1
    assert sched.retry_pending() == []              # only 'a' exists → avoided
    # the avoid is one-shot: a recovered old node must not starve the job
    sched.update_node(NodeTelemetry('b', [_gpu([])]))  # refresh adds a node
    [p2] = sched.retry_pending()
    assert p2.node in ('a', 'b')
    assert sched.reschedules == 1


# ---------------------------------------------------------------------------
# Closed-loop harness: NodeSim-measured telemetry through the §6 scheduler
# ---------------------------------------------------------------------------

_SIM = SimConfig(total_pages=1024)


def test_telemetry_from_sim_is_measured_and_sane():
    trace = make_online_trace(name='t', horizon_s=30.0, base_rate=0.3,
                              burst_rate=3.0, prompt_mean=512, seed=3)
    res = run_online_standalone(
        WorkloadPair('t', trace, OfflineWorkload('idle')), _SIM)
    g = telemetry_from_sim(res, window=30.0)
    assert g.source == 'nodesim'
    assert g.busy_intervals, 'online activity must produce busy intervals'
    assert all(0.0 <= a < b for a, b in g.busy_intervals)
    assert 0.0 < p_compute(g) < 1.0
    assert len(g.mem_trace_t) == len(g.mem_trace_free) >= 2
    assert np.all(g.mem_trace_free <= _SIM.total_pages)
    assert np.all(np.diff(g.mem_trace_t) > 0)
    # memory dips below full while requests hold KV
    assert g.mem_trace_free.min() < _SIM.total_pages


def test_profile_workload_from_sim_saturating_curve():
    off = OfflineWorkload('prof', prompt_tokens=256, output_tokens=128,
                          max_batch=32)
    w = profile_workload_from_sim(off, _SIM, horizon_s=8.0,
                                  fractions=(0.1, 0.3, 0.6, 1.0))
    assert w.thrput_max > 0
    assert np.all(np.diff(w.thrput_points) >= 0)
    assert w.mem_points[0] < w.m_req <= w.mem_points[-1]
    # more memory → more throughput at the low end (memory-bound regime)
    assert w.thrput_points[0] < w.thrput_points[-1]


def test_fleet_workloads_alignment_structure():
    fleet = make_fleet_workloads(4, 2, horizon_s=60.0, seed=1,
                                 n_ramp_nodes=1, ramp_at_s=20.0)
    assert len(fleet) == 4 and all(len(n.gpu_traces) == 2 for n in fleet)
    # ramp node heats up after ramp_at_s
    ramp = fleet[0].gpu_traces[0]
    early = sum(1 for r in ramp.requests if r.t_arrive < 20.0)
    late = sum(1 for r in ramp.requests if r.t_arrive >= 20.0)
    assert late > 3 * max(early, 1)
    # slicing rebases to epoch-local time
    sl = slice_trace(ramp, 20.0, 40.0)
    assert sl.horizon_s == pytest.approx(20.0)
    assert all(0.0 <= r.t_arrive < 20.0 for r in sl.requests)


def test_closed_loop_places_from_measured_telemetry():
    cfg = HarnessConfig(n_nodes=3, gpus_per_node=1, epoch_s=30.0,
                        n_epochs=1, sim=_SIM, n_ramp_nodes=0,
                        measure_baseline=False, seed=2)
    h = make_harness(cfg, n_jobs=2)
    h.run()
    assert h.scheduler.placements, 'no job placed'
    for tele in h.scheduler.nodes.values():
        assert all(g.source == 'nodesim' for g in tele.gpus)
    for p in h.scheduler.placements.values():
        assert p.achieved is not None          # monitoring loop reported
        assert p.achieved > 0.0


def test_closed_loop_evicts_and_reschedules_sla_violator():
    """The §6 monitoring plane end to end: a node that was quiet when
    scouted heats up, its jobs' MEASURED achieved throughput falls below
    SLA for violation_patience epochs, they are evicted and successfully
    rescheduled onto healthy nodes where they recover."""
    cfg = HarnessConfig(n_nodes=4, gpus_per_node=2, epoch_s=40.0,
                        n_epochs=4, sim=_SIM, measure_baseline=False,
                        seed=0)
    h = make_harness(cfg)
    ramp_node = h.fleet[0].name
    h.run()
    assert h.scheduler.evictions >= 1
    assert h.scheduler.reschedules >= 1
    # rescheduled jobs ended up off the ramp node and SLA-compliant
    final = h.reports[-1]
    moved = [p for p in h.scheduler.placements.values()
             if p.node != ramp_node and p.job.job_id in final.achieved]
    assert moved
    assert any(final.achieved[p.job.job_id] >= p.job.sla for p in moved)


def test_union_intersection_edge_cases():
    W = (0.0, 100.0)
    # empty interval sets
    assert _union_intersection([], [], W) == (0.0, 0.0)
    assert _union_intersection([(10.0, 20.0)], [], W) == (0.0, 10.0)
    # touching (zero-measure overlap) intervals
    inter, union = _union_intersection([(0.0, 5.0)], [(5.0, 10.0)], W)
    assert inter == 0.0 and union == pytest.approx(10.0)
    # fully nested
    inter, union = _union_intersection([(0.0, 10.0)], [(2.0, 4.0)], W)
    assert inter == pytest.approx(2.0) and union == pytest.approx(10.0)
    # identical sets
    ivs = [(1.0, 3.0), (7.0, 9.0)]
    inter, union = _union_intersection(ivs, list(ivs), W)
    assert inter == union == pytest.approx(4.0)
    # intervals clipped by the window
    inter, union = _union_intersection([(-5.0, 10.0)], [(5.0, 200.0)], W)
    assert inter == pytest.approx(5.0) and union == pytest.approx(100.0)


def test_p_multi_idle_gpus_count_as_aligned():
    """Zero busy time on both GPUs → T_∪ == 0 → perfectly aligned (the gate
    must not reject multi-GPU placement on a fully idle node)."""
    assert p_multi([_gpu([]), _gpu([])]) == 1.0
    assert p_multi([_gpu([(0.0, 1.0)])]) == 1.0          # single GPU


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _iv = st.lists(
        st.tuples(st.floats(0, 99, allow_nan=False),
                  st.floats(0.01, 30, allow_nan=False)).map(
            lambda p: (p[0], min(p[0] + p[1], 100.0))),
        max_size=6)

    @settings(max_examples=60, deadline=None)
    @given(_iv, _iv)
    def test_union_intersection_properties(a, b):
        inter, union = _union_intersection(a, b, (0.0, 100.0))
        assert 0.0 <= inter <= union <= 100.0
        ri, ru = _union_intersection(b, a, (0.0, 100.0))   # symmetric
        assert inter == pytest.approx(ri) and union == pytest.approx(ru)


def test_scheduler_no_double_booking():
    node = NodeTelemetry('n', [_gpu([]), _gpu([])])
    sched = ClusterScheduler([node])
    j1 = OfflineJob(profile_workload('a', thrput_max=10, m_req=512), 0.3)
    j2 = OfflineJob(profile_workload('b', thrput_max=10, m_req=512), 0.3)
    j3 = OfflineJob(profile_workload('c', thrput_max=10, m_req=512), 0.3)
    p1, p2 = sched.place(j1), sched.place(j2)
    assert p1.gpu_indices != p2.gpu_indices
    assert sched.place(j3) is None      # node full
