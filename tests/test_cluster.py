"""Cluster performance model (Eq. 1–2) and scheduler (§6)."""
import numpy as np
import pytest

from repro.core.cluster.perfmodel import (
    GPUTelemetry, NodeTelemetry, admissible, p_compute, p_memory, p_multi,
    predict_normalized_throughput, profile_workload)
from repro.core.cluster.scheduler import ClusterScheduler, OfflineJob


def _gpu(busy, free_frac=0.8, horizon=100.0):
    ts = np.linspace(0, horizon, 16)
    free = np.full_like(ts, free_frac * 4096)
    return GPUTelemetry(busy, ts, free, window=(0, horizon))


def test_p_compute_idle_fraction():
    g = _gpu([(0, 25.0), (50.0, 75.0)])
    assert p_compute(g) == pytest.approx(0.5)


def test_p_memory_monotone_in_free_memory():
    w = profile_workload('w', thrput_max=100.0, m_req=2048)
    lo = p_memory(w, _gpu([], free_frac=0.2))
    hi = p_memory(w, _gpu([], free_frac=0.9))
    assert hi > lo
    assert 0.0 <= lo <= hi <= 1.0


def test_p_memory_deficit_penalty():
    """Dipping below M_req costs MAC_w · E[ΔM] (Eq. 2)."""
    w = profile_workload('w', thrput_max=100.0, m_req=4000)
    tight = p_memory(w, _gpu([], free_frac=0.5))   # 2048 < m_req
    ample = p_memory(w, _gpu([], free_frac=1.0))
    assert tight < ample


def test_p_multi_alignment():
    a = [(0, 10.0), (20.0, 30.0)]
    aligned = [_gpu(a), _gpu(list(a))]
    assert p_multi(aligned) == pytest.approx(1.0)
    disjoint = [_gpu([(0, 10.0)]), _gpu([(10.0, 20.0)])]
    assert p_multi(disjoint) == pytest.approx(0.0)
    # partial overlap
    part = [_gpu([(0, 10.0)]), _gpu([(5.0, 15.0)])]
    assert p_multi(part) == pytest.approx(5.0 / 15.0)


def test_admission_gate_requires_alignment():
    w = profile_workload('mp', thrput_max=100.0, m_req=1024, n_gpus=2)
    misaligned = [_gpu([(0, 10.0)]), _gpu([(40.0, 50.0)])]
    assert not admissible(w, misaligned)
    aligned = [_gpu([(0, 10.0)]), _gpu([(0, 10.0)])]
    assert admissible(w, aligned)


def test_eq1_product_form():
    w = profile_workload('w', thrput_max=100.0, m_req=1024)
    g = _gpu([(0, 50.0)], free_frac=0.9)
    pred = predict_normalized_throughput(w, [g])
    assert pred == pytest.approx(p_compute(g) * p_memory(w, g) * 1.0)


def test_scheduler_places_on_best_node_and_evicts_violators():
    idle = NodeTelemetry('idle', [_gpu([])])
    busy = NodeTelemetry('busy', [_gpu([(0, 90.0)])])
    sched = ClusterScheduler([busy, idle])
    job = OfflineJob(profile_workload('j', thrput_max=10.0, m_req=1024),
                     sla=0.3)
    p = sched.place(job)
    assert p is not None and p.node == 'idle'
    # persistent SLA violation → eviction + requeue
    for _ in range(3):
        sched.report_throughput(job.job_id, achieved_norm=0.1)
    assert sched.evictions == 1
    assert job in sched.pending
    assert job.job_id not in sched.placements


def test_scheduler_queues_unplaceable_jobs():
    busy = NodeTelemetry('busy', [_gpu([(0, 99.0)])])
    sched = ClusterScheduler([busy])
    job = OfflineJob(profile_workload('j', thrput_max=10.0, m_req=1024),
                     sla=0.9)
    assert sched.place(job) is None
    assert job in sched.pending


def test_scheduler_no_double_booking():
    node = NodeTelemetry('n', [_gpu([]), _gpu([])])
    sched = ClusterScheduler([node])
    j1 = OfflineJob(profile_workload('a', thrput_max=10, m_req=512), 0.3)
    j2 = OfflineJob(profile_workload('b', thrput_max=10, m_req=512), 0.3)
    j3 = OfflineJob(profile_workload('c', thrput_max=10, m_req=512), 0.3)
    p1, p2 = sched.place(j1), sched.place(j2)
    assert p1.gpu_indices != p2.gpu_indices
    assert sched.place(j3) is None      # node full
