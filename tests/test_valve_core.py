"""Unit + property tests for the Valve core mechanisms: pool invariants,
Algorithm 1, MIAD, lifecycle."""
import math

import numpy as np
import pytest

# property-based suite: declared in pyproject [test]; skip (not error) when
# the environment lacks it so bare collection stays green
hypothesis = pytest.importorskip('hypothesis')
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import eviction
from repro.core.lifecycle import OnlineLifecycleTracker
from repro.core.miad import MIADConfig, MIADReservation
from repro.serving.kvpool import KVPool, QUARANTINE_PAGE


# ---------------------------------------------------------------------------
# KVPool
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(['alloc_on', 'alloc_off', 'free',
                                           'reclaim', 'reserve', 'release']),
                          st.integers(0, 30)), min_size=1, max_size=60))
def test_pool_invariants_random_ops(ops):
    """Pool invariants hold under arbitrary op sequences: no double-owned
    page, quarantine never owned, free lists consistent."""
    pool = KVPool(n_handles=6, pages_per_handle=4, reserved_handles=2)
    live = []
    for i, (op, arg) in enumerate(ops):
        if op in ('alloc_on', 'alloc_off'):
            rid = f'r{i}'
            got = pool.alloc(rid, (arg % 6) + 1,
                             'online' if op == 'alloc_on' else 'offline')
            if got is not None:
                live.append(rid)
        elif op == 'free' and live:
            pool.free(live.pop(arg % len(live)))
        elif op == 'reclaim':
            offl = pool.offline_handles()
            if offl:
                victims = [offl[arg % len(offl)]]
                inv = pool.reclaim_handles(victims)
                for r in inv:
                    if r in live:
                        live.remove(r)
        elif op == 'reserve':
            empt = pool.empty_offline_handles()
            if empt:
                pool.reserve_handle(empt[arg % len(empt)])
        elif op == 'release':
            pool.release_reserved_handle()
        pool.check_invariants()
    assert pool.owner[QUARANTINE_PAGE] is None


def test_pool_reclaim_frees_whole_victim_request():
    pool = KVPool(4, 4, reserved_handles=1)
    pool.alloc('a', 6, 'offline')   # spans ≥2 handles
    inv = pool.reclaim_handles([pool.offline_handles()[0]])
    assert 'a' in inv
    # request 'a' lost all its pages, including ones outside the handle
    assert 'a' not in pool.pages_of
    pool.check_invariants()


def test_pool_online_reserved_separation():
    pool = KVPool(4, 4, reserved_handles=2)
    # online allocs only from reserved handles, offline only outside
    on = pool.alloc('on', 8, 'online')
    off = pool.alloc('off', 8, 'offline')
    on_handles = {pool.handle_of(p) for p in on}
    off_handles = {pool.handle_of(p) for p in off}
    assert on_handles <= set(pool.reserved)
    assert not (off_handles & set(pool.reserved))
    assert pool.alloc('on2', 1, 'online') is None   # reserved exhausted


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 6), st.integers(2, 8), st.integers(1, 12),
       st.randoms(use_true_random=False))
def test_algorithm1_structure(k, n_handles, n_reqs, rnd):
    """Greedy picks k distinct handles and its FIRST pick has globally
    minimal single-handle token cost (the per-step guarantee)."""
    reqs = {f'r{i}': rnd.randint(1, 100) for i in range(n_reqs)}
    assignment = {h: {r for r in reqs if rnd.random() < 0.4}
                  for h in range(n_handles)}
    cost = lambda r: reqs[r]
    reqs_of = lambda h: assignment[h]
    kk = min(k, n_handles)
    greedy = eviction.select_handles(kk, list(range(n_handles)),
                                     reqs_of, cost)
    assert len(greedy) == kk == len(set(greedy))
    first_cost = sum(reqs[r] for r in assignment[greedy[0]])
    assert first_cost == min(sum(reqs[r] for r in assignment[h])
                             for h in range(n_handles))


def test_algorithm1_beats_fifo_in_aggregate():
    """Across many random fragmented pools, greedy's expected impacted cost
    is well below FIFO's (Fig. 11's 22.9–40.1% claim is an aggregate)."""
    rnd = np.random.default_rng(0)
    g_tot = f_tot = 0.0
    for trial in range(200):
        n_handles, n_reqs = 10, 16
        costs = {f'r{i}': int(rnd.integers(1, 200)) for i in range(n_reqs)}
        assignment = {h: {r for r in costs if rnd.random() < 0.3}
                      for h in range(n_handles)}
        reqs_of = lambda h: assignment[h]
        cost = lambda r: costs[r]
        k = 3
        def total(sel):
            return sum(costs[r] for r in eviction.impacted_requests(
                sel, reqs_of))
        g_tot += total(eviction.select_handles(
            k, list(range(n_handles)), reqs_of, cost))
        f_tot += total(eviction.select_handles_fifo(
            k, list(range(n_handles))))
    assert g_tot < 0.75 * f_tot        # ≥25% aggregate cost reduction


def test_algorithm1_prefers_cheap_handles():
    # handle 0 impacts an expensive request, handle 1 a cheap one, 2 none
    reqs_of = {0: {'big'}, 1: {'small'}, 2: set()}.__getitem__
    cost = {'big': 1000, 'small': 1}.__getitem__
    assert eviction.select_handles(1, [0, 1, 2], reqs_of, cost) == [2]
    assert eviction.select_handles(2, [0, 1, 2], reqs_of, cost) == [2, 1]


def test_algorithm1_marginal_cost_shares_requests():
    """A request already impacted by an earlier pick is free for later
    picks (the E set in the paper's Algorithm 1)."""
    # handles 0,1 share request x (cost 10); handle 2 has y (cost 5)
    reqs_of = {0: {'x'}, 1: {'x'}, 2: {'y'}}.__getitem__
    cost = {'x': 10, 'y': 5}.__getitem__
    sel = eviction.select_handles(2, [0, 1, 2], reqs_of, cost)
    assert sel == [2, 0] or sel == [2, 1] or set(sel) == {0, 1}
    # picking both x-handles costs 10; picking y then an x-handle costs 15 —
    # but greedy picks y (5) first, then an x handle (10) = marginal 10;
    # alternative [0,1] = 10 total.  Verify greedy's total ≤ any pair:
    def total(s):
        return sum(cost(r) for r in eviction.impacted_requests(s, reqs_of))
    best = min(total(p) for p in ([0, 1], [0, 2], [1, 2]))
    assert total(sel) <= best + 5  # greedy is 1-1/e-approx, sanity bound


# ---------------------------------------------------------------------------
# MIAD
# ---------------------------------------------------------------------------

def test_miad_bounds_and_growth():
    cfg = MIADConfig(alpha=2.0, h_max=32)
    m = MIADReservation(h_init=1, cfg=cfg)
    # sustained pressure: H doubles but never exceeds h_max
    for i in range(20):
        h = m.on_tick(float(i), online_used=h_used(m))
        assert 1 <= h <= 32
    assert m.h == 32


def h_used(m):
    return m.h  # always at 100% of reservation → pressured


def test_miad_release_when_idle():
    cfg = MIADConfig(t_init=1.0, t_min=0.5, t_step=0.5, h_max=32)
    m = MIADReservation(h_init=16, cfg=cfg)
    t = 0.0
    for _ in range(100):
        t += 1.0
        m.on_tick(t, online_used=0)
    assert m.h == cfg.h_min            # fully released back to offline


def test_miad_t_controller_tracks_target():
    """Reclamations above target → T grows (hold longer); below → shrinks."""
    cfg = MIADConfig(target_rate=0.1, rate_window=10.0, t_init=1.0,
                     t_max=16.0)
    m = MIADReservation(h_init=4, cfg=cfg)
    t = 0.0
    for _ in range(20):                # 2 reclaims/s >> target
        t += 0.5
        m.note_reclamation(t)
        m.on_tick(t, online_used=0)
    assert m.t > cfg.t_init
    high = m.t
    for _ in range(120):               # silence → rate decays below target
        t += 1.0
        m.on_tick(t, online_used=0)
    assert m.t < high
    assert m.t == cfg.t_min            # fully relaxed


# ---------------------------------------------------------------------------
# Lifecycle / T_cool
# ---------------------------------------------------------------------------

def test_lifecycle_gap_telemetry_and_t_cool():
    lc = OnlineLifecycleTracker(t_cool_init=0.001)
    lc.request_start('r', 0.0)
    t = 0.0
    for _ in range(5):                 # decode iterations with 3ms gaps
        lc.iteration_start(t)
        t += 0.030
        lc.iteration_end(t)
        t += 0.003
    lc.request_end('r', t)
    assert lc.max_gap == pytest.approx(0.003)
    assert lc.t_cool == pytest.approx(0.006)   # 2 × max gap
    # inside cooldown: may not wake
    assert not lc.may_wake_offline(t + 0.004)
    assert lc.may_wake_offline(t + 0.007)


def test_lifecycle_idle_between_requests_is_not_a_gap():
    lc = OnlineLifecycleTracker(t_cool_init=0.001)
    lc.request_start('a', 0.0)
    lc.iteration_start(0.0)
    lc.iteration_end(0.03)
    lc.request_end('a', 0.03)
    # 10 s idle, then a new request — must not register a 10 s "gap"
    lc.request_start('b', 10.0)
    lc.iteration_start(10.0)
    lc.iteration_end(10.03)
    lc.request_end('b', 10.03)
    assert lc.max_gap < 1.0
