"""Async serving front-end: deterministic protocol harness (no sockets).

Everything runs through the in-process ASGI client on a VirtualClock
node — requests, the driver pump, and SSE delivery interleave at event-
loop await points, and waits advance the virtual clock instead of
sleeping.  Covers the PR's acceptance gates:

- concurrent online streams colocated with an offline batch job, with the
  paper's ≤ 1-compute-preemption-per-online-request bound asserted from
  the runtime's typed event log;
- a mid-stream client disconnect provably frees the request's KV lease
  (and its invalidation route dies with it);
- cancelling a still-queued batch job never allocates a page;
- engine-level cancellation keeps ``NodeOrchestrator.drain()`` /
  ``has_work()`` live and is counted in stats;
- batch-job lifecycle (queued → in_progress → completed → results) with
  outputs identical to a direct offline drain;
- request validation and the trace-replay load generator's determinism.

No pytest-asyncio in the container: each test wraps its coroutine in
``asyncio.run``.
"""
import asyncio

import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.events import PreemptionEvent
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.launch.node import NodeOrchestrator
from repro.serving.engine import EngineConfig
from repro.serving.frontend.app import FrontendApp, token_text
from repro.serving.frontend.driver import AsyncNodeDriver, clock_sleep
from repro.serving.frontend.loadgen import (
    LoadGenerator, TraceEntry, make_online_trace)
from repro.serving.frontend.testing import ASGIClient
from repro.serving.kvpool import KVPool
from repro.serving.scheduler import ReqState

ONLINE_ARCH = 'qwen3-0.6b'
OFFLINE_ARCHS = ('internlm2-1.8b', 'qwen3-0.6b')
# every reduced config in play shares this vocab (prompts must be valid
# ids for whichever engine they land on)
VOCAB = reduced(get_config(ONLINE_ARCH), page_size=4).vocab_size

# every async scenario is wall-clock-free; this bounds a livelocked pump
TIMEOUT_S = 120


def _ecfg(klass):
    return EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                        klass=klass)


def _node(*, pool_handles=5, pph=4, offline=True):
    pool = KVPool(pool_handles, pph, page_size=4, reserved_handles=1)
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=VirtualClock())
    node = NodeOrchestrator(rt, idle_advance=1e-3)
    node.add_engine(reduced(get_config(ONLINE_ARCH), page_size=4),
                    _ecfg('online'), seed=0, name='online')
    if offline:
        for i, arch in enumerate(OFFLINE_ARCHS):
            node.add_engine(reduced(get_config(arch), page_size=4),
                            _ecfg('offline'), seed=10 + i, name=f'off{i}')
    return node


def _prompt(vocab, n, seed):
    return np.random.default_rng(seed).integers(1, vocab, n).tolist()


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, TIMEOUT_S))


async def _poll_batch(client, bid, *, until, clock, max_polls=20000):
    """Poll a batch's status until ``until``; the pump runs between polls.
    Returns every status string observed (for lifecycle assertions)."""
    seen = []
    for _ in range(max_polls):
        resp = await client.get(f'/v1/batches/{bid}')
        assert resp.status == 200
        seen.append(resp.json()['status'])
        if seen[-1] == until:
            return seen
        await clock_sleep(clock, 1e-4)
    raise AssertionError(f'batch never reached {until!r}: {seen[-5:]}')


# ---------------------------------------------------------------------------
# Colocation under the preemption bound
# ---------------------------------------------------------------------------

def test_concurrent_streams_with_batch_under_preemption_bound():
    """≥4 concurrent online SSE streams land on a node whose offline
    engines are mid-batch; everything completes, and the event log shows
    no online request preempted offline compute more than once."""
    node = _node(pool_handles=6)
    vocab = node.online.mcfg.vocab_size

    async def scenario():
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            # offline batch first: its items hold live pages when the
            # online burst arrives, so admission forces reclamation
            batch = await client.post('/v1/batches', json={'requests': [
                {'prompt': _prompt(vocab, 12, 100 + i), 'max_tokens': 8}
                for i in range(4)]})
            assert batch.status == 200
            bid = batch.json()['id']
            await _poll_batch(client, bid, until='in_progress',
                              clock=node.clock)

            async def one_stream(i):
                sr = client.stream('POST', '/v1/completions',
                                   json={'prompt': _prompt(vocab, 10, i),
                                         'max_tokens': 6, 'stream': True})
                toks = []
                async with sr:
                    assert sr.status == 200
                    async for ev in sr.events():
                        if ev.done:
                            break
                        import json as _json
                        c = _json.loads(ev.data)['choices'][0]
                        if c.get('token') is not None:
                            toks.append(c['token'])
                return toks

            results = await asyncio.gather(*(one_stream(i)
                                             for i in range(4)))
            statuses = await _poll_batch(client, bid, until='completed',
                                         clock=node.clock)
            return results, statuses

    results, statuses = _run(scenario())
    assert all(len(t) == 6 for t in results), [len(t) for t in results]
    assert statuses[-1] == 'completed'

    # the paper's bound, read from the typed event log — not from a
    # summary counter: fold PreemptionEvent.requests per online request
    preempts = node.runtime.bus.events(PreemptionEvent)
    assert len(preempts) >= 1          # colocation actually contended
    per_req = {}
    for ev in preempts:
        for rid in ev.requests:
            per_req[rid] = per_req.get(rid, 0) + 1
    assert per_req and max(per_req.values()) <= 1, per_req
    tel = node.runtime.telemetry.snapshot()
    assert tel['max_preemptions_per_request'] <= 1
    node.runtime.check_invariants()
    node.pool.check_invariants()
    assert node.runtime.invalidation_routes() == []


# ---------------------------------------------------------------------------
# Cancellation / leak regressions
# ---------------------------------------------------------------------------

def test_disconnect_mid_stream_releases_lease_and_routes():
    """Client drops the SSE connection after the first tokens: the
    request's lease frees on the spot, its invalidation route dies with
    it, and the node keeps serving."""
    node = _node(offline=False)
    vocab = node.online.mcfg.vocab_size
    # reservation-independent leak check: total free pages across ALL
    # handles (MIAD legitimately moves handles between reserved/offline)
    free0 = sum(len(d) for d in node.pool.free_in_handle)

    async def scenario():
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            sr = client.stream('POST', '/v1/completions',
                               json={'prompt': _prompt(vocab, 8, 1),
                                     'max_tokens': 24, 'stream': True})
            async with sr:
                got = 0
                async for ev in sr.events():
                    if not ev.done:
                        got += 1
                    if got >= 2:
                        break
                await sr.disconnect()      # mid-stream hang-up
            # the app handler observed the disconnect and unwound; give
            # the pump one tick to settle bookkeeping
            await clock_sleep(node.clock, 1e-3)
            assert driver.stats.streams_cancelled == 1

            # the node still serves: a fresh request completes normally
            resp = await client.post('/v1/completions',
                                     json={'prompt': _prompt(vocab, 8, 2),
                                           'max_tokens': 4})
            assert resp.status == 200
            return resp.json()

    completion = _run(scenario())
    assert completion['choices'][0]['finish_reason'] == 'length'
    assert len(completion['choices'][0]['tokens']) == 4

    (cancelled,) = [r for r in node.online.requests.values()
                    if r.state is ReqState.CANCELLED]
    assert cancelled.lease is None and cancelled.pages == []
    assert node.runtime.memory.live_leases('online') == []
    assert node.runtime.invalidation_routes() == []
    assert sum(len(d) for d in node.pool.free_in_handle) == free0
    assert node.metrics()['cancellations'] == 1
    node.runtime.check_invariants()
    node.pool.check_invariants()


def test_cancel_queued_batch_never_allocates():
    """Admission is deferred to scheduler admission, and the gates stay
    closed while an online request is in flight — so a batch cancelled
    while still queued provably never leased a page."""
    node = _node()
    vocab = node.online.mcfg.vocab_size

    async def scenario():
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            # a long online stream holds the gates closed
            sr = client.stream('POST', '/v1/completions',
                               json={'prompt': _prompt(vocab, 8, 5),
                                     'max_tokens': 24, 'stream': True})
            async with sr:
                it = sr.events()
                await it.__anext__()       # online is live → gates closed

                batch = await client.post('/v1/batches', json={'requests': [
                    {'prompt': _prompt(vocab, 12, 50 + i), 'max_tokens': 8}
                    for i in range(3)]})
                bid = batch.json()['id']
                assert batch.json()['status'] == 'queued'
                # gated: no offline lease exists anywhere
                assert node.runtime.memory.live_leases('offline') == []
                assert all(e.stats.dispatches == 0 for e in node.offline)

                resp = await client.post(f'/v1/batches/{bid}/cancel')
                assert resp.json()['status'] == 'cancelled'
                assert resp.json()['request_counts']['cancelled'] == 3

                # the stream finishes undisturbed
                async for ev in it:
                    pass
            res = await client.get(f'/v1/batches/{bid}/results')
            return res

    res = _run(scenario())
    assert res.status == 200
    assert all(r['status'] == 'cancelled' and r['tokens'] == []
               for r in res.json()['results'])
    # never allocated: no offline engine ever dispatched or leased
    assert all(e.stats.dispatches == 0 for e in node.offline)
    assert all(r.lease is None and r.pages == []
               for e in node.offline for r in e.requests.values())
    assert node.runtime.memory.live_leases('offline') == []
    assert sum(e.stats.cancellations for e in node.offline) == 3
    assert node.runtime.invalidation_routes() == []
    node.runtime.check_invariants()
    node.pool.check_invariants()


def test_engine_cancel_keeps_drain_live_and_counts():
    """Cancelling queued AND running requests leaves the node loop live:
    ``drain()`` terminates without a watchdog stall, ``has_work()`` goes
    False, and cancellations are counted (the liveness regression for the
    cancellation path)."""
    node = _node(offline=False)
    eng = node.online
    vocab = eng.mcfg.vocab_size
    rids = [eng.submit(_prompt(vocab, 8, i), max_new_tokens=4)
            for i in range(6)]              # max_batch=4 → 2 stay queued
    for _ in range(3):
        node.step()
    running = [r for r in rids if r in eng.running]
    queued = [r for r in rids if r in eng.queue]
    assert running and queued
    assert eng.cancel(running[0]) and eng.cancel(queued[-1])
    assert eng.cancel(running[0]) is False          # idempotent
    assert eng.cancel('no-such-request') is False

    node.drain(max_steps=2000)                      # must not stall
    assert not node.has_work()
    assert eng.stats.cancellations == 2
    assert len(eng.finished) == 4
    for rid in (running[0], queued[-1]):
        assert eng.requests[rid].state is ReqState.CANCELLED
        assert eng.requests[rid].lease is None
    assert node.metrics()['cancellations'] == 2
    assert node.runtime.invalidation_routes() == []
    node.runtime.check_invariants()
    node.pool.check_invariants()


# ---------------------------------------------------------------------------
# Batch-job lifecycle
# ---------------------------------------------------------------------------

def test_batch_lifecycle_and_result_fidelity():
    """queued → in_progress → completed; results are refused (409) before
    the job is terminal and match a direct offline drain afterwards."""
    specs = [{'prompt': _prompt(VOCAB, 10, 200 + i), 'max_tokens': 5}
             for i in range(3)]

    # reference: same prompts fed straight to a fresh node's offline
    # engines in BatchManager's round-robin order, drained synchronously
    ref = _node()
    ref_out = []
    ref_rids = [(ref.offline[i % len(ref.offline)],
                 ref.offline[i % len(ref.offline)].submit(
                     s['prompt'], s['max_tokens']))
                for i, s in enumerate(specs)]
    ref.drain(max_steps=5000)
    ref_out = [e.output_tokens(r) for e, r in ref_rids]

    node = _node()

    async def scenario():
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            sub = await client.post('/v1/batches', json={'requests': specs})
            assert sub.status == 200
            job = sub.json()
            assert job['status'] == 'queued'
            assert job['request_counts'] == {
                'total': 3, 'queued': 3, 'in_progress': 0,
                'completed': 0, 'cancelled': 0}
            early = await client.get(f'/v1/batches/{job["id"]}/results')
            assert early.status == 409                 # not terminal yet
            statuses = await _poll_batch(client, job['id'],
                                         until='completed',
                                         clock=node.clock)
            res = await client.get(f'/v1/batches/{job["id"]}/results')
            return statuses, res.json()

    statuses, results = _run(scenario())
    assert 'in_progress' in statuses
    assert results['object'] == 'batch.results'
    by_index = sorted(results['results'], key=lambda r: r['index'])
    assert [r['tokens'] for r in by_index] == ref_out
    assert all(r['status'] == 'completed'
               and r['text'] == token_text(r['tokens'])
               for r in by_index)
    # heterogeneous placement: round-robin used both offline models
    assert len({r['engine'] for r in by_index}) == 2


# ---------------------------------------------------------------------------
# Validation + non-streaming parity
# ---------------------------------------------------------------------------

def test_request_validation_and_routing():
    node = _node()
    vocab = node.online.mcfg.vocab_size

    async def scenario():
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            bad = [
                ({'max_tokens': 4}, 400),                    # no prompt
                ({'prompt': [], 'max_tokens': 4}, 400),      # empty
                ({'prompt': ['a'], 'max_tokens': 4}, 400),   # not ids
                ({'prompt': [1, 2], 'max_tokens': 0}, 400),  # bad budget
                ({'prompt': [1] * 47, 'max_tokens': 9}, 400),  # > max_seq
                ({'prompt': [vocab + 7], 'max_tokens': 4}, 400),  # vocab
            ]
            for body, want in bad:
                resp = await client.post('/v1/completions', json=body)
                assert resp.status == want, (body, resp.status)
                assert 'error' in resp.json()
            for body in ({}, {'requests': []},
                         {'requests': [{'max_tokens': 4}]},
                         {'requests': [{'prompt': [1], 'max_tokens': 99}]}):
                resp = await client.post('/v1/batches', json=body)
                assert resp.status == 400, body
            assert (await client.get('/v1/batches/nope')).status == 404
            assert (await client.post('/v1/batches/nope/cancel')
                    ).status == 404
            assert (await client.get('/v1/nowhere')).status == 404
            health = await client.get('/healthz')
            assert health.status == 200
            assert health.json()['online'] is True
            metrics = await client.get('/v1/metrics')
            assert metrics.status == 200
            assert 'cancellations' in metrics.json()
            # nothing above ever reached an engine
            assert node.online.stats.dispatches == 0

    _run(scenario())


def test_nonstream_completion_matches_streamed_text():
    """``stream: false`` returns exactly the text a streaming client
    would reassemble from its deltas (same seed, fresh nodes)."""
    import json as _json
    prompt = _prompt(VOCAB, 9, 77)

    async def non_stream():
        node = _node(offline=False)
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            resp = await client.post('/v1/completions',
                                     json={'prompt': prompt,
                                           'max_tokens': 5})
            assert resp.status == 200
            body = resp.json()
            assert body['usage'] == {'prompt_tokens': 9,
                                     'completion_tokens': 5}
            return body['choices'][0]['text']

    async def streamed():
        node = _node(offline=False)
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            sr = client.stream('POST', '/v1/completions',
                               json={'prompt': prompt, 'max_tokens': 5,
                                     'stream': True})
            parts = []
            async with sr:
                async for ev in sr.events():
                    if ev.done:
                        break
                    c = _json.loads(ev.data)['choices'][0]
                    if c.get('token') is not None:
                        parts.append(c['text'])
            return ''.join(parts)

    assert _run(non_stream()) == _run(streamed())


# ---------------------------------------------------------------------------
# Trace-replay load generator
# ---------------------------------------------------------------------------

def _replay_once():
    node = _node(pool_handles=8)

    async def scenario():
        async with AsyncNodeDriver(node) as driver:
            client = ASGIClient(FrontendApp(driver))
            gen = LoadGenerator(client, node.clock,
                                vocab_size=node.online.mcfg.vocab_size)
            trace = make_online_trace(6, horizon_s=0.5, prompt_len=8,
                                      max_new_tokens=4, seed=9)
            trace.append(TraceEntry(t=0.05, kind='batch', n_requests=2,
                                    prompt_len=8, max_new_tokens=4,
                                    seed=99))
            return await gen.replay(trace)

    report = _run(scenario())
    node.runtime.check_invariants()
    return report


def test_loadgen_replay_is_deterministic():
    """The load generator paces on the virtual clock: two replays of the
    same trace on fresh nodes produce the SAME report, TTFTs included —
    the property that makes benchmark regressions attributable."""
    a, b = _replay_once(), _replay_once()
    assert a.n_online == 6 and a.completed == 6 and a.failed == 0
    assert a.batch_jobs == 1
    assert a.peak_concurrent_streams >= 2     # the front-loaded burst
    assert a.tokens_streamed == 24
    assert a.requests_per_s > 0
    assert a.ttft_pct(99) is not None and a.ttft_pct(99) > 0
    assert a.to_dict() == b.to_dict()


# ---------------------------------------------------------------------------
# Disaggregated plane: cancel during the prefill→decode handoff
# ---------------------------------------------------------------------------

def _disagg_plane():
    """Minimal two-node disagg plane (online engines only) — the driver
    runs over it through the same duck-typed node surface."""
    from repro.serving.disagg import DisaggPlane
    clock = VirtualClock()

    def side(name, reserved):
        pool = KVPool(6, 4, page_size=4, reserved_handles=reserved,
                      name=name)
        rt = ValveRuntime(pool,
                          RuntimeConfig(n_devices=1, t_cool_init=0.002),
                          clock=clock)
        node = NodeOrchestrator(rt, idle_advance=1e-3, disaggregated=True)
        node.add_engine(reduced(get_config(ONLINE_ARCH), page_size=4),
                        _ecfg('online'), seed=0, name=f'{name}-online')
        return node

    return DisaggPlane(side('prefill', 2), side('decode', 5))


def test_cancel_during_handoff_leaks_nothing_on_either_pool():
    """A client disconnect in EITHER handoff window — (a) prefill done
    but the lease still on the prefill pool, (b) already migrated and
    queued on the decode engine but not yet admitted — must release the
    lease on whichever pool holds it: no page, lease, or invalidation
    route survives on either side."""
    plane = _disagg_plane()
    vocab = plane.online.mcfg.vocab_size
    pe, de = plane.prefill.online, plane.decode.online
    free0 = [sum(len(d) for d in p.free_in_handle)
             for p in (plane.prefill.pool, plane.decode.pool)]

    async def scenario():
        driver = AsyncNodeDriver(plane)    # no pump: windows stepped by hand

        # --- window (a): RUNNING on prefill, handoff pump not yet run ---
        s1 = driver.submit_stream(_prompt(vocab, 8, 31), max_new_tokens=8)
        for _ in range(200):
            if (s1.req_id in pe.requests
                    and pe.requests[s1.req_id].state is ReqState.RUNNING):
                break
            plane.prefill.step()
        assert pe.requests[s1.req_id].state is ReqState.RUNNING
        assert plane.stats.handoffs == 0
        assert plane.prefill.runtime.memory.live_leases('online') \
            == [s1.req_id]
        assert driver.cancel_stream(s1.req_id)
        await s1.collect()
        assert s1.finish_reason == 'cancelled'
        assert pe.requests[s1.req_id].state is ReqState.CANCELLED
        assert de.requests == {}           # never reached the decode side

        # --- window (b): migrated to decode, queued, not yet admitted ---
        s2 = driver.submit_stream(_prompt(vocab, 8, 32), max_new_tokens=8)
        for _ in range(200):
            plane.prefill.step()
            plane._pump_handoffs()
            if s2.req_id in de.queue:
                break
        assert s2.req_id in de.queue and s2.req_id not in pe.requests
        assert plane.stats.handoffs == 1
        # the migrated lease lives on the DECODE plane now
        assert plane.prefill.runtime.memory.live_leases('online') == []
        assert plane.decode.runtime.memory.live_leases('online') \
            == [s2.req_id]
        assert driver.cancel_stream(s2.req_id)
        await s2.collect()
        assert s2.finish_reason == 'cancelled'
        assert de.requests[s2.req_id].state is ReqState.CANCELLED
        assert driver.stats.streams_cancelled == 2

    _run(scenario())
    # nothing leaked on EITHER pool: every page back, no live lease, no
    # invalidation route pinning reserved KV
    for node, f0 in zip((plane.prefill, plane.decode), free0):
        assert sum(len(d) for d in node.pool.free_in_handle) == f0
        assert node.runtime.memory.live_leases('online') == []
        assert node.runtime.invalidation_routes() == []
    plane.check_invariants()
