"""WKV6 kernel vs sequential + chunked oracles, interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_chunked, wkv6_ref

CASES = [
    # (B, T, H, K, chunk, dtype)
    (2, 64, 2, 16, 16, jnp.float32),
    (1, 128, 4, 32, 32, jnp.float32),
    (2, 100, 2, 16, 32, jnp.float32),      # unaligned T
    (1, 64, 2, 64, 16, jnp.bfloat16),
    (3, 48, 1, 16, 64, jnp.float32),       # chunk > T
]


def _setup(case, seed, decay_lo=-2.5):
    b, t, h, dk, chunk, dtype = case
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(b, t, h, dk)) * 0.5, dtype)
    k = jnp.asarray(rng.normal(size=(b, t, h, dk)) * 0.5, dtype)
    v = jnp.asarray(rng.normal(size=(b, t, h, dk)) * 0.5, dtype)
    # log-decays in [decay_lo, ~0): the range trained RWKV6 models occupy
    # (w = exp(-exp(x))); the chunked form's f32 envelope is
    # |decay_lo|·chunk/2 ≲ 85 nats — see kernels/rwkv6/kernel.py
    logw = rng.uniform(decay_lo, -0.005, size=(b, t, h, dk))
    w = jnp.asarray(np.exp(logw), dtype)
    u = jnp.asarray(rng.normal(size=(h, dk)) * 0.3, dtype)
    s0 = jnp.asarray(rng.normal(size=(b, h, dk, dk)) * 0.1, jnp.float32)
    return r, k, v, w, u, s0


@pytest.mark.parametrize('case', CASES)
def test_wkv6_kernel_matches_sequential_ref(case):
    r, k, v, w, u, s0 = _setup(case, hash(case) % 2**32)
    chunk = case[4]
    y, s = wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    y_ref, s_ref = wkv6_ref(f32(r), f32(k), f32(v), f32(w), f32(u), s0)
    tol = 3e-2 if r.dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=tol, atol=tol)


def test_wkv6_kernel_matches_chunked_oracle():
    case = (2, 96, 2, 32, 32, jnp.float32)
    r, k, v, w, u, s0 = _setup(case, 11)
    y, s = wkv6(r, k, v, w, u, s0, chunk=32, interpret=True)
    y_o, s_o = wkv6_chunked(r, k, v, w, u, s0, chunk=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_o),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_o),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_pathological_decay_small_chunk():
    """Extreme decays (log w ≈ -12/step) stay finite and accurate at small
    chunks, where |decay_lo|·chunk/2 stays inside the f32 envelope."""
    case = (1, 64, 2, 16, 8, jnp.float32)
    r, k, v, w, u, s0 = _setup(case, 3, decay_lo=-12.0)
    y, s = wkv6(r, k, v, w, u, s0, chunk=8, interpret=True)
    assert np.all(np.isfinite(np.asarray(y)))
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    y_ref, s_ref = wkv6_ref(f32(r), f32(k), f32(v), f32(w), f32(u), s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-3, atol=1e-3)


def test_wkv6_state_streaming_composition():
    """Running T tokens once == running two halves with carried state."""
    case = (1, 64, 2, 16, 16, jnp.float32)
    r, k, v, w, u, s0 = _setup(case, 5)
    y_full, s_full = wkv6(r, k, v, w, u, s0, chunk=16, interpret=True)
    half = 32
    y1, s1 = wkv6(r[:, :half], k[:, :half], v[:, :half], w[:, :half],
                  u, s0, chunk=16, interpret=True)
    y2, s2 = wkv6(r[:, half:], k[:, half:], v[:, half:], w[:, half:],
                  u, s1, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-5, atol=1e-5)
