"""Disaggregated prefill/decode serving plane (repro.serving.disagg).

The contract under test: a DisaggPlane — prefill and decode as two full
Valve nodes over separate KV pools, joined by migration-based KV handoff —
drains the same online trace to BIT-IDENTICAL outputs as a colocated
single-pool node, with ZERO prefill tokens recomputed at any handoff,
while both pools keep the paper's ≤ 1-preemption-per-(request, device)
bound and refusals degrade to the colocated fallback instead of erroring.
"""
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.events import PageMigration, PrefillHandoff, ReclamationEvent
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.launch.node import NodeOrchestrator
from repro.serving.disagg import DisaggPlane
from repro.serving.engine import EngineConfig
from repro.serving.kvpool import KVPool
from repro.serving.scheduler import ReqState

ARCH = 'qwen3-0.6b'


def _ecfg(klass):
    return EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                        klass=klass)


def _prompt(vocab, n, seed):
    return np.random.default_rng(seed).integers(1, vocab, n).tolist()


def _valve_node(pool, clock, *, disaggregated=False, offline=True,
                prefix=''):
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=clock)
    node = NodeOrchestrator(rt, idle_advance=1e-3,
                            disaggregated=disaggregated)
    cfg = reduced(get_config(ARCH), page_size=4)
    node.add_engine(cfg, _ecfg('online'), seed=0, name=f'{prefix}online')
    if offline:
        node.add_engine(cfg, _ecfg('offline'), seed=0,
                        name=f'{prefix}off')
    return node


def _plane(*, prefill_handles=8, prefill_reserved=4,
           decode_handles=8, decode_reserved=6, offline=True):
    """Two disaggregated Valve nodes sharing one virtual timeline.  The
    decode pool's reservation is sized generously: migrated online leases
    land via ``KVPool.alloc`` on the reserved region directly (no
    pressure-reclaim on that path), so a tight reservation turns handoffs
    into deferrals — which is exactly what the deferral test shrinks it
    for."""
    clock = VirtualClock()
    prefill = _valve_node(
        KVPool(prefill_handles, 4, page_size=4,
               reserved_handles=prefill_reserved, name='prefill'),
        clock, disaggregated=True, offline=offline, prefix='p-')
    decode = _valve_node(
        KVPool(decode_handles, 4, page_size=4,
               reserved_handles=decode_reserved, name='decode'),
        clock, disaggregated=True, offline=offline, prefix='d-')
    return DisaggPlane(prefill, decode)


def _colocated(*, offline=True):
    return _valve_node(
        KVPool(8, 4, page_size=4, reserved_handles=4, name='colo'),
        VirtualClock(), offline=offline)


def _online_trace(target, n=3):
    vocab = target.online.mcfg.vocab_size
    return [target.online.submit(_prompt(vocab, 12, 40 + i),
                                 max_new_tokens=8) for i in range(n)]


def _outputs(target, rids):
    out = []
    for rid in rids:
        eng = target.engine_of(rid) if hasattr(target, 'engine_of') \
            else target.online
        out.append(eng.output_tokens(rid))
    return out


# ---------------------------------------------------------------------------
# The headline contract: bit-identical, zero recompute
# ---------------------------------------------------------------------------

def test_handoff_bit_identical_zero_recompute():
    ref = _colocated()
    ref_rids = _online_trace(ref)
    ref.drain(max_steps=5000)
    ref_out = _outputs(ref, ref_rids)
    assert all(len(t) == 8 for t in ref_out)

    plane = _plane()
    rids = _online_trace(plane)
    plane.drain(max_steps=5000)

    # every request handed off exactly once, prefill → decode
    assert plane.stats.handoffs == len(rids)
    assert plane.stats.handoffs_deferred == 0
    assert [sp for _, sp, _ in plane.handoffs] == ['prefill'] * len(rids)
    assert [dp for _, _, dp in plane.handoffs] == ['decode'] * len(rids)

    # ... and finished ON the decode engine with the colocated outputs:
    # greedy decode would diverge on any lost or recomputed-from-wrong-
    # state token, so equality is the end-to-end correctness witness
    de = plane.decode.online
    for rid in rids:
        assert plane.engine_of(rid) is de
        assert de.requests[rid].state is ReqState.FINISHED
        assert de.requests[rid].recomputes == 0
    assert _outputs(plane, rids) == ref_out
    assert len(plane.prefill.online.finished) == 0

    # zero-recompute handoff, from every vantage point: the engine never
    # charged a recomputed token, the telemetry fold saw none, and each
    # PrefillHandoff event carried 0
    assert de.stats.tokens_recomputed == 0
    for node in (plane.prefill, plane.decode):
        snap = node.runtime.telemetry.snapshot()
        assert snap['prefill_handoffs'] == len(rids)
        assert snap['handoff_recompute_tokens'] == 0
        assert snap['handoff_pages'] == plane.stats.pages_copied
        assert snap['handoff_latency']['count'] == len(rids)
        evs = node.runtime.bus.events(PrefillHandoff)
        assert len(evs) == len(rids)
        for ev in evs:
            assert ev.recompute_tokens == 0
            assert ev.src_pool == 'prefill' and ev.dst_pool == 'decode'
            assert ev.pages_copied > 0 and ev.latency_s >= 0.0

    # the data plane actually moved pages (a 12-token prompt + first
    # token = 4 pages minimum per request)
    migs = [e for e in plane.prefill.runtime.bus.events(PageMigration)
            if e.cross_pool]
    assert len(migs) == len(rids)
    assert plane.stats.pages_copied == sum(e.n_pages for e in migs) > 0

    # nothing lingers on either pool: leases released, routes dead
    for node in (plane.prefill, plane.decode):
        assert node.runtime.memory.live_leases('online') == []
        assert node.runtime.invalidation_routes() == []
    plane.check_invariants()

    m = plane.metrics()
    assert m['online_finished'] == len(rids)
    assert m['handoffs'] == len(rids)
    assert m['handoff_recompute_tokens'] == 0
    assert m['max_preemptions_per_request'] <= 1


# ---------------------------------------------------------------------------
# Refusal == deferral (the colocated fallback)
# ---------------------------------------------------------------------------

def test_no_capacity_refusal_defers_to_colocated_fallback():
    """With the decode reservation too small for even one lease, every
    handoff attempt is refused ('no-capacity', source untouched) — the
    request completes on the prefill engine with the colocated output."""
    ref = _colocated()
    ref_rids = _online_trace(ref, n=1)
    ref.drain(max_steps=5000)
    ref_out = _outputs(ref, ref_rids)

    plane = _plane(decode_reserved=1)     # 4 reserved pages < 5 needed
    rids = _online_trace(plane, n=1)
    plane.drain(max_steps=5000)

    assert plane.stats.handoffs == 0
    assert plane.stats.handoffs_deferred > 0
    assert plane.prefill.runtime.memory.stats.migration_refusals == \
        plane.stats.handoffs_deferred
    pe = plane.prefill.online
    assert plane.engine_of(rids[0]) is pe
    assert pe.requests[rids[0]].state is ReqState.FINISHED
    assert _outputs(plane, rids) == ref_out
    assert pe.stats.tokens_recomputed == 0
    assert plane.decode.online.requests == {}
    for node in (plane.prefill, plane.decode):
        assert node.runtime.memory.live_leases('online') == []
        assert node.runtime.invalidation_routes() == []
    plane.check_invariants()


# ---------------------------------------------------------------------------
# Both pools backfill; the preemption bound holds per (request, device)
# ---------------------------------------------------------------------------

def test_offline_backfill_on_both_pools_under_preemption_bound():
    plane = _plane()
    vocab = plane.online.mcfg.vocab_size
    off_rids = []
    for node in (plane.prefill, plane.decode):
        eng = node.offline[0]
        off_rids.append((eng, eng.submit(_prompt(vocab, 8, 7),
                                         max_new_tokens=8)))
    for _ in range(4):                    # offline decode under way
        plane.step()
    rids = _online_trace(plane, n=2)
    plane.drain(max_steps=20000)

    assert plane.stats.handoffs == len(rids)
    assert all(len(plane.engine_of(r).output_tokens(r)) == 8 for r in rids)
    # offline work finished on BOTH pools — the prefill side harvested
    # its own post-handoff idleness, the decode side its pre-handoff one
    for eng, rid in off_rids:
        assert eng.requests[rid].state is ReqState.FINISHED
        assert len(eng.output_tokens(rid)) == 8
    assert all(e.stats.tokens_generated > 0 for e in plane.offline)

    # each runtime's gates closed for its own online phase and woke after
    # T_cool; the §4.2 bound holds per (request, device) — devices are
    # disjoint between the nodes, so per-runtime checks compose
    for node in (plane.prefill, plane.decode):
        snap = node.runtime.telemetry.snapshot()
        assert snap['compute_preemptions'] >= 1
        assert snap['offline_wakeups'] >= 1
        assert snap['max_preemptions_per_request'] <= 1
    plane.check_invariants()


# ---------------------------------------------------------------------------
# Cross-pool rescue between the nodes (reclamation victims migrate too)
# ---------------------------------------------------------------------------

def test_cross_rescue_between_disagg_pools_zero_recompute():
    """With cross-rescue enabled, an online burst on the tight prefill
    pool rescues its offline victims to the decode pool — whole lease,
    zero recompute, bit-equal continuation on the decode offline engine —
    and the reclamation log proves copy-before-reallocation."""
    def run(disturb):
        plane = _plane(prefill_handles=5, prefill_reserved=1,
                       decode_reserved=4)
        plane.enable_cross_rescue()
        vocab = plane.online.mcfg.vocab_size
        eng = plane.prefill.offline[0]
        rids = [eng.submit(_prompt(vocab, 12, 70 + i), max_new_tokens=8)
                for i in range(2)]
        for _ in range(4):
            plane.step()
        if disturb:
            # 28-token prompt + 12 new = 10 pages >> the 4-page prefill
            # reservation → reclamation takes offline handles → rescue
            on = plane.submit(_prompt(vocab, 28, 99), max_new_tokens=12)
            plane.drain(max_steps=20000)
            assert len(plane.engine_of(on).output_tokens(on)) == 12
        else:
            plane.drain(max_steps=20000)
        return plane, rids

    ref_plane, ref_rids = run(disturb=False)
    ref_out = _outputs(ref_plane, ref_rids)

    plane, rids = run(disturb=True)
    assert plane.stats.rescues >= 1
    rescued = {e.owner for e
               in plane.prefill.runtime.bus.events(PageMigration)
               if e.cross_pool and e.src_pool == 'prefill'
               and e.owner in set(rids)}
    assert rescued

    dst = plane.decode.offline[0]
    for rid in rescued:
        assert plane.engine_of(rid) is dst
        assert dst.requests[rid].recomputes == 0
    assert dst.stats.tokens_recomputed == 0
    assert _outputs(plane, rids) == ref_out

    # the ReclamationEvent names the rescued victims, and the ordering
    # check (inside check_invariants) proves each had its data-plane copy
    # published BEFORE the reclamation freed the source pages
    recl = plane.prefill.runtime.bus.events(ReclamationEvent)
    named = {r for ev in recl for r in ev.rescued}
    assert rescued <= named
    for ev in recl:
        assert not (set(ev.requests) & rescued)
    plane.check_invariants()


def test_pair_cheapest_picks_cheapest_link_and_records_it():
    """Topology-aware pool pairing (cluster placement plane): the plane is
    built over the candidate pair whose KV-handoff link is cheapest, and
    the chosen link is recorded in plane.link / metrics."""
    from repro.core.cluster.placement import TopologyModel

    clock = VirtualClock()

    def node(pool_name):
        return _valve_node(
            KVPool(8, 4, page_size=4, reserved_handles=4, name=pool_name),
            clock, disaggregated=True, offline=False,
            prefix=f'{pool_name}-')

    pre_far, pre_near = node('pre-far'), node('pre-near')
    dec = node('dec')
    topo = TopologyModel(rack_of={'pA': 1, 'pB': 0, 'dX': 0})
    plane = DisaggPlane.pair_cheapest(
        {'pA': pre_far, 'pB': pre_near}, {'dX': dec}, topo)
    # pB shares dX's rack: node-local beats pA's cross-rack link
    assert plane.prefill is pre_near and plane.decode is dec
    assert plane.link == ('pB', 'dX', 'node-local',
                          topo.link_costs['node-local'])
    assert plane.metrics()['handoff_link'] == plane.link
