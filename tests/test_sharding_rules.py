"""logical_to_spec edge cases over SERVE_RULES/TRAIN_RULES on 1/2/3-axis
meshes (no hypothesis dependency — test_sharding.py skips without it).

The contract under test: a rule mapping to a tuple whose axes are *all*
absent from the mesh resolves to ``None`` (replicated) — never an empty
tuple, never a name the mesh does not provide.
"""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (SERVE_RULES, TRAIN_RULES,
                                        logical_to_spec)


def _mesh_of(axis_names):
    devs = np.asarray(jax.devices()[:1]).reshape((1,) * len(axis_names))
    return Mesh(devs, axis_names)


MESHES = {
    1: _mesh_of(('model',)),
    2: _mesh_of(('data', 'model')),
    3: _mesh_of(('pod', 'data', 'model')),
}

LOGICAL = ['batch', 'seq', 'heads', 'kv_heads', 'head_dim', 'embed',
           'ffn', 'vocab', 'qkv', 'layers', 'pages', None]


@pytest.mark.parametrize('n_axes', [1, 2, 3])
@pytest.mark.parametrize('rules', [SERVE_RULES, TRAIN_RULES],
                         ids=['serve', 'train'])
def test_never_yields_empty_tuple_or_absent_axis(n_axes, rules):
    mesh = MESHES[n_axes]
    for a in LOGICAL:
        for b in LOGICAL:
            spec = logical_to_spec((a, b), rules, mesh)
            for part in spec:
                assert part != (), (a, b, mesh.axis_names)
                names = (part,) if isinstance(part, str) else (part or ())
                assert all(n in mesh.axis_names for n in names), (a, b, spec)


@pytest.mark.parametrize('n_axes', [1, 2, 3])
def test_all_absent_tuple_is_replicated(n_axes):
    # batch -> ('pod', 'data'): on a model-only mesh both are absent —
    # the dim must be replicated (None entry / trailing trim), not ().
    spec = logical_to_spec(('batch', 'heads'), SERVE_RULES, MESHES[n_axes])
    want = {1: P(None, 'model'),
            2: P('data', 'model'),
            3: P(('pod', 'data'), 'model')}[n_axes]
    assert spec == want
    assert logical_to_spec(('batch',), SERVE_RULES, MESHES[1]) == P()


def test_without_mesh_is_fully_replicated():
    # mesh=None has no axes: nothing to shard over, so every rule —
    # including tuple-valued ones — resolves replicated.  The old
    # passthrough named axes no mesh provides.
    assert logical_to_spec(('batch', 'heads', 'embed'), SERVE_RULES) == P()
    assert logical_to_spec(('batch', 'seq'), TRAIN_RULES, None) == P()


def test_crafted_absent_rules():
    # rules whose mapped axes exist nowhere in a ('data','model') mesh
    mesh = MESHES[2]
    rules = {'x': ('pod', 'expertpar'), 'y': 'model'}
    assert logical_to_spec(('x', 'y'), rules, mesh) == P(None, 'model')
    assert logical_to_spec(('y', 'x'), rules, mesh) == P('model')
