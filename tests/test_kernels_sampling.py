"""Fused unembed+sample kernel parity — token-exact, no tolerance window.

The fused path's whole claim is that the engine can skip materializing
(B, V) logits without changing a single sampled token, so every parity
test here is ``array_equal`` on int32 tokens, not ``allclose``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sampling.ops import fused_unembed_sample
from repro.kernels.sampling.ref import unembed_sample_ref

CASES = [
    # (B, D, V, block_v) — V deliberately not a multiple of block_v in
    # most cases: the ragged last tile must mask, not sample, the padding
    (1, 32, 257, 128),
    (4, 64, 1000, 256),
    (3, 48, 512, 512),     # single tile
    (2, 64, 769, 128),
    (5, 32, 130, 64),
]


def _setup(case, seed=0):
    b, d, v, _ = case
    rng = np.random.default_rng(seed)
    last = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    unembed = jnp.asarray(rng.standard_normal((d, v)) * 0.3, jnp.float32)
    return last, unembed


@pytest.mark.parametrize('case', CASES)
def test_greedy_pallas_matches_ref_and_plain_argmax(case):
    last, unembed = _setup(case, seed=hash(case) % 2**32)
    got = fused_unembed_sample(last, unembed, backend='pallas',
                               interpret=True, block_v=case[3])
    ref = unembed_sample_ref(last, unembed)
    oracle = jnp.argmax(last @ unembed, axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


@pytest.mark.parametrize('case', CASES[:3])
@pytest.mark.parametrize('seed', [0, 17])
def test_temperature_pallas_matches_ref(case, seed):
    """Gumbel-max sampling: identical counter-hash noise on both backends
    makes kernel-vs-ref parity exact at T > 0 too."""
    last, unembed = _setup(case, seed=3)
    got = fused_unembed_sample(last, unembed, seed, temperature=0.8,
                               backend='pallas', interpret=True,
                               block_v=case[3])
    ref = unembed_sample_ref(last, unembed, seed, temperature=0.8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_temperature_seed_actually_samples():
    """Different seeds must be able to draw different tokens (the noise is
    live), and a fixed seed must reproduce exactly."""
    last, unembed = _setup((8, 32, 257, 128), seed=5)
    draws = [np.asarray(unembed_sample_ref(last, unembed, s,
                                           temperature=2.0))
             for s in range(12)]
    assert any(not np.array_equal(draws[0], d) for d in draws[1:])
    again = np.asarray(unembed_sample_ref(last, unembed, 0, temperature=2.0))
    np.testing.assert_array_equal(draws[0], again)


def test_tie_break_is_first_occurrence_across_tiles():
    """A max value duplicated in different vocab tiles must resolve to the
    earliest index, exactly like ``jnp.argmax`` — the cross-tile strict-``>``
    reduction is what the engine's bit-identity contract rests on."""
    b, d, v, block_v = 2, 16, 300, 128
    last = jnp.ones((b, d), jnp.float32)
    w = np.zeros((d, v), np.float32)
    w[:, 40] = 1.0     # tile 0
    w[:, 200] = 1.0    # tile 1 — same score, must lose to index 40
    w[:, 299] = 1.0    # ragged last tile — same score, must also lose
    unembed = jnp.asarray(w)
    got = fused_unembed_sample(last, unembed, backend='pallas',
                               interpret=True, block_v=block_v)
    oracle = jnp.argmax(last @ unembed, axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))
    assert np.asarray(got).tolist() == [40, 40]


def test_padding_vocab_never_wins():
    """All-negative logits: the ragged tile's pad columns (masked to -inf)
    must not beat a real, merely-bad token."""
    b, d, v = 2, 16, 130
    rng = np.random.default_rng(11)
    last = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    unembed = jnp.asarray(-np.abs(rng.standard_normal((d, v))) - 5.0,
                          jnp.float32)
    got = fused_unembed_sample(last, unembed, backend='pallas',
                               interpret=True, block_v=64)
    assert (np.asarray(got) < v).all()
    oracle = jnp.argmax(last @ unembed, axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))
