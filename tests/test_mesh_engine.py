"""Mesh-sharded engine: tensor-parallel serving must be bit-identical to
the single-device path.

``EngineConfig.mesh`` threads a jax device mesh through cache layout,
prefill and decode via SERVE_RULES (``repro.distributed.sharding``); the
``mesh=None`` path is the untouched PR-1..7 engine.  On CPU the mesh is
virtual (conftest forces 8 host devices), so equality here is exact —
GSPMD partitioning must not change a single sampled token.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.models.api import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kvpool import KVPool

ARCH = 'qwen3-0.6b'


def _drain(mesh, *, seed=0, n_reqs=3):
    cfg = reduced(get_config(ARCH), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    pool = KVPool(8, 4, page_size=4, reserved_handles=1)
    ecfg = EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8, mesh=mesh)
    eng = Engine(model, params, pool, ecfg, clock=VirtualClock())
    rng = np.random.default_rng(11)
    rids = [eng.submit(rng.integers(1, cfg.vocab_size,
                                    size=int(n)).tolist(),
                       max_new_tokens=8)
            for n in rng.integers(5, 20, size=n_reqs)]
    eng.run_to_completion()
    outs = [eng.output_tokens(r) for r in rids]
    pool.check_invariants()
    return outs


def test_mesh_drain_bit_identical_to_single_device(make_virtual_mesh):
    mesh = make_virtual_mesh((4,), ('model',))
    ref = _drain(None)
    got = _drain(mesh)
    assert all(len(o) == 8 for o in ref)
    assert got == ref


def test_mesh_cache_actually_sharded(make_virtual_mesh):
    """The KV cache must really live partitioned across the mesh (kv-head
    axis), not replicated — otherwise "tensor parallel" is a no-op."""
    mesh = make_virtual_mesh((2,), ('model',))
    cfg = reduced(get_config(ARCH), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    pool = KVPool(4, 4, page_size=4)
    eng = Engine(model, params, pool,
                 EngineConfig(max_batch=2, max_seq=32, prefill_chunk=8,
                              mesh=mesh),
                 clock=VirtualClock())
    leaves = jax.tree_util.tree_leaves(eng.cache)
    assert leaves and all(
        len(leaf.sharding.device_set) == 2 for leaf in leaves)
