"""Flash-attention kernel vs pure-jnp oracle: shape/dtype sweeps in
interpret mode (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape) * 0.5
    return jnp.asarray(x, dtype)


CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, dtype, bq, bk)
    (1, 128, 128, 4, 4, 64, True, jnp.float32, 64, 64),
    (2, 256, 256, 8, 2, 64, True, jnp.float32, 128, 128),
    (1, 128, 128, 4, 1, 128, True, jnp.bfloat16, 64, 64),
    (2, 192, 192, 4, 2, 32, True, jnp.float32, 64, 64),   # ragged blocks
    (1, 64, 256, 2, 2, 64, False, jnp.float32, 64, 64),   # cross, non-causal
    (2, 100, 100, 4, 4, 64, True, jnp.float32, 64, 64),   # unaligned seq
]


@pytest.mark.parametrize('case', CASES)
def test_flash_matches_ref(case):
    b, sq, skv, hq, hkv, d, causal, dtype, bq, bk = case
    rng = np.random.default_rng(hash(case) % 2**32)
    q = _rand(rng, (b, sq, hq, d), dtype)
    k = _rand(rng, (b, skv, hkv, d), dtype)
    v = _rand(rng, (b, skv, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_lowers_tpu_shapes():
    """Grid/BlockSpec construction at production shapes (Dh=128, bf16,
    128-token MXU-aligned blocks).  CPU backend requires interpret=True even
    to lower; the BlockSpec arithmetic exercised here is backend-agnostic."""
    q = jax.ShapeDtypeStruct((2, 1024, 16, 128), jnp.bfloat16)
    k = jax.ShapeDtypeStruct((2, 1024, 8, 128), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, interpret=True))
    _ = f.lower(q, k, k)
