"""Vectorized NodeSim fast path: ``SimConfig(vectorized=True)`` batches
decode-only stretches of the inner loop (``_burst_online_decode`` /
``_burst_offline_decode``) and must be *bit-identical* to the scalar event
loop — same floating-point timeline, same event stream, same telemetry.
The fleet benchmark gates the speedup; these tests pin the equivalence on
a spread of workload shapes (colocated, standalone, shared-prefix,
decode-heavy) across compute/memory policy combinations.
"""
from dataclasses import replace

import pytest

from repro.core.sim.colocation import (
    SimConfig, run_offline_standalone, run_online_standalone, run_strategy)
from repro.core.sim.workload import (
    OfflineWorkload, WorkloadPair, make_online_trace, make_workload_pairs)


def _sig(res):
    """Everything observable about a run: latencies, token counts, busy
    intervals, memory traces, the typed event stream (repr — carries every
    field), and the numeric telemetry counters."""
    tel = None
    if res.telemetry is not None:
        t = res.telemetry.counters
        tel = {k: getattr(t, k) for k in dir(t)
               if not k.startswith('_')
               and isinstance(getattr(t, k), (int, float))}
    return dict(ttft=res.ttft, tpot=res.tpot, off=res.offline_tokens,
                wasted=res.offline_tokens_wasted, rec=res.recompute_tokens,
                busy=res.busy_intervals, mt=res.mem_trace_t,
                mf=res.mem_trace_free, rej=res.rejected,
                mp=res.max_preempt_per_request, hz=res.horizon,
                ev=[repr(e) for e in res.events], tel=tel)


def _assert_parity(fn, cfg):
    a = _sig(fn(cfg))
    b = _sig(fn(replace(cfg, vectorized=True)))
    for k in a:
        assert a[k] == b[k], f'vectorized path diverges in {k!r}'


_CFG = SimConfig(total_pages=2048)
_PAIRS = make_workload_pairs(3, horizon_s=120.0)


@pytest.mark.parametrize('i', range(len(_PAIRS)))
@pytest.mark.parametrize('compute,memory', [
    ('Channel', 'OurMem'), ('KernelPreempt', 'StaticMem')])
def test_colocated_parity(i, compute, memory):
    _assert_parity(
        lambda c: run_strategy(_PAIRS[i], compute, memory, c), _CFG)


@pytest.mark.parametrize('i', range(len(_PAIRS)))
def test_standalone_parity(i):
    _assert_parity(lambda c: run_online_standalone(_PAIRS[i], c), _CFG)
    _assert_parity(lambda c: run_offline_standalone(_PAIRS[i], c), _CFG)


def test_shared_prefix_mixed_sizes_parity():
    """The hard case: prefix-share publication, mixed request sizes, and
    admission probes interleaved with decode bursts (the probe's rng/alloc
    sequence must land in the same dispatch on both paths)."""
    off = OfflineWorkload('offmix', prompt_tokens=512, output_tokens=256,
                          max_batch=32, prompt_choices=(128, 512, 1024),
                          output_choices=(64, 256, 512),
                          shared_prefix_tokens=96)
    on = make_online_trace(name='sp', horizon_s=120.0, base_rate=0.08,
                           burst_rate=4.0, seed=7)
    pair = WorkloadPair('sp', on, off)
    for compute in ('Channel', 'GPreempt'):
        _assert_parity(
            lambda c, cp=compute: run_strategy(pair, cp, 'OurMem', c), _CFG)


def test_decode_heavy_parity():
    """The benchmark's speedup-gate scenario: long offline outputs, batch
    capped below the memory limit (pure decode bursts), sparse online."""
    off = OfflineWorkload('long', prompt_tokens=256, output_tokens=2048,
                          max_batch=24)
    on = make_online_trace(name='sparse', horizon_s=300.0, base_rate=0.02,
                           burst_rate=0.5, seed=11)
    pair = WorkloadPair('dh', on, off)
    _assert_parity(lambda c: run_strategy(pair, 'Channel', 'OurMem', c),
                   SimConfig(total_pages=8192))
