"""Warm-up regression tests for the two sliding-window rate estimators.

Pre-fix, both ``MIADReservation._event_rate`` and
``ReclamationRateLimiter.rate`` divided the event count by the *full*
window even when the estimator had observed far less time, so a burst
inside the first window read as a low rate: T failed to increase
multiplicatively exactly when bursts start (the moment the §5 controller
exists for), and the monitoring-plane rate underreported.  Both now divide
by the elapsed observation horizon, capped at the window.
"""
import pytest

from repro.core.miad import MIADConfig, MIADReservation
from repro.core.reclamation import ReclamationRateLimiter


def _burst_rate_estimate(window_s: float):
    """Drive both estimators with the same warm-up burst: 6 events in the
    first 5 s of a much longer window.  True rate ≈ 1.2/s."""
    cfg = MIADConfig(rate_window=window_s)
    miad = MIADReservation(h_init=4, cfg=cfg)
    limiter = ReclamationRateLimiter(window_s=window_s)
    t = 0.0
    for _ in range(6):
        t += 5.0 / 6.0
        miad.note_reclamation(t)
        limiter.note(t)
    return miad._event_rate(t), limiter.rate(t), t


@pytest.mark.parametrize('window_s', [60.0, 120.0])
def test_warmup_burst_rate_uses_elapsed_horizon(window_s):
    miad_rate, limiter_rate, t = _burst_rate_estimate(window_s)
    true_rate = 6.0 / (t - 5.0 / 6.0)   # observation starts at first event
    # pre-fix both estimators returned 6/window (0.05–0.1/s) — an
    # underestimate by the window/elapsed ratio
    assert miad_rate == pytest.approx(true_rate, rel=0.01)
    assert limiter_rate == pytest.approx(true_rate, rel=0.01)
    assert miad_rate > 6.0 / window_s * 5      # far above the buggy value


def test_warmup_burst_drives_t_up_multiplicatively():
    """A burst inside the first ``rate_window`` must push T up by the
    multiplicative factor ``t_beta``.  Pre-fix the measured rate stayed
    below ``target_rate`` (6/120 = 0.05 < 0.1) and T *decreased*
    additively from ``t_init`` — the regression this test pins."""
    cfg = MIADConfig()          # target 0.1/s, window 120 s, t_init 1.0
    m = MIADReservation(h_init=4, cfg=cfg)
    t = 0.0
    for _ in range(6):          # 6 reclamations in 5 s ≈ 1.2/s >> target
        t += 5.0 / 6.0
        m.note_reclamation(t)
        m.on_tick(t, online_used=0)
    assert m.t >= cfg.t_init * cfg.t_beta, \
        f'T must grow multiplicatively during a warm-up burst, got {m.t}'


def test_single_event_is_not_a_burst():
    """One reclamation over a near-zero elapsed horizon must NOT read as an
    enormous rate (the naive elapsed-horizon division would say 1000/s and
    multiplicatively ratchet T off a single event)."""
    m = MIADReservation(h_init=4, cfg=MIADConfig())   # window 120, target 0.1
    m.note_reclamation(5.0)
    assert m._event_rate(5.0005) == pytest.approx(1.0 / 120.0)
    m.on_tick(5.0005, online_used=0)
    assert m.t <= MIADConfig().t_init                 # no multiplicative jump
    limiter = ReclamationRateLimiter(window_s=60.0)
    limiter.note(5.0)
    assert limiter.rate(5.0005) == pytest.approx(1.0 / 60.0)


def test_rate_decays_after_burst_leaves_window():
    cfg = MIADConfig(rate_window=30.0)
    m = MIADReservation(h_init=4, cfg=cfg)
    limiter = ReclamationRateLimiter(window_s=30.0)
    for i in range(5):
        m.note_reclamation(float(i))
        limiter.note(float(i))
    assert m._event_rate(40.0) == 0.0
    assert limiter.rate(40.0) == 0.0


def test_steady_state_rate_unchanged_by_fix():
    """After a full window of observation the estimate is count/window —
    the fix only changes warm-up behavior."""
    limiter = ReclamationRateLimiter(window_s=10.0)
    t = 0.0
    for _ in range(100):        # 1 event/s for 100 s
        t += 1.0
        limiter.note(t)
    assert limiter.rate(t) == pytest.approx(1.0, rel=0.11)
