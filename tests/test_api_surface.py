"""Public-API snapshot: the control-plane surface (sessions, runtime,
events, telemetry) is pinned against ``tests/api_surface.txt`` so surface
changes are deliberate, reviewed diffs.

Regenerate after an intentional change:

    scripts/ci.sh --regen-api
    # (equivalently: PYTHONPATH=src python -m repro.core.api > tests/api_surface.txt)
"""
import os

from repro.core.api import api_surface

SNAPSHOT = os.path.join(os.path.dirname(__file__), 'api_surface.txt')


def test_api_surface_matches_snapshot():
    want = open(SNAPSHOT).read().splitlines()
    got = api_surface()
    added = sorted(set(got) - set(want))
    removed = sorted(set(want) - set(got))
    assert got == want, (
        'public control-plane API changed — if intentional, regenerate '
        'the snapshot with scripts/ci.sh --regen-api\n'
        + ''.join(f'  + {l}\n' for l in added)
        + ''.join(f'  - {l}\n' for l in removed))


def test_surface_contains_the_v1_contract():
    """Spot-check the names the docs promise (a deleted snapshot file must
    not let the contract silently vanish)."""
    text = '\n'.join(api_surface())
    for needle in ('ValveSession.admit', 'ValveSession.finish',
                   'ValveSession.may_dispatch', 'ValveRuntime.open_session',
                   'ValveRuntime.subscribe', 'TelemetryRegistry.snapshot',
                   'PreemptionEvent', 'ReclamationEvent', 'WakeupEvent',
                   'ReservationChangeEvent', 'MemoryPressureEvent'):
        assert needle in text, needle
