"""NodeOrchestrator end-to-end: heterogeneous-model colocation over one
pool/runtime, invalidation fan-out to the owning engine, gate-driven
offline backfill, and the paper's ≤1-preemption-per-online-request bound."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.clock import VirtualClock
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.launch.node import NodeOrchestrator
from repro.serving.engine import EngineConfig
from repro.serving.kvpool import KVPool

ONLINE_ARCH = 'qwen3-0.6b'
OFFLINE_ARCHS = ('internlm2-1.8b', 'qwen3-0.6b')


def _ecfg(klass):
    return EngineConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                        klass=klass)


def _node(*, pool_handles=5, pph=4):
    pool = KVPool(pool_handles, pph, page_size=4, reserved_handles=1)
    clock = VirtualClock()
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=clock)
    node = NodeOrchestrator(rt, idle_advance=1e-3)
    node.add_engine(reduced(get_config(ONLINE_ARCH), page_size=4),
                    _ecfg('online'), seed=0, name='online')
    for i, arch in enumerate(OFFLINE_ARCHS):
        node.add_engine(reduced(get_config(arch), page_size=4),
                        _ecfg('offline'), seed=10 + i, name=f'off{i}')
    return node


def _submit_offline(node, rng):
    """Two requests per offline engine (5 pages each → every offline handle
    holds live pages, so reclamation must invalidate)."""
    rids = []
    for eng in node.offline:
        for _ in range(2):
            rids.append((eng, eng.submit(
                rng.integers(1, eng.mcfg.vocab_size, 12).tolist(),
                max_new_tokens=8)))
    return rids


def test_heterogeneous_colocation_end_to_end():
    """One online qwen3-0.6b + two offline engines of *different* model
    configs (internlm2-1.8b, qwen3-0.6b) share one KVPool through the
    NodeOrchestrator; an online burst forces reclamation that invalidates
    requests in BOTH offline engines; everything recovers and recomputes to
    the undisturbed outputs."""
    # undisturbed reference: same seeds, offline only
    ref_node = _node()
    ref_rids = _submit_offline(ref_node, np.random.default_rng(7))
    ref_node.drain(max_steps=5000)
    ref_outputs = [(e.mcfg.name, e.output_tokens(r)) for e, r in ref_rids]

    # disturbed run: online burst lands mid-generation
    node = _node()
    rng = np.random.default_rng(7)
    rids = _submit_offline(node, rng)
    for _ in range(4):                    # all engines prefill + start decode
        node.step()
    # the burst: 28-token prompt + 12 new tokens = 10 pages, far beyond the
    # 4-page reservation → reclaims 2 offline handles (compute-first)
    on_rid = node.online.submit(
        rng.integers(1, node.online.mcfg.vocab_size, 28).tolist(),
        max_new_tokens=12)
    node.drain(max_steps=5000)

    # online completed, bounded interference
    assert len(node.online.output_tokens(on_rid)) == 12
    node.runtime.check_invariants()       # ≤1 compute preemption per request
    assert node.runtime.stats.compute_preemptions <= 1
    assert node.runtime.reclaimer.stats.reclamations >= 1

    # the reclamation hit live pages in BOTH heterogeneous offline engines,
    # and the fan-out routed each invalidation to the owning engine
    invs = [e.stats.invalidations for e in node.offline]
    assert all(v >= 1 for v in invs), invs

    # every offline request finished and recomputed to the undisturbed
    # output (greedy decoding is deterministic per engine/model)
    got_outputs = [(e.mcfg.name, e.output_tokens(r)) for e, r in rids]
    assert got_outputs == ref_outputs

    # heterogeneity is real: the two offline engines serve different models
    names = {e.mcfg.name for e in node.offline}
    assert len(names) == 2, names
    node.pool.check_invariants()


def test_gate_driven_backfill_and_wakeup():
    """Offline backfills only while gates are open; closed gates are
    recorded as skips, and the runtime wakes offline after T_cool."""
    node = _node(pool_handles=8)
    rng = np.random.default_rng(3)
    eng = node.offline[0]
    eng.submit(rng.integers(1, eng.mcfg.vocab_size, 8).tolist(),
               max_new_tokens=4)
    # online request in flight → gates closed → offline must not dispatch
    node.online.submit(
        rng.integers(1, node.online.mcfg.vocab_size, 8).tolist(),
        max_new_tokens=4)
    node.step()
    assert node.stats.online_dispatches == 1
    assert node.stats.offline_dispatches == 0
    assert node.stats.gated_skips == 1
    node.drain(max_steps=2000)
    assert node.stats.offline_dispatches > 0       # woke after T_cool
    assert node.runtime.stats.offline_wakeups >= 1
    assert len(eng.finished) == 1
    node.runtime.check_invariants()


def test_register_rejects_mismatched_engines():
    node = _node()
    with pytest.raises(AssertionError):
        # second online engine on the same node
        node.add_engine(reduced(get_config(ONLINE_ARCH), page_size=4),
                        _ecfg('online'))
    # page-size mismatch with the shared pool
    with pytest.raises(AssertionError):
        node.add_engine(reduced(get_config(ONLINE_ARCH), page_size=8),
                        _ecfg('offline'))


def test_route_table_empty_after_burst_heavy_run():
    """Route lifetime == page lifetime: after a burst-heavy run with
    admission rejections, invalidations and a full drain, the runtime's
    invalidation-route table must be EMPTY (the old per-request
    ``bind_invalidation`` table leaked entries for requests that never
    reached ``_finish``)."""
    node = _node()
    rng = np.random.default_rng(11)
    rids = _submit_offline(node, rng)
    for _ in range(4):
        node.step()
    # two online bursts: the first reclaims offline handles mid-decode,
    # the second lands while memory is still tight (admission blocks at
    # the queue head → exercises the admit-rejection rollback path)
    for k in range(3):
        node.online.submit(
            rng.integers(1, node.online.mcfg.vocab_size, 20).tolist(),
            max_new_tokens=8)
    node.drain(max_steps=8000)
    assert any(e.stats.invalidations >= 1 for e in node.offline)
    assert node.runtime.invalidation_routes() == []
    assert all(s.owned_requests() == []
               for s in node.runtime.sessions.values())
    node.runtime.check_invariants()
    # every submitted request still completed exactly
    for eng, rid in rids:
        assert len(eng.output_tokens(rid)) == 8


def test_node_observes_event_stream():
    """The orchestrator subscribes to the typed stream; its event counters
    must agree with the unified telemetry registry."""
    node = _node()
    rng = np.random.default_rng(12)
    _submit_offline(node, rng)
    for _ in range(4):
        node.step()
    node.online.submit(
        rng.integers(1, node.online.mcfg.vocab_size, 28).tolist(),
        max_new_tokens=12)
    node.drain(max_steps=8000)
    tel = node.runtime.telemetry.counters
    assert node.stats.preemptions_seen == tel.preemptions >= 1
    assert node.stats.wakeups_seen == tel.wakeups >= 1
    assert node.stats.invalidation_bursts_seen == tel.reclamations >= 1
    m = node.metrics()
    assert m['compute_preemptions'] == tel.preemptions
    assert m['preemption_latency']['count'] == tel.preemptions


def test_node_metrics_shape():
    node = _node()
    rng = np.random.default_rng(5)
    node.online.submit(
        rng.integers(1, node.online.mcfg.vocab_size, 8).tolist(),
        max_new_tokens=4)
    node.drain(max_steps=1000)
    m = node.metrics()
    assert m['online_finished'] == 1
    assert m['max_preemptions_per_request'] <= 1
    assert set(m['engines']) == {'online', 'off0', 'off1'}
    assert m['engines']['off0']['arch'].startswith('internlm2')
