"""Shape-aware spec resolution: jit arguments must always divide evenly."""
import numpy as np
import pytest

# property-based suite: declared in pyproject [test]; skip (not error) when
# the environment lacks it so bare collection stays green
hypothesis = pytest.importorskip('hypothesis')
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (TRAIN_RULES, SERVE_RULES,
                                        logical_to_spec, shaped_spec)


@pytest.fixture(scope='module')
def mesh():
    devs = np.asarray(jax.devices()[:1] * 4).reshape(2, 2)
    return Mesh(devs, ('data', 'model'))


def _axis_sizes(mesh, part):
    if part is None:
        return 1
    parts = (part,) if isinstance(part, str) else part
    n = 1
    for p in parts:
        n *= mesh.shape[p]
    return n


@settings(max_examples=120, deadline=None)
@given(st.lists(st.sampled_from(
    [(8, 'batch'), (40, 'heads'), (8, 'kv_heads'), (128, 'head_dim'),
     (17, 'vocab'), (64, 'ffn'), (3, None), (256, 'embed'), (6, 'seq')]),
    min_size=1, max_size=4))
def test_shaped_spec_always_divides(mesh, dims):
    shape = tuple(d for d, _ in dims)
    axes = tuple(a for _, a in dims)
    spec = shaped_spec(shape, axes, TRAIN_RULES, mesh)
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for dim, part in zip(shape, parts):
        assert dim % _axis_sizes(mesh, part) == 0, (shape, axes, spec)


def test_shaped_spec_relocates_dropped_axis(mesh):
    # kv_heads=3 can't take model(2); head_dim=128 can
    spec = shaped_spec((4, 3, 128), ('batch', 'kv_heads', 'head_dim'),
                       SERVE_RULES, mesh)
    assert spec == P('data', None, 'model')


def test_shaped_spec_keeps_divisible_mapping(mesh):
    spec = shaped_spec((4, 8, 128), ('batch', 'kv_heads', 'head_dim'),
                       SERVE_RULES, mesh)
    assert spec == P('data', 'model')   # trailing None trimmed


def test_shaped_spec_partial_tuple(mesh):
    # batch maps to ('pod','data') — pod absent in this mesh, data kept
    spec = shaped_spec((6, 10), ('batch', None), TRAIN_RULES, mesh)
    assert spec == P('data')


def test_logical_to_spec_drops_missing_axes(mesh):
    spec = logical_to_spec(('batch', 'heads'), TRAIN_RULES, mesh)
    assert spec == P('data', 'model')
