"""Training substrate: optimizer, data determinism, checkpoint/restart,
fault tolerance, end-to-end loss decrease."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.data import DataConfig, Prefetcher, batch_at
from repro.training.fault_tolerance import (
    HeartbeatConfig, HeartbeatMonitor, StragglerDetector, elastic_mesh_shape,
    plan_recovery)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, warmup_steps=1, decay_steps=1000,
                          weight_decay=0.0, grad_clip=0)
    params = {'w': jnp.asarray([5.0, -3.0])}
    state = opt.init_opt_state(params)
    loss = lambda p: jnp.sum(p['w'] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=110,
                          min_lr_frac=0.1)
    lrs = [float(opt.lr_at(cfg, jnp.asarray(s))) for s in range(0, 130, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=0.02)
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)   # cosine floor


def test_grad_clip_bounds_update_norm():
    cfg = opt.AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=1)
    params = {'w': jnp.zeros(4)}
    state = opt.init_opt_state(params)
    g = {'w': jnp.full(4, 1e6)}
    _, _, metrics = opt.adamw_update(cfg, params, g, state)
    assert float(metrics['grad_norm']) > 1e5   # raw norm reported


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_batch_at_is_pure():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=100, seed=3)
    a, b = batch_at(cfg, 7), batch_at(cfg, 7)
    np.testing.assert_array_equal(a['tokens'], b['tokens'])
    c = batch_at(cfg, 8)
    assert not np.array_equal(a['tokens'], c['tokens'])


def test_prefetcher_order_and_resume():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=50)
    pf = Prefetcher(cfg, start_step=5)
    steps = []
    for _ in range(4):
        s, batch = next(pf)
        steps.append(s)
        np.testing.assert_array_equal(batch['tokens'],
                                      batch_at(cfg, s)['tokens'])
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=32, global_batch=2, vocab_size=64, seed=0)
    b = batch_at(cfg, 0)
    # labels[t] continues the same underlying sequence as tokens[t+1]
    np.testing.assert_array_equal(b['tokens'][:, 1:], b['labels'][:, :-1])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_bitexact(tmp_path):
    tree = {'params': {'w': jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       'b': jnp.ones(4, jnp.bfloat16)},
            'opt': {'step': jnp.asarray(7, jnp.int32)}}
    d = str(tmp_path)
    ckpt.save(d, 7, tree)
    assert ckpt.latest_step(d) == 7
    restored, step = ckpt.restore(d, 7, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 3, {'x': jnp.zeros(2)})
    assert not any(p.endswith('.tmp') for p in os.listdir(d))


def test_checkpoint_prune(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, {'x': jnp.asarray([float(s)])})
    ckpt.prune(d, keep=2)
    assert ckpt.latest_step(d) == 5
    assert sorted(os.listdir(d)) == ['step_4', 'step_5']


def test_checkpoint_overwrite_same_step(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {'x': jnp.asarray([1.0])})
    ckpt.save(d, 1, {'x': jnp.asarray([2.0])})
    restored, _ = ckpt.restore(d, 1, {'x': jnp.zeros(1)})
    assert float(restored['x'][0]) == 2.0


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_death_detection():
    mon = HeartbeatMonitor(['h0', 'h1', 'h2'],
                           HeartbeatConfig(interval_s=1.0, miss_threshold=3))
    for t in range(5):
        mon.beat('h0', float(t))
        mon.beat('h1', float(t))
        # h2 silent
    dead = mon.check(5.0)
    assert dead == ['h2']
    assert sorted(mon.alive) == ['h0', 'h1']


def test_elastic_mesh_shrink():
    assert elastic_mesh_shape(256, 16) == (16, 16)
    assert elastic_mesh_shape(240, 16) == (15, 16)   # lost a 16-chip host
    assert elastic_mesh_shape(8, 16) is None         # below one model group


def test_plan_recovery_end_to_end():
    mon = HeartbeatMonitor(['h0', 'h1'],
                           HeartbeatConfig(interval_s=1.0, miss_threshold=2))
    mon.beat('h0', 10.0)
    plan = plan_recovery(mon, devices_per_host=8, model_parallel=4,
                         last_ckpt_step=42, old_shape=(4, 4), now=10.0)
    assert plan is not None
    assert plan.lost_hosts == ['h1']
    assert plan.new_shape == (2, 4)
    assert plan.restore_step == 42


def test_straggler_detection():
    det = StragglerDetector()
    for i in range(16):
        for h in ('a', 'b', 'c', 'd'):
            det.record(h, 1.0 if h != 'd' else 2.5)
    assert det.stragglers() == ['d']
    assert 'd' in det.quarantined


# ---------------------------------------------------------------------------
# End-to-end: train a reduced model, checkpoint, restore, continue
# ---------------------------------------------------------------------------

def test_train_loss_decreases_and_restart_is_deterministic(tmp_path):
    from repro.launch.train import train
    d = str(tmp_path / 'ck')
    _, _, losses = train('qwen3-0.6b', steps=12, batch=4, seq=32,
                         use_reduced=True, ckpt_dir=d, ckpt_every=8,
                         log_every=100,
                         opt_cfg=opt.AdamWConfig(lr=3e-3, warmup_steps=2))
    assert np.mean(losses[-4:]) < np.mean(losses[:4])   # learning signal
    # crash after step 12; restart resumes from the step-8 checkpoint and
    # must retrace the exact same loss trajectory (data is step-pure)
    _, _, losses2 = train('qwen3-0.6b', steps=12, batch=4, seq=32,
                          use_reduced=True, ckpt_dir=d, restore=True,
                          log_every=100,
                          opt_cfg=opt.AdamWConfig(lr=3e-3, warmup_steps=2))
    assert len(losses2) == 4                            # steps 8..11
    np.testing.assert_allclose(losses2, losses[8:], rtol=2e-2, atol=2e-2)
