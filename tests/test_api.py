"""Control-plane API v1: class-scoped sessions — ownership-routed
invalidation delivery, admit/finish bundles, route lifetime == page
lifetime, legacy klass-string shims preserved."""
import pytest

from repro.core.api import PoolSession, ValveSession
from repro.core.clock import VirtualClock
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.serving.kvpool import KVPool


def _rt(n_handles=8, pph=4, **kw):
    pool = KVPool(n_handles, pph, reserved_handles=1)
    clock = VirtualClock()
    rt = ValveRuntime(pool, RuntimeConfig(**kw), clock=clock)
    return rt, pool, clock


# ---------------------------------------------------------------------------
# Session basics
# ---------------------------------------------------------------------------

def test_open_session_names_and_ids_are_scoped():
    rt, _, _ = _rt()
    a = rt.open_session('offline', name='batch-a')
    b = rt.open_session('offline')          # auto-name (monotonic counter)
    assert isinstance(a, ValveSession)
    assert a.name == 'batch-a' and b.name == 'offline0'
    assert a.new_request_id() == 'batch-a-0'
    assert a.new_request_id() == 'batch-a-1'
    assert b.new_request_id() == 'offline0-0'
    with pytest.raises(AssertionError):
        rt.open_session('offline', name='batch-a')      # duplicate name


def test_session_alloc_records_ownership_and_free_releases_it():
    rt, pool, _ = _rt()
    s = rt.open_session('offline', name='s')
    rid = s.new_request_id()
    pages = s.alloc(rid, 3)
    assert pages is not None
    assert s.owned_requests() == [rid]
    assert rt.invalidation_routes() == [rid]
    s.free(rid)
    assert s.owned_requests() == []
    assert rt.invalidation_routes() == []
    assert pool.pages_of_request(rid) == []


def test_online_admit_bundles_lifecycle_and_rolls_back_on_failure():
    rt, pool, clock = _rt(n_handles=2, pph=4)   # 1 reserved handle = 4 pages
    s = rt.open_session('online', name='on')
    # success: lifecycle sees the request, gates closed by its arrival
    pool.alloc('off-x', 4, 'offline')           # fill the offline handle
    got = s.admit('r0', 2)
    assert got is not None
    assert 'r0' in rt.lifecycle.active
    assert not rt.offline_may_dispatch()
    s.finish('r0')
    assert 'r0' not in rt.lifecycle.active
    # failure: pool exhausted beyond reclamation → lifecycle rolled back
    big = s.admit('r1', 100)
    assert big is None
    assert 'r1' not in rt.lifecycle.active
    assert rt.invalidation_routes() == []       # no route for the rejection


def test_invalidation_routes_to_owning_session_same_class_no_crosstalk():
    """Two OFFLINE sessions (the collision class the id-discriminator
    workaround existed for): a reclamation touching both delivers each
    request to ITS owner only."""
    rt, pool, _ = _rt(n_handles=4, pph=4)
    got_a, got_b = [], []
    a = rt.open_session('offline', name='a',
                        on_invalidate=lambda inv: got_a.append(sorted(inv)))
    b = rt.open_session('offline', name='b',
                        on_invalidate=lambda inv: got_b.append(sorted(inv)))
    ra, rb = a.new_request_id(), b.new_request_id()
    # interleave so both offline handles hold pages of both sessions
    assert a.alloc(ra, 6) is not None
    assert b.alloc(rb, 6) is not None
    on = rt.open_session('online', name='on')
    assert on.admit('burst', 10) is not None    # forces reclamation of both
    assert got_a == [[ra]] and got_b == [[rb]]
    # routes for invalidated requests die with their pages
    assert ra not in rt.invalidation_routes()
    assert rb not in rt.invalidation_routes()
    rt.check_invariants()


def test_reallocation_after_invalidation_reroutes():
    rt, pool, _ = _rt(n_handles=4, pph=4)
    deliveries = []
    s = rt.open_session('offline', name='s',
                        on_invalidate=lambda inv: deliveries.append(set(inv)))
    rid = s.new_request_id()
    assert s.alloc(rid, 12) is not None         # every offline handle live
    on = rt.open_session('online', name='on')
    assert on.admit('b0', 8) is not None
    assert deliveries == [{rid}]
    # the engine would requeue + re-admit: a fresh alloc re-routes the id
    assert s.alloc(rid, 4) is not None
    assert rid in rt.invalidation_routes()
    s.finish(rid)
    on.finish('b0')
    assert rt.invalidation_routes() == []


def test_session_close_releases_everything():
    rt, pool, _ = _rt()
    s = rt.open_session('offline', name='s')
    rids = [s.new_request_id() for _ in range(3)]
    for r in rids:
        assert s.alloc(r, 2) is not None
    s.close()
    assert rt.invalidation_routes() == []
    assert pool.used_pages_for('offline') == 0
    assert 's' not in rt.sessions
    with pytest.raises(AssertionError):
        s.alloc('late', 1)                      # closed sessions refuse


# ---------------------------------------------------------------------------
# Legacy shims (deprecated klass-string methods must keep working)
# ---------------------------------------------------------------------------

def test_legacy_klass_methods_still_work_via_hidden_sessions():
    rt, pool, _ = _rt()
    pool.alloc('off-1', 10, 'offline')
    got = rt.alloc_online('on-1', 8)            # forces reclamation
    assert got is not None
    assert rt.reclaimer.stats.reclamations == 1
    rt.free_online('on-1')
    assert rt.alloc_offline('off-2', 2) is not None
    rt.free_offline('off-2')
    rt.check_invariants()
    assert rt.invalidation_routes() == []


def test_legacy_bind_route_fallback_still_delivers():
    """bind_invalidation (deprecated) still routes ids with no session
    owner — the transition path for un-migrated frameworks."""
    rt, pool, _ = _rt(n_handles=4, pph=4)
    hits = []
    pool.alloc('off-legacy', 12, 'offline')     # allocated around the runtime
    rt.bind_invalidation('off-legacy', lambda inv: hits.append(set(inv)))
    on = rt.open_session('online', name='on')
    assert on.admit('b', 8) is not None
    assert hits == [{'off-legacy'}]
    rt.unbind_invalidation('off-legacy')
    on.finish('b')
    assert rt.invalidation_routes() == []


def test_legacy_shim_alloc_does_not_shadow_bound_route():
    """Regression: allocation through the deprecated klass-string shims
    records the hidden legacy session as owner; a per-request bound
    callback must still win over that session's (absent) callback."""
    rt, pool, _ = _rt(n_handles=4, pph=4)
    hits = []
    assert rt.alloc_offline('r1', 12) is not None   # hidden legacy session
    rt.bind_invalidation('r1', lambda inv: hits.append(set(inv)))
    on = rt.open_session('online', name='on')
    assert on.admit('b', 8) is not None
    assert hits == [{'r1'}]
    rt.unbind_invalidation('r1')
    on.finish('b')
    assert rt.invalidation_routes() == []


def test_session_names_are_never_reissued_after_close():
    rt, _, _ = _rt()
    a = rt.open_session('offline')
    b = rt.open_session('offline')
    assert (a.name, b.name) == ('offline0', 'offline1')
    b.close()
    c = rt.open_session('offline')      # must not collide with 'offline1'
    assert c.name == 'offline2'


# ---------------------------------------------------------------------------
# PoolSession (runtime-less engines keep the same call shape)
# ---------------------------------------------------------------------------

def test_pool_session_matches_interface():
    pool = KVPool(4, 4, reserved_handles=1)
    s = PoolSession(pool, 'offline', name='solo')
    rid = s.new_request_id()
    assert rid.startswith('solo-')
    assert s.may_dispatch() is True
    pages = s.admit(rid, 3)
    assert pages == pool.pages_of_request(rid)
    s.iteration_start(); s.iteration_end()      # no-ops, must not raise
    s.finish(rid)
    assert pool.pages_of_request(rid) == []
    pool.check_invariants()


def test_pool_session_ownership_is_name_segment_exact():
    """'off1' must not claim 'off10-...' (prefix-collision regression)."""
    pool = KVPool(4, 4, reserved_handles=1)
    s1 = PoolSession(pool, 'offline', name='off1')
    s10 = PoolSession(pool, 'offline', name='off10')
    r10 = s10.new_request_id()
    assert s10.alloc(r10, 2) is not None
    assert s1.owned_requests() == []
    assert s10.owned_requests() == [r10]


# ---------------------------------------------------------------------------
# Memory-plane API v1: leases through sessions
# ---------------------------------------------------------------------------

def test_partial_invalidation_keeps_route_until_release():
    """A session-owned request that survives a reclamation with a prefix
    keeps its lease AND its delivery route (route lifetime == lease
    lifetime); a second reclamation still reaches it; finish drains."""
    rt, pool, _ = _rt(n_handles=6, pph=4)
    hits = []
    s = rt.open_session('offline', name='s',
                        on_invalidate=lambda inv: hits.append(
                            {k: (v.keep, v.resume) for k, v in inv.items()}))
    rid = s.new_request_id()
    lease = s.alloc(rid, 20)                    # fills every offline handle
    assert lease is not None
    lease.note_filled(80)                       # fully materialized
    on = rt.open_session('online', name='on')
    assert on.admit('b0', 8) is not None        # reclaims the cheapest tail
    keep, resume = hits[-1][rid]
    # Algorithm 1 under the plane cost picks an UNFILLED-tail handle: the
    # whole 80-token fill survives (resume clamps to the fill)
    assert keep > 0 and resume == min(keep * pool.page_size, 80) == 80
    assert len(lease) == keep and lease.resume_tokens == resume
    # the survivor keeps its route: a second, pool-draining burst still
    # reaches it (now losing the whole prefix → lease released)
    assert rid in rt.invalidation_routes()
    assert on.admit('b1', 16) is not None
    assert rid in hits[-1]
    assert hits[-1][rid][0] < keep              # prefix shrank further
    s.finish(rid)
    on.finish('b0')
    on.finish('b1')
    assert rt.invalidation_routes() == []
    rt.check_invariants()


def test_session_admit_extends_surviving_lease():
    """Re-admitting a partially-invalidated id extends the SAME lease back
    to the target and keeps the resume point (the engine's re-admission
    path after the patch requeues a victim)."""
    rt, pool, _ = _rt(n_handles=6, pph=4)
    s = rt.open_session('offline', name='s')
    lease = s.alloc('s-0', 20)
    lease.note_filled(80)
    on = rt.open_session('online', name='on')
    assert on.admit('b0', 8) is not None
    keep = len(lease)
    assert 0 < keep < 20
    resume = lease.resume_tokens
    assert resume == min(keep * pool.page_size, 80)
    # extend back toward the target within what offline still has free
    again = s.admit('s-0', 16)
    assert again is lease and len(lease) == 16
    assert lease.resume_tokens == resume        # resume point survived
    rt.check_invariants()
