"""Distributed behaviour under a multi-device CPU mesh.

jax locks the device count at first init, so each scenario runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f'--xla_force_host_platform_device_count={devices}',
               PYTHONPATH='src')
    proc = subprocess.run([sys.executable, '-c', textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env, cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_train_step_sharded_matches_meshless():
    out = _run('''
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.configs.base import ShapeConfig
        from repro.models.api import build_model
        from repro.training import optimizer as opt
        from repro.training.train_step import make_train_step
        from repro.training.data import DataConfig, batch_at

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        cfg = reduced(get_config('qwen3-0.6b'))
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        ostate = opt.init_opt_state(params)
        dcfg = DataConfig(seq_len=32, global_batch=8, vocab_size=cfg.vocab_size)
        batch = jax.tree.map(jnp.asarray, batch_at(dcfg, 0))

        sb, _ = make_train_step(model, mesh, zero1=True)
        step = sb(ShapeConfig('t', 32, 8, 'train'))
        p1, s1, m1 = step(params, ostate, batch)

        step0, _ = make_train_step(model, None)
        p0, s0, m0 = step0(model.init_params(jax.random.PRNGKey(0)),
                           opt.init_opt_state(params), batch)
        print('sharded', float(m1['loss']), 'meshless', float(m0['loss']))
        np.testing.assert_allclose(float(m1['loss']), float(m0['loss']),
                                   rtol=2e-2)
        # params agree after one step (bf16 tolerance)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p0)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-2)
        print('OK')
    ''')
    assert 'OK' in out


def test_zero1_moments_sharded_over_data():
    out = _run('''
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config, reduced
        from repro.models.api import build_model
        from repro.training import optimizer as opt
        from repro.training.train_step import param_specs

        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        cfg = reduced(get_config('internlm2-1.8b'), d_model=64, d_ff=256)
        model = build_model(cfg)
        pspec = param_specs(model, mesh)
        ospec = opt.opt_state_specs(pspec, mesh, zero1=True,
                                    param_shapes=model.param_shapes())
        # at least one moment leaf picked up the data axis
        has_data = any('data' in str(s.spec)
                       for s in jax.tree.leaves(ospec['mu']))
        assert has_data, [str(s.spec) for s in jax.tree.leaves(ospec['mu'])][:5]
        print('OK')
    ''')
    assert 'OK' in out


def test_compressed_allreduce_matches_mean():
    out = _run('''
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.compression import (init_error_state,
                                                make_compressed_allreduce)
        mesh = jax.make_mesh((8,), ('data',))
        rng = np.random.default_rng(0)
        # global (8, 64) sharded over data: row i is device i's local grad
        g_global = rng.normal(size=(8, 64)).astype(np.float32)
        sharding = NamedSharding(mesh, P('data', None))
        reduce_fn = make_compressed_allreduce(mesh, {'w': P('data', None)},
                                              ('data',))
        grads = {'w': jax.device_put(g_global, sharding)}
        err = {'w': jax.device_put(jnp.zeros((8, 64), jnp.float32), sharding)}
        out, new_err = reduce_fn(grads, err)
        want = g_global.mean(axis=0)
        got = np.asarray(out['w'])[0]    # every shard holds the mean
        rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        print('rel err', rel)
        # int8 with 1/8 sum headroom leaves ~4 bits/element: coarse on one
        # round — error feedback is what makes it converge across rounds
        assert rel < 0.15, rel
        # error feedback: applying the residual next round recovers precision
        out2, _ = reduce_fn(jax.tree.map(jnp.zeros_like, grads), new_err)
        got2 = got + np.asarray(out2['w'])[0]
        rel2 = np.abs(got2 - want).max() / (np.abs(want).max() + 1e-9)
        print('rel err with feedback', rel2)
        assert rel2 < rel
        print('OK')
    ''')
    assert 'OK' in out


def test_checkpoint_elastic_reshard():
    out = _run('''
        import jax, numpy as np, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training import checkpoint as ckpt

        mesh_a = jax.make_mesh((4, 2), ('data', 'model'))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xa = jax.device_put(x, NamedSharding(mesh_a, P('data', 'model')))
        d = tempfile.mkdtemp()
        ckpt.save(d, 1, {'x': xa})

        # "lose a host": restore under a smaller (2, 2) mesh
        devs = np.asarray(jax.devices()[:4]).reshape(2, 2)
        mesh_b = jax.sharding.Mesh(devs, ('data', 'model'))
        target = {'x': jnp.zeros((8, 8), jnp.float32)}
        sh = {'x': NamedSharding(mesh_b, P('data', 'model'))}
        restored, step = ckpt.restore(d, 1, target, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored['x']), np.asarray(x))
        assert restored['x'].sharding.mesh.shape['data'] == 2
        print('OK')
    ''')
    assert 'OK' in out


def test_elastic_failover_end_to_end():
    """DESIGN.md §6: train on a (4, 2) mesh, checkpoint, 'lose a host',
    re-mesh to (2, 2) via plan_recovery, restore, and continue — the loss
    trajectory must match the unbroken run (data is step-pure)."""
    out = _run('''
        import jax, numpy as np, jax.numpy as jnp, tempfile
        from repro.configs import get_config, reduced
        from repro.configs.base import ShapeConfig
        from repro.models.api import build_model
        from repro.training import checkpoint as ckpt, optimizer as opt
        from repro.training.data import DataConfig, batch_at
        from repro.training.fault_tolerance import (
            HeartbeatConfig, HeartbeatMonitor, plan_recovery)
        from repro.training.train_step import make_train_step

        cfg = reduced(get_config('qwen3-0.6b'))
        model = build_model(cfg)
        dcfg = DataConfig(seq_len=32, global_batch=8,
                          vocab_size=cfg.vocab_size)
        shape = ShapeConfig('t', 32, 8, 'train')
        ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=1)

        def run_steps(step_fn, params, state, lo, hi):
            losses = []
            for s in range(lo, hi):
                batch = jax.tree.map(jnp.asarray, batch_at(dcfg, s))
                params, state, m = step_fn(params, state, batch)
                losses.append(float(m['loss']))
            return params, state, losses

        # unbroken reference on the full mesh
        mesh_a = jax.make_mesh((4, 2), ('data', 'model'))
        sb, _ = make_train_step(model, mesh_a, opt_cfg=ocfg, donate=False)
        step_a = sb(shape)
        p0 = model.init_params(jax.random.PRNGKey(0))
        s0 = opt.init_opt_state(p0)
        _, _, ref = run_steps(step_a, p0, s0, 0, 6)

        # broken run: 3 steps, checkpoint, host dies
        p, s = model.init_params(jax.random.PRNGKey(0)), None
        s = opt.init_opt_state(p)
        p, s, l1 = run_steps(step_a, p, s, 0, 3)
        d = tempfile.mkdtemp()
        ckpt.save(d, 3, {'params': p, 'opt': s})

        mon = HeartbeatMonitor(['h0', 'h1'],
                               HeartbeatConfig(interval_s=1, miss_threshold=2))
        mon.beat('h0', 10.0)            # h1 silent → dead
        plan = plan_recovery(mon, devices_per_host=4, model_parallel=2,
                             last_ckpt_step=ckpt.latest_step(d),
                             old_shape=(4, 2), now=10.0)
        assert plan is not None and plan.new_shape == (2, 2), plan

        devs = np.asarray(jax.devices()[:4]).reshape(2, 2)
        mesh_b = jax.sharding.Mesh(devs, ('data', 'model'))
        sb_b, make_sh = make_train_step(model, mesh_b, opt_cfg=ocfg,
                                        donate=False)
        sh = make_sh(shape)['in_shardings']
        target = {'params': model.init_params(jax.random.PRNGKey(1)),
                  'opt': opt.init_opt_state(p0)}
        restored, step = ckpt.restore(
            d, plan.restore_step, target,
            shardings={'params': sh[0], 'opt': sh[1]})
        step_b = sb_b(shape)
        _, _, l2 = run_steps(step_b, restored['params'], restored['opt'],
                             step, 6)
        got = l1 + l2
        print('ref', ref)
        print('got', got)
        np.testing.assert_allclose(got, ref, rtol=3e-2, atol=3e-2)
        print('OK')
    ''')
    assert 'OK' in out


def test_serve_step_lowers_on_small_mesh():
    """A miniature dry-run: decode step lowers+compiles on a (2,4) mesh."""
    out = _run('''
        import jax
        from repro.configs import get_config, SHAPES
        from repro.kernels.common import cost_analysis_dict
        from repro.models.api import build_model
        from repro.training.train_step import make_serve_step
        from repro.configs.base import ShapeConfig

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        cfg = get_config('qwen3-0.6b')
        model = build_model(cfg)
        shape = ShapeConfig('decode_small', 2048, 8, 'decode')
        jitted, _ = make_serve_step(model, mesh, shape)
        lowered = jitted.lower(model.param_shapes(),
                               model.cache_shapes(shape),
                               model.input_specs(shape))
        compiled = lowered.compile()
        print('flops', cost_analysis_dict(compiled).get('flops', 0.0) > 0)
        print('OK')
    ''')
    assert 'flops True' in out   # cost analysis must actually report flops
    assert 'OK' in out
