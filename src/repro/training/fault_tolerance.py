"""Fault tolerance for 1000+-node runs: heartbeats, elastic re-meshing,
straggler mitigation.

All host-side control-plane logic — deliberately clock-injected so the unit
tests drive it deterministically, and the same machinery feeds the cluster
scheduler's P_multi alignment score (core/cluster/perfmodel.py).
"""
from __future__ import annotations

import math
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------

@dataclass
class HeartbeatConfig:
    interval_s: float = 10.0
    miss_threshold: int = 3      # misses before a host is declared dead


class HeartbeatMonitor:
    """Coordinator-side liveness tracking."""

    def __init__(self, hosts: Sequence[str],
                 cfg: Optional[HeartbeatConfig] = None):
        self.cfg = cfg or HeartbeatConfig()
        self.last_seen: Dict[str, float] = {h: 0.0 for h in hosts}
        self.dead: set = set()

    def beat(self, host: str, now: float) -> None:
        if host not in self.dead:
            self.last_seen[host] = now

    def check(self, now: float) -> List[str]:
        """Returns hosts newly declared dead at ``now``."""
        limit = self.cfg.interval_s * self.cfg.miss_threshold
        newly = [h for h, t in self.last_seen.items()
                 if h not in self.dead and now - t > limit]
        self.dead.update(newly)
        return newly

    @property
    def alive(self) -> List[str]:
        return [h for h in self.last_seen if h not in self.dead]


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------

def elastic_mesh_shape(n_devices: int, model_parallel: int
                       ) -> Optional[Tuple[int, int]]:
    """Largest (data, model) mesh fitting the survivors.

    The model axis is fixed (param shards must stay complete); the data axis
    shrinks to the largest multiple that fits.  None if even one model group
    cannot be formed.
    """
    if n_devices < model_parallel:
        return None
    return (n_devices // model_parallel, model_parallel)


@dataclass
class ElasticPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, int]
    lost_hosts: List[str]
    restore_step: int


def plan_recovery(monitor: HeartbeatMonitor, devices_per_host: int,
                  model_parallel: int, last_ckpt_step: Optional[int],
                  old_shape: Tuple[int, ...], now: float
                  ) -> Optional[ElasticPlan]:
    """On heartbeat loss: shrink the data axis, restore the last checkpoint.

    Returns None when nothing died or no viable mesh remains (full restart
    needed)."""
    newly = monitor.check(now)
    if not newly:
        return None
    n = len(monitor.alive) * devices_per_host
    shape = elastic_mesh_shape(n, model_parallel)
    if shape is None or last_ckpt_step is None:
        return None
    return ElasticPlan(old_shape, shape, newly, last_ckpt_step)


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------

@dataclass
class StragglerConfig:
    window: int = 32             # per-host step-time samples
    ratio: float = 1.5           # slow if EWMA > ratio × cluster median
    ewma_alpha: float = 0.25
    min_samples: int = 8


class StragglerDetector:
    """Per-host step-time telemetry → quarantine recommendations.

    The same busy-interval telemetry feeds Valve's P_multi placement score;
    a quarantined host is excluded from offline placement and flagged to the
    training launcher for data-axis exclusion at the next re-mesh.
    """

    def __init__(self, cfg: Optional[StragglerConfig] = None):
        self.cfg = cfg or StragglerConfig()
        self.ewma: Dict[str, float] = {}
        self.count: Dict[str, int] = defaultdict(int)
        self.quarantined: set = set()

    def record(self, host: str, step_time_s: float) -> None:
        a = self.cfg.ewma_alpha
        prev = self.ewma.get(host)
        self.ewma[host] = (step_time_s if prev is None
                           else a * step_time_s + (1 - a) * prev)
        self.count[host] += 1

    def _median(self) -> Optional[float]:
        vals = sorted(v for h, v in self.ewma.items()
                      if self.count[h] >= self.cfg.min_samples)
        if not vals:
            return None
        m = len(vals) // 2
        return vals[m] if len(vals) % 2 else 0.5 * (vals[m - 1] + vals[m])

    def stragglers(self) -> List[str]:
        med = self._median()
        if med is None or med <= 0:
            return []
        out = [h for h, v in self.ewma.items()
               if self.count[h] >= self.cfg.min_samples
               and v > self.cfg.ratio * med]
        self.quarantined.update(out)
        return out
