"""Sharded checkpoints with atomic commit and elastic restore.

Layout::

    <dir>/step_<N>.tmp/          # written first
        manifest.json            # step, tree structure, global shapes, mesh
        host<k>.npz              # this process's addressable shards
    <dir>/step_<N>/              # atomic rename after fsync — a crashed
                                 # writer never leaves a half-checkpoint

Restore reassembles global arrays from shard files and re-shards onto the
*current* mesh, which may differ from the writer's (elastic scaling: a host
is lost, the data axis shrinks, training resumes from the same step).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


_NATIVE_KINDS = set('fiub')


def _storable(a: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bf16 etc.) — stage through float32; the
    manifest records the true dtype for restore."""
    a = np.asarray(a)
    if a.dtype.kind in _NATIVE_KINDS and a.dtype.name != 'bfloat16':
        return a
    return a.astype(np.float32)


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = '/'.join(str(getattr(p, 'key', getattr(p, 'idx', p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, *, process_index: Optional[int] = None
         ) -> str:
    """Write one checkpoint; returns the committed directory."""
    pidx = jax.process_index() if process_index is None else process_index
    tmp = os.path.join(ckpt_dir, f'step_{step}.tmp')
    final = os.path.join(ckpt_dir, f'step_{step}')
    os.makedirs(tmp, exist_ok=True)

    arrays: Dict[str, np.ndarray] = {}
    manifest_leaves = {}
    for key, leaf in _flatten_with_paths(tree):
        leaf = jax.numpy.asarray(leaf) if np.isscalar(leaf) else leaf
        shards = getattr(leaf, 'addressable_shards', None)
        if shards is None:  # plain numpy
            arrays[f'{key}::0'] = _storable(leaf)
            manifest_leaves[key] = {
                'shape': list(np.shape(leaf)),
                'dtype': str(np.asarray(leaf).dtype),
                'shards': {'0': [[0, n] for n in np.shape(leaf)]},
            }
            continue
        entry = {'shape': list(leaf.shape), 'dtype': str(leaf.dtype),
                 'shards': {}}
        seen_keys = set()
        for sh in shards:
            idx = sh.index  # tuple of slices into the global array
            bounds = [[(s.start or 0),
                       (s.stop if s.stop is not None else dim)]
                      for s, dim in zip(idx, leaf.shape)]
            bkey = json.dumps(bounds)
            if bkey in seen_keys:
                continue  # replicated shard — store once
            seen_keys.add(bkey)
            sid = f'{len(entry["shards"])}'
            arrays[f'{key}::{sid}'] = _storable(sh.data)
            entry['shards'][sid] = bounds
        manifest_leaves[key] = entry

    np.savez(os.path.join(tmp, f'host{pidx}.npz'), **arrays)
    manifest = {'step': step, 'leaves': manifest_leaves,
                'n_processes': jax.process_count()}
    with open(os.path.join(tmp, 'manifest.json'), 'w') as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split('_', 1)[1]) for d in os.listdir(ckpt_dir)
             if d.startswith('step_') and not d.endswith('.tmp')]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, *, shardings=None):
    """Rebuild ``target_tree``-structured arrays from a checkpoint.

    ``shardings``: optional pytree of NamedShardings for the *current* mesh —
    global arrays are re-sharded onto it (elastic restore).  Without it,
    plain numpy arrays are returned.
    """
    d = os.path.join(ckpt_dir, f'step_{step}')
    with open(os.path.join(d, 'manifest.json')) as f:
        manifest = json.load(f)

    hosts = [fn for fn in os.listdir(d) if fn.endswith('.npz')]
    stores = [np.load(os.path.join(d, fn)) for fn in hosts]

    def assemble(key: str, entry) -> np.ndarray:
        dt = entry['dtype']
        buf_dt = np.float32 if np.dtype(dt).kind not in _NATIVE_KINDS \
            or dt == 'bfloat16' else np.dtype(dt)
        out = np.zeros(entry['shape'], dtype=buf_dt)
        filled = np.zeros(entry['shape'], dtype=bool) if entry['shape'] else None
        for store in stores:
            for sid, bounds in entry['shards'].items():
                akey = f'{key}::{sid}'
                if akey not in store:
                    continue
                sl = tuple(slice(lo, hi) for lo, hi in bounds)
                out[sl] = store[akey]
                if filled is not None:
                    filled[sl] = True
        if filled is not None:
            assert filled.all(), f'checkpoint leaf {key} has holes'
        return out

    leaves = {}
    for key, entry in manifest['leaves'].items():
        leaves[key] = assemble(key, entry)

    flat_target = _flatten_with_paths(target_tree)
    _, treedef = jax.tree_util.tree_flatten(target_tree)
    ordered = []
    for key, tgt in flat_target:
        arr = leaves[key]
        want = np.dtype(jax.numpy.asarray(tgt).dtype
                        if not hasattr(tgt, 'dtype') else tgt.dtype)
        ordered.append(arr.astype(want))
    restored = jax.tree_util.tree_unflatten(treedef, ordered)

    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored, manifest['step']


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split('_', 1)[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith('step_') and not d.endswith('.tmp'))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f'step_{s}'), ignore_errors=True)
