"""Deterministic synthetic token pipeline with background prefetch.

Real deployments swap in a tokenized corpus reader; everything downstream
(shapes, sharding, determinism contract) is identical.  Batches are a pure
function of (seed, step), so restart-after-failure resumes bit-identically —
the property the checkpoint/restart test asserts.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    # structured synthetic data: repeated n-grams make the LM loss actually
    # decrease, so convergence tests have signal
    ngram: int = 8


def batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """The batch for a given step (pure function — restart-safe)."""
    rng = np.random.default_rng((cfg.seed << 32) ^ step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # n-gram language: each sequence repeats a per-sequence n-gram with noise
    grams = rng.integers(1, v, size=(b, cfg.ngram))
    reps = -(-s // cfg.ngram) + 1
    seq = np.tile(grams, (1, reps))[:, : s + 1]
    noise = rng.random((b, s + 1)) < 0.05
    seq = np.where(noise, rng.integers(1, v, size=(b, s + 1)), seq)
    return {
        'tokens': seq[:, :-1].astype(np.int32),
        'labels': seq[:, 1:].astype(np.int32),
    }


class Prefetcher:
    """Double-buffered host pipeline: a background thread stays one batch
    ahead so host data generation overlaps device compute."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_at(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
