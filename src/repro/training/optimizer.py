"""AdamW (hand-rolled — no optax in this environment) with ZeRO-1 moment
sharding hooks.

Moments are pytrees shaped like params.  ``moment_axes`` derives their logical
sharding from the param axes; with ``zero1=True`` the first dimension that is
unsharded in the param spec is additionally sharded over the data axis —
optimizer state then scales O(1/|data|) per device on top of TP.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0          # global-norm clip; 0 disables
    warmup_steps: int = 100
    decay_steps: int = 10_000       # cosine decay horizon
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup → cosine decay (f32 scalar, jit-safe)."""
    step = step.astype(jnp.float32) if hasattr(step, 'astype') else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {
        'step': jnp.zeros((), jnp.int32),
        'mu': jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params),
        'nu': jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state
                 ) -> Tuple[Any, dict, dict]:
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    step = opt_state['step'] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        step_v = mhat / (jnp.sqrt(nhat) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step_v + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(opt_state['mu'])
    flat_nu = tdef.flatten_up_to(opt_state['nu'])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {'step': step, 'mu': new_mu, 'nu': new_nu}
    return new_p, new_state, {'lr': lr, 'grad_norm': gnorm}


# ---------------------------------------------------------------------------
# Sharding of optimizer state
# ---------------------------------------------------------------------------

def zero1_moment_specs(param_shapes, param_specs, mesh, data_axes=('pod', 'data')):
    """ZeRO-1: shard each moment over the data axis on top of the param's TP
    spec — the first dim that is unsharded in the param spec and divisible by
    the data-axis size gets the data axes (PartitionSpec level, needs shapes
    for the divisibility check)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def one(shape_leaf, sharding):
        spec = sharding.spec if hasattr(sharding, 'spec') else sharding
        parts = list(spec) + [None] * (len(shape_leaf.shape) - len(spec))
        for i, (dim, p) in enumerate(zip(shape_leaf.shape, parts)):
            if p is None and dim % n == 0 and dim >= n:
                parts[i] = axes if len(axes) > 1 else axes[0]
                break
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, param_shapes, param_specs)


def opt_state_specs(param_specs, mesh, *, zero1: bool = False,
                    param_shapes=None):
    """NamedSharding tree for the optimizer state."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    if zero1:
        assert param_shapes is not None, 'zero1 needs param shapes'
        m = zero1_moment_specs(param_shapes, param_specs, mesh)
    else:
        m = param_specs
    return {'step': NamedSharding(mesh, P()), 'mu': m, 'nu': m}
