"""Distributed train step: microbatch gradient accumulation, remat (inside
the model's scan-over-layers), AdamW, ZeRO-1 moment sharding.

``make_train_step(model, mesh)`` returns (jitted_step, in/out shardings).
Microbatching splits the global batch along its leading axis and scans,
accumulating f32 grads — under XLA async collectives the DP reduce of
microbatch *i* overlaps the compute of *i+1*.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    TRAIN_RULES, axis_rules, logical_to_spec, shaped_spec, tree_spec_shaped)
from repro.models.api import Model
from repro.training import optimizer as opt


def param_specs(model: Model, mesh: Mesh, rules=None):
    return tree_spec_shaped(model.param_axes(), model.param_shapes(),
                            rules or TRAIN_RULES, mesh)


def batch_specs(model: Model, shape, mesh: Mesh, rules=None):
    rules = rules or TRAIN_RULES
    specs = model.input_specs(shape)
    return {k: NamedSharding(mesh, shaped_spec(specs[k].shape, v, rules, mesh))
            for k, v in model.input_axes(shape).items()}


def make_train_step(model: Model, mesh: Optional[Mesh], *,
                    opt_cfg: Optional[opt.AdamWConfig] = None,
                    microbatches: int = 1,
                    zero1: bool = True,
                    rules=None,
                    donate: bool = True):
    """Returns (step_fn, make_shardings).

    step_fn(params, opt_state, batch) → (params, opt_state, metrics).
    Works meshless (CPU tests) and under any (data[,pod],model) mesh.
    """
    rules = rules or TRAIN_RULES
    ocfg = opt_cfg or opt.AdamWConfig()

    def loss_fn(params, mb):
        with axis_rules(mesh, rules):
            loss, aux = model.loss_fn(params, mb)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(carry, mb):
                gsum, lsum = carry
                (l, _aux), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            gzero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (gzero, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            aux = {}

        params, opt_state, metrics = opt.adamw_update(
            ocfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    def make_shardings(shape):
        assert mesh is not None
        pspec = param_specs(model, mesh, rules)
        ospec = opt.opt_state_specs(
            pspec, mesh, zero1=zero1,
            param_shapes=model.param_shapes() if zero1 else None)
        bspec = batch_specs(model, shape, mesh, rules)
        out_metrics = {k: NamedSharding(mesh, P())
                       for k in ('lr', 'grad_norm', 'loss')}
        return dict(
            in_shardings=(pspec, ospec, bspec),
            out_shardings=(pspec, ospec, out_metrics),
        )

    if mesh is None:
        return jax.jit(step), None

    def jitted(shape):
        sh = make_shardings(shape)
        return jax.jit(step, in_shardings=sh['in_shardings'],
                       out_shardings=sh['out_shardings'],
                       donate_argnums=(0, 1) if donate else ())

    return jitted, make_shardings


def make_serve_step(model: Model, mesh: Optional[Mesh], shape, *, rules=None):
    """Jitted prefill or decode step for an execution shape (dry-run + serve).

    Returns (step_fn, in_shardings, out_shardings are inferred).
    """
    from repro.distributed.sharding import LONG_SERVE_RULES, SERVE_RULES
    if rules is None:
        rules = LONG_SERVE_RULES if shape.name == 'long_500k' else SERVE_RULES
    long_ctx = shape.name == 'long_500k'

    def prefill_step(params, cache, batch):
        with axis_rules(mesh, rules):
            return model.prefill_fn(params, cache, batch)

    def decode_step(params, cache, batch):
        with axis_rules(mesh, rules):
            return model.decode_fn(params, cache, batch,
                                   long_context=long_ctx)

    fn = prefill_step if shape.kind == 'prefill' else decode_step
    if mesh is None:
        return jax.jit(fn), None

    pspec = tree_spec_shaped(model.param_axes(), model.param_shapes(),
                             rules, mesh)
    cspec = tree_spec_shaped(model.cache_axes(shape),
                             model.cache_shapes(shape), rules, mesh)
    ispecs = model.input_specs(shape)
    bspec = {k: NamedSharding(mesh, shaped_spec(ispecs[k].shape, v, rules, mesh))
             for k, v in model.input_axes(shape).items()}
    logits_spec = NamedSharding(
        mesh, shaped_spec((shape.global_batch, model.cfg.vocab_size),
                          ('batch', 'vocab'), rules, mesh))
    jitted = jax.jit(fn, in_shardings=(pspec, cspec, bspec),
                     out_shardings=(cspec, logits_spec),
                     donate_argnums=(1,))
    return jitted, dict(params=pspec, cache=cspec, batch=bspec)
