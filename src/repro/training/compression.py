"""int8-compressed gradient all-reduce with error feedback.

The data-parallel gradient reduce moves `params × 4` bytes per step per
device; quantizing to int8 cuts collective bytes 4× (2× vs bf16).  Scheme:

- per-leaf symmetric quantization, scale = max|g| / (127 / n_shards) so the
  *sum* over shards still fits int8 (psum preserves dtype → int8 stays on
  the wire);
- error feedback: the quantization residual is carried to the next step, so
  compression error accumulates to O(1) instead of O(steps) (SGD with
  error-feedback converges at the uncompressed rate).

Implemented with shard_map over the data axes; the model axis can stay auto
(params sharded over 'model' are untouched — each model shard reduces its own
slice over data).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import manual_shard_map


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(g, err, axes: Tuple[str, ...], n_shards: int):
    """One leaf: error-feedback int8 quantize → psum → dequantize.

    Two collective rounds: (1) pmax of the per-shard max|g| (one f32 scalar —
    negligible bytes) so every shard quantizes at the SAME scale, then
    (2) psum of the int8 payload.  The shared scale reserves 1/n headroom so
    the cross-shard sum cannot overflow int8.

    Returns (mean-reduced f32 gradient, new error residual).
    """
    g = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axes)     # scalar round
    qmax = 127.0 / max(n_shards, 1)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(g / scale), -qmax, qmax).astype(jnp.int8)
    new_err = g - dequantize(q, scale)
    qsum = jax.lax.psum(q, axes)                       # int8 on the wire
    return dequantize(qsum, scale) / n_shards, new_err


def make_compressed_allreduce(mesh: Mesh, grad_specs,
                              data_axes: Sequence[str] = ('pod', 'data')):
    """Returns fn(grads, err) → (reduced_grads, new_err) under shard_map.

    ``grad_specs``: pytree of PartitionSpecs for the gradients (model-axis
    sharding preserved; the data axes must not appear — grads are per-shard
    values being reduced).
    """
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def to_local_spec(spec):
        # inside shard_map the grads are manual over data axes but those axes
        # don't appear in grad tensors; specs pass through unchanged
        return spec

    specs = jax.tree.map(to_local_spec, grad_specs,
                         is_leaf=lambda s: isinstance(s, P))

    @functools.partial(
        manual_shard_map, mesh=mesh,
        in_specs=(specs, specs), out_specs=(specs, specs))
    def reduce_fn(grads, err):
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err)
        out = [compressed_psum_leaf(g, e, axes, n)
               for g, e in zip(flat_g, flat_e)]
        new_g = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_e = jax.tree.unflatten(tdef, [o[1] for o in out])
        return new_g, new_e

    return reduce_fn


def init_error_state(grads_like):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
