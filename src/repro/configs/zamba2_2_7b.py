"""zamba2-2.7b — hybrid Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf].

54L Mamba2 (d_model=2560, ssm_state=64), one SHARED attention+MLP block
(32H over concat(hidden, embed) width 2*d_model, d_ff=10240) applied every 6
Mamba2 layers (9 applications), vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='zamba2-2.7b',
    family='hybrid',
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10_240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_kernel=4,
    hybrid_attn_every=6,
    hybrid_attn_heads=32,
    hybrid_attn_d_ff=10_240,
    tie_embeddings=True,
)
