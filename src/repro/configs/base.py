"""Config system: architectures and input shapes.

Every assigned architecture is a ``ModelConfig`` in ``src/repro/configs/<id>.py``
with the exact published dimensions.  ``reduced()`` variants (same family, tiny
dims) power CPU smoke tests; the full configs are only ever lowered with
``jax.ShapeDtypeStruct`` stand-ins in the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'encdec' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0  # llama4-style always-on shared expert

    # --- SSM (rwkv6 / mamba2) ---
    ssm_state: int = 0          # mamba2 state size N
    ssm_head_dim: int = 64      # rwkv6 wkv head dim / mamba2 head dim P
    ssm_expand: int = 2         # mamba2 inner expansion
    conv_kernel: int = 4        # mamba2 depthwise conv width

    # --- hybrid (zamba2): shared attn+mlp block applied every k SSM layers ---
    hybrid_attn_every: int = 0
    hybrid_attn_heads: int = 0
    hybrid_attn_d_ff: int = 0

    # --- enc-dec (seamless-m4t) ---
    enc_layers: int = 0
    dec_layers: int = 0

    # --- modality frontend stub: None | 'audio' | 'vision' ---
    frontend: Optional[str] = None
    frontend_tokens: int = 0  # prefix embedding count injected at prefill

    # --- serving ---
    page_size: int = 16           # tokens per KV page
    pages_per_handle: int = 64    # equal-size reclamation handles (paper §5)

    # ---------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def is_attention_free(self) -> bool:
        return self.family == 'ssm'

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic-memory decode path exists (SSM state or hybrid)."""
        return self.family in ('ssm', 'hybrid')

    # ------------------------------------------------------------ param math
    def _attn_params(self, d_in: Optional[int] = None) -> int:
        d = d_in if d_in is not None else self.d_model
        p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * self.d_model
        if self.qk_norm:
            p += 2 * self.hd
        return p

    def _mlp_params(self, d_ff: Optional[int] = None) -> int:
        f = d_ff if d_ff is not None else self.d_ff
        return 3 * self.d_model * f  # SwiGLU: gate, up, down

    def param_count(self) -> int:
        """Total parameters N (for MODEL_FLOPS = 6·N·D roofline accounting)."""
        D = self.d_model
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        if self.family in ('dense', 'vlm'):
            per = self._attn_params() + self._mlp_params() + 2 * D
            return emb + self.n_layers * per + D
        if self.family == 'moe':
            expert = self._mlp_params()
            per = (self._attn_params() + 2 * D + D * self.n_experts
                   + (self.n_experts + self.n_shared_experts) * expert)
            return emb + self.n_layers * per + D
        if self.family == 'ssm':  # rwkv6
            H = D // self.ssm_head_dim
            tm = (6 * D          # mu params (token-shift mixes: r,k,v,w,g,x)
                  + 2 * D * 32 + 5 * 32 * D   # low-rank data-dep decay/mix (lora dim 32)
                  + 4 * D * D    # r,k,v,g projections
                  + D * D        # output
                  + H * self.ssm_head_dim  # u (bonus)
                  + 2 * D)       # ln_x scale + decay base
            cm = 2 * D * self.d_ff + self.d_ff * 0 + self.d_ff * D  # channel mix (k,v) + recv
            per = tm + cm + 2 * D
            return emb + self.n_layers * per + D
        if self.family == 'hybrid':  # zamba2
            d_in = self.ssm_expand * D
            H = d_in // self.ssm_head_dim
            mamba = (D * (2 * d_in + 2 * self.ssm_state + H)  # in_proj (x,z,B,C,dt)
                     + self.conv_kernel * (d_in + 2 * self.ssm_state)
                     + 2 * H + d_in * D + d_in)
            per = mamba + 2 * D
            n_apps = self.n_layers // max(self.hybrid_attn_every, 1)
            d2 = 2 * D
            shared_hd = d2 // self.hybrid_attn_heads
            shared = (3 * d2 * self.hybrid_attn_heads * shared_hd
                      + self.hybrid_attn_heads * shared_hd * D
                      + 3 * D * self.hybrid_attn_d_ff + 2 * d2)
            return emb + self.n_layers * per + shared + n_apps * 0 + D
        if self.family == 'encdec':
            per_enc = self._attn_params() + self._mlp_params() + 2 * D
            per_dec = 2 * self._attn_params() + self._mlp_params() + 3 * D
            return emb + self.enc_layers * per_enc + self.dec_layers * per_dec + 2 * D
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only top-k + shared experts)."""
        if self.family != 'moe':
            return self.param_count()
        expert = self._mlp_params()
        total = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.moe_top_k) * expert
        return total - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    'train_4k':    ShapeConfig('train_4k', 4_096, 256, 'train'),
    'prefill_32k': ShapeConfig('prefill_32k', 32_768, 32, 'prefill'),
    'decode_32k':  ShapeConfig('decode_32k', 32_768, 128, 'decode'),
    'long_500k':   ShapeConfig('long_500k', 524_288, 1, 'decode'),
}


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs, per DESIGN.md shape-skip rules."""
    if shape.name == 'long_500k' and not cfg.supports_long_context:
        return False, 'skipped/long-context-full-attention'
    return True, 'ok'


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=16,
    )
    if cfg.family == 'moe':
        small.update(n_experts=4, moe_top_k=min(cfg.moe_top_k, 2) or 1)
    if cfg.family == 'ssm':
        small.update(d_model=64, ssm_head_dim=16, d_ff=128, n_heads=4, n_kv_heads=4)
    if cfg.family == 'hybrid':
        small.update(n_layers=4, hybrid_attn_every=2, hybrid_attn_heads=4,
                     hybrid_attn_d_ff=128, ssm_state=8, ssm_head_dim=16)
    if cfg.family == 'encdec':
        small.update(enc_layers=2, dec_layers=2)
    if cfg.frontend is not None:
        small.update(frontend_tokens=8)
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + '-reduced', **small)
