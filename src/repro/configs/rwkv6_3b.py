"""rwkv6-3b "Finch" — attention-free, data-dependent decay [arXiv:2404.05892; hf].

32L, d_model=2560 (40 wkv heads x 64), d_ff=8960 (channel-mix), vocab=65536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='rwkv6-3b',
    family='ssm',
    n_layers=32,
    d_model=2560,
    n_heads=40,        # wkv heads = d_model / ssm_head_dim
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65_536,
    ssm_head_dim=64,
)
