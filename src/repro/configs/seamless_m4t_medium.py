"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596; hf].

12L (12 enc + 12 dec), d_model=1024, 16H (GQA kv=16 = MHA), d_ff=4096,
vocab=256206.  [audio] frontend is a STUB: input_specs() provides precomputed
speech frame embeddings (B, S_enc, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='seamless-m4t-medium',
    family='encdec',
    n_layers=12,
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    attn_bias=True,
    frontend='audio',
)
