"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L, d_model=5120, 40H (GQA kv=8), d_ff=8192 (per expert), vocab=202048.
Early fusion reduced to the instructed vision stub (prefix patch embeddings).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='llama4-scout-17b-a16e',
    family='moe',
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    n_experts=16,
    moe_top_k=1,
    n_shared_experts=1,
    qk_norm=True,
    rope_theta=5e5,
    frontend='vision',
    frontend_tokens=2048,
)
