"""valve-7b — the paper's own evaluation model class (§7.2 colocates a 7B online
model with a 7B offline model).  Mistral-7B-class dense config used by the
paper-replication benchmarks; not part of the assigned-architecture pool.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='valve-7b',
    family='dense',
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
)
