"""Architecture registry.

``get_config(arch_id)`` resolves the exact published config; ``ARCHS`` lists the
ten assigned architectures (``valve-7b`` is the paper's own eval model, used by
the benchmark suite but not part of the assigned pool).
"""
from repro.configs.base import (
    ModelConfig, ShapeConfig, SHAPES, cell_supported, reduced,
)

from repro.configs import (
    seamless_m4t_medium,
    internlm2_1_8b,
    command_r_35b,
    qwen3_14b,
    qwen3_0_6b,
    rwkv6_3b,
    llava_next_mistral_7b,
    phi3_5_moe,
    llama4_scout,
    zamba2_2_7b,
    valve_7b,
)

_ALL = {
    m.CONFIG.name: m.CONFIG
    for m in (
        seamless_m4t_medium, internlm2_1_8b, command_r_35b, qwen3_14b,
        qwen3_0_6b, rwkv6_3b, llava_next_mistral_7b, phi3_5_moe,
        llama4_scout, zamba2_2_7b, valve_7b,
    )
}

# The ten assigned architectures, in the assignment order.
ARCHS = [
    'seamless-m4t-medium',
    'internlm2-1.8b',
    'command-r-35b',
    'qwen3-14b',
    'qwen3-0.6b',
    'rwkv6-3b',
    'llava-next-mistral-7b',
    'phi3.5-moe-42b-a6.6b',
    'llama4-scout-17b-a16e',
    'zamba2-2.7b',
]


def get_config(arch: str) -> ModelConfig:
    try:
        return _ALL[arch]
    except KeyError:
        raise KeyError(f'unknown arch {arch!r}; known: {sorted(_ALL)}') from None


def all_configs():
    return dict(_ALL)


__all__ = [
    'ModelConfig', 'ShapeConfig', 'SHAPES', 'cell_supported', 'reduced',
    'ARCHS', 'get_config', 'all_configs',
]
