"""llava-next-mistral-7b — VLM, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone: mistral-7b — 32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=32000.
[vlm] frontend is a STUB: input_specs() provides precomputed anyres patch
embeddings (B, frontend_tokens, d_model); 2880 = 576 base + 4x576 tiles.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name='llava-next-mistral-7b',
    family='vlm',
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    rope_theta=1e6,
    frontend='vision',
    frontend_tokens=2880,
)
