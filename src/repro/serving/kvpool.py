"""Global paged KV pool with equal-size reclamation handles (paper §5).

Physical layout (mirrors the JAX pool arrays the engine owns):

    page 0                      — the QUARANTINE page (always mapped)
    pages 1 … n_handles·pph     — handle h owns pages [1+h·pph, 1+(h+1)·pph)

Pages are allocated from a single free list shared by all requests, so a
request's pages scatter across handles (the fragmentation the paper's
Algorithm 1 exploits).  Handles are either *online-reserved* (the MIAD
headroom H) or offline-usable.  Reclaiming a handle remaps every mapped page
in it to quarantine and transfers the handle to the reserved set — no page is
ever unmapped, so no access can fault.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

QUARANTINE_PAGE = 0


@dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    reclaims: int = 0
    reclaimed_pages: int = 0
    alloc_failures: int = 0


class KVPool:
    def __init__(self, n_handles: int, pages_per_handle: int,
                 page_size: int = 16, reserved_handles: int = 1):
        assert n_handles >= 1 and pages_per_handle >= 1
        self.n_handles = n_handles
        self.pph = pages_per_handle
        self.page_size = page_size
        self.n_pages = 1 + n_handles * pages_per_handle

        # page → owning request id (None = free); page 0 is never owned
        self.owner: List[Optional[str]] = [None] * self.n_pages
        # request id → its mapped pages, in allocation order
        self.pages_of: Dict[str, List[int]] = {}
        # request id → 'online' | 'offline'
        self.klass_of: Dict[str, str] = {}
        # free pages per handle (deque for O(1) pop)
        self.free_in_handle: List[deque] = [
            deque(self._handle_pages(h)) for h in range(n_handles)]
        # MIAD-reserved handles (online headroom), insertion-ordered for FIFO
        self.reserved: "OrderedDict[int, float]" = OrderedDict()
        for h in range(min(reserved_handles, n_handles)):
            self.reserved[h] = 0.0
        self.stats = PoolStats()

    # ------------------------------------------------------------- layout
    def _handle_pages(self, h: int) -> range:
        return range(1 + h * self.pph, 1 + (h + 1) * self.pph)

    def handle_of(self, page: int) -> int:
        assert page >= 1, 'quarantine page belongs to no handle'
        return (page - 1) // self.pph

    def reqs_of_handle(self, h: int) -> Set[str]:
        return {self.owner[p] for p in self._handle_pages(h)
                if self.owner[p] is not None}

    # ------------------------------------------------------------ queries
    def pages_of_request(self, req_id: str) -> List[int]:
        """Copy of a request's mapped pages, in allocation order."""
        return list(self.pages_of.get(req_id, ()))

    def handles_of_request(self, req_id: str) -> List[int]:
        """Sorted handles holding ≥1 page of ``req_id`` (the handles whose
        reclamation would invalidate it — orchestrator/test introspection)."""
        return sorted({self.handle_of(p)
                       for p in self.pages_of.get(req_id, ())})

    def request_ids(self, klass: Optional[str] = None) -> List[str]:
        """Live request ids holding pages, optionally filtered by class —
        the node orchestrator's per-engine occupancy view."""
        return [r for r in self.pages_of
                if klass is None or self.klass_of.get(r) == klass]

    def free_pages_for(self, klass: str) -> int:
        if klass == 'online':
            hs = self.reserved.keys()
        else:
            hs = (h for h in range(self.n_handles) if h not in self.reserved)
        return sum(len(self.free_in_handle[h]) for h in hs)

    def used_pages_for(self, klass: str) -> int:
        return sum(len(v) for r, v in self.pages_of.items()
                   if self.klass_of[r] == klass)

    def online_used_handles(self) -> int:
        """Reserved handles with ≥1 online page (MIAD pressure signal)."""
        used = 0
        for h in self.reserved:
            if any(self.owner[p] is not None for p in self._handle_pages(h)):
                used += 1
        return used

    # ---------------------------------------------------------- alloc/free
    def alloc(self, req_id: str, n: int, klass: str = 'offline'
              ) -> Optional[List[int]]:
        """Allocate ``n`` pages for ``req_id``; None if insufficient."""
        assert klass in ('online', 'offline')
        # ids are node-global: a second alloc under a live id means two
        # engines minted colliding request ids (their pages would merge)
        assert req_id not in self.pages_of, \
            f'request id {req_id!r} already holds pages'
        if klass == 'online':
            handles = list(self.reserved.keys())
        else:
            handles = [h for h in range(self.n_handles)
                       if h not in self.reserved]
        if sum(len(self.free_in_handle[h]) for h in handles) < n:
            self.stats.alloc_failures += 1
            return None
        got: List[int] = []
        for h in handles:
            fl = self.free_in_handle[h]
            while fl and len(got) < n:
                p = fl.popleft()
                self.owner[p] = req_id
                got.append(p)
            if len(got) == n:
                break
        self.pages_of.setdefault(req_id, []).extend(got)
        self.klass_of[req_id] = klass
        self.stats.allocs += 1
        return got

    def free(self, req_id: str) -> int:
        """Release every page of ``req_id``; returns #pages freed."""
        pages = self.pages_of.pop(req_id, [])
        self.klass_of.pop(req_id, None)
        for p in pages:
            if self.owner[p] == req_id:
                self.owner[p] = None
                self.free_in_handle[self.handle_of(p)].append(p)
        self.stats.frees += 1
        return len(pages)

    # ---------------------------------------------------------- MIAD hooks
    def offline_handles(self) -> List[int]:
        return [h for h in range(self.n_handles) if h not in self.reserved]

    def empty_offline_handles(self) -> List[int]:
        return [h for h in self.offline_handles()
                if len(self.free_in_handle[h]) == self.pph]

    def reserve_handle(self, h: int, now: float = 0.0) -> None:
        """Move a (fully-free) handle into the online reservation."""
        assert h not in self.reserved
        assert len(self.free_in_handle[h]) == self.pph, \
            'reserve requires a reclaimed/empty handle'
        self.reserved[h] = now

    def release_reserved_handle(self) -> Optional[int]:
        """MIAD additive decrease: return the emptiest reserved handle to
        offline use (never one holding online pages)."""
        for h in list(self.reserved.keys()):
            if len(self.free_in_handle[h]) == self.pph:
                del self.reserved[h]
                return h
        return None

    # ---------------------------------------------------------- reclamation
    def reclaim_handles(self, handles: Sequence[int], now: float = 0.0
                        ) -> Dict[str, List[int]]:
        """Remap every mapped page of ``handles`` to quarantine and move the
        handles to the online reservation.

        Returns {offline request id: [its invalidated page ids]} — the
        paper's "invalidated page IDs exposed to the framework".  The caller
        (ValveRuntime) must have disabled offline compute first; this class
        only records, the runtime asserts the ordering invariant.
        """
        invalidated: Dict[str, List[int]] = {}
        for h in handles:
            assert h not in self.reserved, 'cannot reclaim a reserved handle'
            for p in self._handle_pages(h):
                r = self.owner[p]
                if r is not None:
                    invalidated.setdefault(r, []).append(p)
                    self.owner[p] = None
                    self.stats.reclaimed_pages += 1
            self.free_in_handle[h] = deque(self._handle_pages(h))
            self.reserved[h] = now
        # an invalidated request loses *all* its KV (it restarts from its
        # prompt+generated tokens), so release its surviving pages too
        for r in list(invalidated.keys()):
            self.free(r)
        self.stats.reclaims += 1
        return invalidated

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        seen: Set[int] = set()
        for r, pages in self.pages_of.items():
            for p in pages:
                assert p != QUARANTINE_PAGE, 'live request maps quarantine'
                assert self.owner[p] == r, (r, p, self.owner[p])
                assert p not in seen, f'page {p} double-owned'
                seen.add(p)
        for h in range(self.n_handles):
            for p in self.free_in_handle[h]:
                assert self.owner[p] is None
                assert p not in seen, f'page {p} both free and owned'
