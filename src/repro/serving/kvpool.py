"""Global paged KV pool with equal-size reclamation handles (paper §5).

Physical layout (mirrors the JAX pool arrays the engine owns):

    page 0                      — the QUARANTINE page (always mapped)
    pages 1 … n_handles·pph     — handle h owns pages [1+h·pph, 1+(h+1)·pph)

Pages are allocated from a single free list shared by all requests, so a
request's pages scatter across handles (the fragmentation the paper's
Algorithm 1 exploits).  Handles are either *online-reserved* (the MIAD
headroom H) or offline-usable.  Reclaiming a handle remaps every mapped page
in it to quarantine and transfers the handle to the reserved set — no page is
ever unmapped, so no access can fault.

Since the Memory-plane API v1 (``repro.core.memory``), this class is the
**physical backend**: it tracks page ownership per *owner id* (a lease id or
an internal shared-prefix block id) and knows nothing about refcounts,
prefix sharing or surviving prefixes — those live in
:class:`~repro.core.memory.MemoryPlane`.  Owner-granular partial frees
(:meth:`free_pages`) and in-place growth (:meth:`alloc_more`) exist for the
plane; ``reclaim_handles(free_survivors=False)`` leaves a victim's
untouched pages mapped so the plane can keep the surviving prefix.

Occupancy queries (``free_pages_for`` / ``used_pages_for`` /
``online_used_handles``) are O(1) incremental counters — they run every
scheduler tick; ``check_invariants`` cross-checks them against full scans.
"""
from __future__ import annotations

import itertools
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set

QUARANTINE_PAGE = 0

# default pool names ('pool0', 'pool1', …) — stable within a process so
# PageMigration events can name src/dst pools without explicit naming
_POOL_SEQ = itertools.count()


@dataclass
class PoolStats:
    allocs: int = 0
    frees: int = 0
    reclaims: int = 0
    reclaimed_pages: int = 0
    alloc_failures: int = 0


class KVPool:
    def __init__(self, n_handles: int, pages_per_handle: int,
                 page_size: int = 16, reserved_handles: int = 1,
                 name: Optional[str] = None):
        assert n_handles >= 1 and pages_per_handle >= 1
        self.n_handles = n_handles
        self.pph = pages_per_handle
        self.page_size = page_size
        self.n_pages = 1 + n_handles * pages_per_handle
        self.name = name or f'pool{next(_POOL_SEQ)}'
        # optional typed event stream (repro.core.events.EventBus): when a
        # runtime/orchestrator attaches one, transfer_pages publishes a
        # PageMigration per ownership move so transfers are observable
        self.bus = None

        # page → owning id (None = free); page 0 is never owned
        self.owner: List[Optional[str]] = [None] * self.n_pages
        # owner id → its mapped pages, in allocation order
        self.pages_of: Dict[str, List[int]] = {}
        # owner id → 'online' | 'offline'
        self.klass_of: Dict[str, str] = {}
        # free pages per handle (deque for O(1) pop)
        self.free_in_handle: List[deque] = [
            deque(self._handle_pages(h)) for h in range(n_handles)]
        # MIAD-reserved handles (online headroom), insertion-ordered for FIFO
        self.reserved: "OrderedDict[int, float]" = OrderedDict()
        for h in range(min(reserved_handles, n_handles)):
            self.reserved[h] = 0.0
        self.stats = PoolStats()
        # -- incremental occupancy counters (the per-tick hot path) --------
        # free pages split by reservation status; mapped pages per handle;
        # used pages per klass; #reserved handles with ≥1 mapped page
        self._free_reserved = sum(
            len(self.free_in_handle[h]) for h in self.reserved)
        self._free_offline = (n_handles * pages_per_handle
                              - self._free_reserved)
        self._mapped_in_handle: List[int] = [0] * n_handles
        self._used_by_klass: Dict[str, int] = {'online': 0, 'offline': 0}
        self._used_reserved_handles = 0

    # ------------------------------------------------------------- layout
    def _handle_pages(self, h: int) -> range:
        return range(1 + h * self.pph, 1 + (h + 1) * self.pph)

    def handle_of(self, page: int) -> int:
        assert page >= 1, 'quarantine page belongs to no handle'
        return (page - 1) // self.pph

    def reqs_of_handle(self, h: int) -> Set[str]:
        return {self.owner[p] for p in self._handle_pages(h)
                if self.owner[p] is not None}

    # ------------------------------------------------- counter transitions
    def _note_free(self, h: int, delta: int) -> None:
        if h in self.reserved:
            self._free_reserved += delta
        else:
            self._free_offline += delta

    def _note_mapped(self, h: int, delta: int) -> None:
        before = self._mapped_in_handle[h]
        self._mapped_in_handle[h] = before + delta
        if h in self.reserved:
            if before == 0 and delta > 0:
                self._used_reserved_handles += 1
            elif before + delta == 0 and before > 0:
                self._used_reserved_handles -= 1

    # ------------------------------------------------------------ queries
    def pages_of_request(self, req_id: str) -> List[int]:
        """Copy of an owner's mapped pages, in allocation order."""
        return list(self.pages_of.get(req_id, ()))

    def handles_of_request(self, req_id: str) -> List[int]:
        """Sorted handles holding ≥1 page of ``req_id`` (the handles whose
        reclamation would invalidate it — orchestrator/test introspection)."""
        return sorted({self.handle_of(p)
                       for p in self.pages_of.get(req_id, ())})

    def request_ids(self, klass: Optional[str] = None) -> List[str]:
        """Live owner ids holding pages, optionally filtered by class —
        includes the memory plane's internal shared-prefix block ids."""
        return [r for r in self.pages_of
                if klass is None or self.klass_of.get(r) == klass]

    def free_pages_for(self, klass: str) -> int:
        return (self._free_reserved if klass == 'online'
                else self._free_offline)

    def used_pages_for(self, klass: str) -> int:
        return self._used_by_klass.get(klass, 0)

    def online_used_handles(self) -> int:
        """Reserved handles with ≥1 mapped page (MIAD pressure signal)."""
        return self._used_reserved_handles

    # ---------------------------------------------------------- alloc/free
    def _take_pages(self, req_id: str, n: int,
                    handles: Sequence[int]) -> Optional[List[int]]:
        if sum(len(self.free_in_handle[h]) for h in handles) < n:
            self.stats.alloc_failures += 1
            return None
        got: List[int] = []
        for h in handles:
            fl = self.free_in_handle[h]
            take = min(len(fl), n - len(got))
            for _ in range(take):
                p = fl.popleft()
                self.owner[p] = req_id
                got.append(p)
            if take:
                self._note_free(h, -take)
                self._note_mapped(h, take)
            if len(got) == n:
                break
        return got

    def _klass_handles(self, klass: str) -> List[int]:
        assert klass in ('online', 'offline')
        if klass == 'online':
            return list(self.reserved.keys())
        return [h for h in range(self.n_handles) if h not in self.reserved]

    def alloc(self, req_id: str, n: int, klass: str = 'offline'
              ) -> Optional[List[int]]:
        """Allocate ``n`` pages for a NEW owner ``req_id``; None if
        insufficient."""
        # ids are node-global: a second alloc under a live id means two
        # engines minted colliding request ids (their pages would merge)
        assert req_id not in self.pages_of, \
            f'request id {req_id!r} already holds pages'
        got = self._take_pages(req_id, n, self._klass_handles(klass))
        if got is None:
            return None
        self.pages_of[req_id] = got
        self.klass_of[req_id] = klass
        self._used_by_klass[klass] += n
        self.stats.allocs += 1
        return got

    def alloc_more(self, req_id: str, n: int) -> Optional[List[int]]:
        """Grow an EXISTING owner by ``n`` pages (lease extension); the
        klass is the one recorded at first allocation."""
        assert req_id in self.pages_of, f'{req_id!r} holds no pages'
        klass = self.klass_of[req_id]
        got = self._take_pages(req_id, n, self._klass_handles(klass))
        if got is None:
            return None
        self.pages_of[req_id].extend(got)
        self._used_by_klass[klass] += n
        self.stats.allocs += 1
        return got

    def free(self, req_id: str) -> int:
        """Release every page of ``req_id``; returns #pages freed.  A free
        for an id that holds no pages is a NO-OP and does not count as a
        lifecycle event (``stats.frees`` unchanged)."""
        pages = self.pages_of.pop(req_id, None)
        if pages is None:
            self.klass_of.pop(req_id, None)
            return 0
        klass = self.klass_of.pop(req_id, None)
        released = 0
        for p in pages:
            if self.owner[p] == req_id:
                self._release_page(p)
                released += 1
        if klass is not None:
            self._used_by_klass[klass] -= released
        self.stats.frees += 1
        return len(pages)

    def free_pages(self, req_id: str, pages: Sequence[int]) -> int:
        """Release a SUBSET of an owner's pages (memory-plane partial free:
        surviving-prefix tails, per-page refcount drops).  Single pass over
        the owner's list — callers batch drops per owner so a request
        completion stays O(pages).  Does not count as a whole-owner
        ``stats.frees`` lifecycle event."""
        held = self.pages_of.get(req_id)
        if not held:
            return 0
        drop = set(pages)
        kept: List[int] = []
        freed = 0
        for p in held:
            if p in drop:
                assert self.owner[p] == req_id, (p, self.owner[p], req_id)
                self._release_page(p)
                freed += 1
            else:
                kept.append(p)
        if freed:
            self._used_by_klass[self.klass_of[req_id]] -= freed
        if kept:
            self.pages_of[req_id] = kept
        else:
            del self.pages_of[req_id]
            self.klass_of.pop(req_id, None)
        return freed

    def transfer_pages(self, old_owner: str, pages: Sequence[int],
                       new_owner: str,
                       dst_pool: Optional['KVPool'] = None
                       ) -> Optional[List[int]]:
        """Move pages from one owner id to another.

        Intra-pool (``dst_pool`` None or self): pure ownership re-key
        (memory-plane use: shared pages outliving their creating lease
        move to an internal block id so the request id can be
        re-admitted).  Klass-preserving; no page moves physically; returns
        the (unchanged) page ids.

        Cross-pool (``dst_pool`` another KVPool): the Valve rescue path —
        allocate the same count in ``dst_pool`` under ``new_owner``
        (klass-preserving), free the source pages here, and return the
        NEW page ids in the destination pool (page ids are pool-local).
        Returns None — with the source untouched — if the destination
        cannot fit the transfer.  Either pool with a bus attached
        publishes a typed PageMigration event.
        """
        if dst_pool is not None and dst_pool is not self:
            return self._transfer_cross_pool(old_owner, list(pages),
                                             new_owner, dst_pool)
        held = self.pages_of[old_owner]
        klass = self.klass_of[old_owner]
        moved = 0
        for p in pages:
            assert self.owner[p] == old_owner, (p, self.owner[p], old_owner)
            self.owner[p] = new_owner
            held.remove(p)
            self.pages_of.setdefault(new_owner, []).append(p)
            moved += 1
        if moved:
            self.klass_of.setdefault(new_owner, klass)
            assert self.klass_of[new_owner] == klass
        if not held:
            del self.pages_of[old_owner]
            self.klass_of.pop(old_owner, None)
        if moved and self.bus is not None:
            self._publish_migration(new_owner, pages)
        return list(pages)

    def _transfer_cross_pool(self, old_owner: str, pages: List[int],
                             new_owner: str, dst: 'KVPool'
                             ) -> Optional[List[int]]:
        klass = self.klass_of[old_owner]
        for p in pages:
            assert self.owner[p] == old_owner, (p, self.owner[p], old_owner)
        if new_owner in dst.pages_of:
            got = dst.alloc_more(new_owner, len(pages))
        else:
            got = dst.alloc(new_owner, len(pages), klass)
        if got is None:
            return None             # destination full — source untouched
        self.free_pages(old_owner, pages)
        for bus in {id(self.bus): self.bus, id(dst.bus): dst.bus}.values():
            if bus is not None:
                from repro.core.events import PageMigration
                bus.publish(PageMigration, owner=new_owner,
                            n_pages=len(pages), src_pool=self.name,
                            dst_pool=dst.name, cross_pool=True,
                            src_pages=tuple(pages), dst_pages=tuple(got))
        return got

    def _publish_migration(self, owner: str, pages: Sequence[int]) -> None:
        from repro.core.events import PageMigration
        self.bus.publish(PageMigration, owner=owner, n_pages=len(pages),
                         src_pool=self.name, dst_pool=self.name,
                         cross_pool=False, src_pages=tuple(pages),
                         dst_pages=tuple(pages))

    def _release_page(self, p: int) -> None:
        self.owner[p] = None
        h = self.handle_of(p)
        self.free_in_handle[h].append(p)
        self._note_free(h, 1)
        self._note_mapped(h, -1)

    # ---------------------------------------------------------- MIAD hooks
    def offline_handles(self) -> List[int]:
        return [h for h in range(self.n_handles) if h not in self.reserved]

    def empty_offline_handles(self) -> List[int]:
        return [h for h in self.offline_handles()
                if len(self.free_in_handle[h]) == self.pph]

    def reserve_handle(self, h: int, now: float = 0.0) -> None:
        """Move a (fully-free) handle into the online reservation."""
        assert h not in self.reserved
        assert len(self.free_in_handle[h]) == self.pph, \
            'reserve requires a reclaimed/empty handle'
        self._free_offline -= self.pph
        self.reserved[h] = now
        self._free_reserved += self.pph

    def release_reserved_handle(self) -> Optional[int]:
        """MIAD additive decrease: return the emptiest reserved handle to
        offline use (never one holding online pages)."""
        for h in list(self.reserved.keys()):
            if len(self.free_in_handle[h]) == self.pph:
                del self.reserved[h]
                self._free_reserved -= self.pph
                self._free_offline += self.pph
                return h
        return None

    # ---------------------------------------------------------- reclamation
    def reclaim_handles(self, handles: Sequence[int], now: float = 0.0,
                        free_survivors: bool = True) -> Dict[str, List[int]]:
        """Remap every mapped page of ``handles`` to quarantine and move the
        handles to the online reservation.

        Returns {owner id: [its invalidated page ids]} — the paper's
        "invalidated page IDs exposed to the framework".  The caller
        (ValveRuntime) must have disabled offline compute first; this class
        only records, the runtime asserts the ordering invariant.

        ``free_survivors=True`` (the legacy whole-request semantics) also
        releases every *untouched* page of each invalidated owner — the
        request restarts from token 0.  The memory plane passes ``False``
        and keeps each lease's surviving prefix mapped, freeing only the
        recompute tail itself (partial invalidation).
        """
        invalidated: Dict[str, List[int]] = {}
        for h in handles:
            assert h not in self.reserved, 'cannot reclaim a reserved handle'
            for p in self._handle_pages(h):
                r = self.owner[p]
                if r is not None:
                    invalidated.setdefault(r, []).append(p)
                    self.owner[p] = None
                    self._note_mapped(h, -1)
                    self.stats.reclaimed_pages += 1
            self._note_free(h, self.pph - len(self.free_in_handle[h]))
            self.free_in_handle[h] = deque(self._handle_pages(h))
            self.reserve_handle(h, now)
        # drop remapped pages from owner lists in ONE pass per owner (a
        # per-page list.remove would be quadratic under reclamation bursts)
        for r, pages in invalidated.items():
            drop = set(pages)
            kept = [p for p in self.pages_of[r] if p not in drop]
            self._used_by_klass[self.klass_of[r]] -= len(pages)
            if kept:
                self.pages_of[r] = kept
                if free_survivors:
                    # legacy semantics: an invalidated request loses *all*
                    # its KV (restarts from its prompt+generated tokens)
                    self.free(r)
            else:
                del self.pages_of[r]
                self.klass_of.pop(r, None)
        self.stats.reclaims += 1
        return invalidated

    # ---------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        seen: Set[int] = set()
        for r, pages in self.pages_of.items():
            assert pages, f'owner {r!r} with empty page list'
            for p in pages:
                assert p != QUARANTINE_PAGE, 'live request maps quarantine'
                assert self.owner[p] == r, (r, p, self.owner[p])
                assert p not in seen, f'page {p} double-owned'
                seen.add(p)
        for h in range(self.n_handles):
            for p in self.free_in_handle[h]:
                assert self.owner[p] is None
                assert p not in seen, f'page {p} both free and owned'
        # incremental counters must agree with a full scan
        free_res = sum(len(self.free_in_handle[h]) for h in self.reserved)
        free_off = sum(len(self.free_in_handle[h])
                       for h in range(self.n_handles)
                       if h not in self.reserved)
        assert self._free_reserved == free_res, \
            (self._free_reserved, free_res)
        assert self._free_offline == free_off, \
            (self._free_offline, free_off)
        for h in range(self.n_handles):
            mapped = sum(1 for p in self._handle_pages(h)
                         if self.owner[p] is not None)
            assert self._mapped_in_handle[h] == mapped, \
                (h, self._mapped_in_handle[h], mapped)
        for klass in ('online', 'offline'):
            used = sum(len(v) for r, v in self.pages_of.items()
                       if self.klass_of[r] == klass)
            assert self._used_by_klass[klass] == used, \
                (klass, self._used_by_klass[klass], used)
        used_res = sum(1 for h in self.reserved
                       if any(self.owner[p] is not None
                              for p in self._handle_pages(h)))
        assert self._used_reserved_handles == used_res, \
            (self._used_reserved_handles, used_res)
