"""Token sampling for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, *, temperature: float = 0.0, key=None, top_k: int = 0):
    """logits (B, V) → tokens (B,) int32.

    temperature 0 → greedy; otherwise softmax sampling (optionally top-k
    truncated).  ``key`` is required when temperature > 0.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, 'temperature sampling needs a PRNG key'
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
