"""The HTTP surface — a framework-free ASGI application.

OpenAI-style endpoints over one :class:`AsyncNodeDriver` (see
``docs/API.md`` § Serving endpoints for the wire contract):

- ``POST /v1/completions`` — online request.  ``stream: true`` responds
  ``text/event-stream``: one SSE frame per token delta, a final frame
  carrying ``finish_reason``, then ``data: [DONE]``.  ``stream: false``
  returns the whole completion as JSON.  A client disconnect mid-stream
  cancels the request — the engine releases its lease immediately, so an
  abandoned stream cannot pin KV pages.
- ``POST /v1/batches`` / ``GET /v1/batches/{id}`` /
  ``GET /v1/batches/{id}/results`` / ``POST /v1/batches/{id}/cancel`` —
  the offline batch-job lifecycle (submit → poll → fetch).
- ``GET /v1/metrics`` — the node's metrics dict; ``GET /healthz``.

The app is plain ASGI (``async def app(scope, receive, send)``) with no
web framework behind it: the container ships no starlette/uvicorn, and
the protocol tests want byte-level control of the wire anyway.  It runs
in-process under the deterministic test client
(:mod:`repro.serving.frontend.testing`) and over real sockets under the
:mod:`repro.serving.frontend.http` adapter — same code path either way.

The repro has no tokenizer, so prompts are token-id arrays and "text" is
the canonical space-joined id rendering (:func:`token_text`) — what the
SSE-vs-drain bit-identity tests compare.
"""
from __future__ import annotations

import asyncio
import json
import re
from typing import Callable, Dict, List, Sequence, Tuple

from repro.serving.frontend.driver import AsyncNodeDriver, OnlineStream
from repro.serving.frontend.sse import DONE_FRAME, encode_sse

__all__ = ['FrontendApp', 'token_text', 'token_delta']

_JSON = {'content-type': 'application/json'}
_SSE = {'content-type': 'text/event-stream', 'cache-control': 'no-cache'}


def token_text(tokens: Sequence[int]) -> str:
    """Canonical text rendering of a token-id sequence ("5 17 99")."""
    return ' '.join(str(int(t)) for t in tokens)


def token_delta(token: int, index: int) -> str:
    """The streamed delta for one token such that concatenating every
    delta reproduces ``token_text`` bit-identically."""
    return ('' if index == 0 else ' ') + str(int(token))


class _HTTPError(Exception):
    def __init__(self, status: int, message: str, kind: str = 'invalid_request'):
        self.status, self.message, self.kind = status, message, kind


class FrontendApp:
    """ASGI application over one driver.  Routes are (method, regex) pairs
    resolved in order; handlers are ``async (match, body) -> (status,
    headers, obj)`` or take over the raw ``send`` for streaming."""

    def __init__(self, driver: AsyncNodeDriver):
        self.driver = driver
        self.node = driver.node
        self.batches = driver.batches
        self._routes: List[Tuple[str, re.Pattern, Callable]] = [
            ('POST', re.compile(r'^/v1/completions$'), self._completions),
            ('POST', re.compile(r'^/v1/batches$'), self._batch_submit),
            ('GET', re.compile(r'^/v1/batches/(?P<bid>[\w.-]+)/results$'),
             self._batch_results),
            ('POST', re.compile(r'^/v1/batches/(?P<bid>[\w.-]+)/cancel$'),
             self._batch_cancel),
            ('GET', re.compile(r'^/v1/batches/(?P<bid>[\w.-]+)$'),
             self._batch_status),
            ('GET', re.compile(r'^/v1/metrics$'), self._metrics),
            ('GET', re.compile(r'^/healthz$'), self._health),
        ]

    # ------------------------------------------------------------------
    # ASGI entry
    # ------------------------------------------------------------------
    async def __call__(self, scope: dict, receive, send) -> None:
        if scope['type'] == 'lifespan':
            await self._lifespan(receive, send)
            return
        assert scope['type'] == 'http', scope['type']
        method, path = scope['method'].upper(), scope['path']
        try:
            for m, pat, handler in self._routes:
                match = pat.match(path)
                if match and m == method:
                    await handler(match, scope, receive, send)
                    return
            raise _HTTPError(404, f'no route for {method} {path}',
                             'not_found')
        except _HTTPError as e:
            await self._respond(send, e.status,
                                {'error': {'message': e.message,
                                           'type': e.kind}})

    async def _lifespan(self, receive, send) -> None:
        while True:
            msg = await receive()
            if msg['type'] == 'lifespan.startup':
                await send({'type': 'lifespan.startup.complete'})
            elif msg['type'] == 'lifespan.shutdown':
                await send({'type': 'lifespan.shutdown.complete'})
                return

    # ------------------------------------------------------------------
    # Plumbing
    # ------------------------------------------------------------------
    async def _read_json(self, receive) -> dict:
        body = b''
        while True:
            msg = await receive()
            if msg['type'] == 'http.disconnect':
                raise _HTTPError(400, 'client disconnected during body')
            body += msg.get('body', b'')
            if not msg.get('more_body'):
                break
        if not body:
            return {}
        try:
            obj = json.loads(body)
        except ValueError:
            raise _HTTPError(400, 'request body is not valid JSON')
        if not isinstance(obj, dict):
            raise _HTTPError(400, 'request body must be a JSON object')
        return obj

    async def _respond(self, send, status: int, obj,
                       headers: Dict[str, str] = _JSON) -> None:
        body = json.dumps(obj, default=str).encode('utf-8')
        await send({'type': 'http.response.start', 'status': status,
                    'headers': [(k.encode(), v.encode())
                                for k, v in headers.items()]
                    + [(b'content-length', str(len(body)).encode())]})
        await send({'type': 'http.response.body', 'body': body})

    def _parse_completion(self, body: dict) -> Tuple[List[int], int, bool]:
        eng = self.node.online
        if eng is None:
            raise _HTTPError(503, 'node has no online engine',
                             'service_unavailable')
        prompt = body.get('prompt')
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) and t >= 0 for t in prompt)):
            raise _HTTPError(400, 'prompt must be a non-empty list of '
                                  'token ids (this repro has no tokenizer)')
        max_tokens = body.get('max_tokens', 16)
        if not isinstance(max_tokens, int) or max_tokens < 1:
            raise _HTTPError(400, 'max_tokens must be a positive integer')
        if len(prompt) + max_tokens > eng.cfg.max_seq:
            raise _HTTPError(400, f'prompt ({len(prompt)}) + max_tokens '
                                  f'({max_tokens}) exceeds the engine '
                                  f'budget of {eng.cfg.max_seq}')
        if any(t >= eng.mcfg.vocab_size for t in prompt):
            raise _HTTPError(400, f'token id out of range (vocab size '
                                  f'{eng.mcfg.vocab_size})')
        return prompt, max_tokens, bool(body.get('stream', False))

    # ------------------------------------------------------------------
    # POST /v1/completions
    # ------------------------------------------------------------------
    async def _completions(self, match, scope, receive, send) -> None:
        body = await self._read_json(receive)
        prompt, max_tokens, stream = self._parse_completion(body)
        s = self.driver.submit_stream(prompt, max_tokens)
        if stream:
            await self._stream_completion(s, receive, send)
        else:
            tokens = await s.collect()
            await self._respond(send, 200, {
                'id': s.req_id,
                'object': 'text_completion',
                'model': self.node.online.mcfg.name,
                'choices': [{'index': 0,
                             'text': token_text(tokens),
                             'tokens': tokens,
                             'finish_reason': s.finish_reason}],
                'usage': {'prompt_tokens': len(prompt),
                          'completion_tokens': len(tokens)},
            })

    async def _stream_completion(self, s: OnlineStream,
                                 receive, send) -> None:
        """SSE-stream one request; a client disconnect cancels it (the
        robustness half: the lease frees the moment the stream drops)."""
        await send({'type': 'http.response.start', 'status': 200,
                    'headers': [(k.encode(), v.encode())
                                for k, v in _SSE.items()]})
        disconnect = asyncio.get_running_loop().create_task(
            self._wait_disconnect(receive))
        try:
            it = s.__aiter__()
            while True:
                nxt = asyncio.get_running_loop().create_task(it.__anext__())
                done, _ = await asyncio.wait(
                    {nxt, disconnect}, return_when=asyncio.FIRST_COMPLETED)
                if disconnect in done:
                    nxt.cancel()
                    s.driver.cancel_stream(s.req_id)
                    return              # client gone: nothing to send
                try:
                    ev = nxt.result()
                except StopAsyncIteration:
                    break
                frame = {'id': s.req_id, 'object': 'text_completion.chunk',
                         'choices': [{'index': 0,
                                      'finish_reason': ev.finish_reason}]}
                if ev.token is not None:
                    frame['choices'][0].update(
                        token=ev.token, text=token_delta(ev.token, ev.index))
                await send({'type': 'http.response.body',
                            'body': encode_sse(json.dumps(frame),
                                               id=f'{s.req_id}:{ev.index}'),
                            'more_body': True})
            # terminal frame (finish_reason) then the [DONE] sentinel
            final = {'id': s.req_id, 'object': 'text_completion.chunk',
                     'choices': [{'index': 0,
                                  'finish_reason': s.finish_reason}]}
            await send({'type': 'http.response.body',
                        'body': encode_sse(json.dumps(final)),
                        'more_body': True})
            await send({'type': 'http.response.body', 'body': DONE_FRAME,
                        'more_body': False})
        finally:
            disconnect.cancel()

    async def _wait_disconnect(self, receive) -> None:
        while True:
            msg = await receive()
            if msg['type'] == 'http.disconnect':
                return

    # ------------------------------------------------------------------
    # Batch jobs
    # ------------------------------------------------------------------
    async def _batch_submit(self, match, scope, receive, send) -> None:
        body = await self._read_json(receive)
        reqs = body.get('requests')
        if not isinstance(reqs, list) or not reqs:
            raise _HTTPError(400, 'requests must be a non-empty list')
        if not self.node.offline:
            raise _HTTPError(503, 'node has no offline engines',
                             'service_unavailable')
        for i, spec in enumerate(reqs):
            if not isinstance(spec, dict) or 'prompt' not in spec:
                raise _HTTPError(400, f'requests[{i}] needs a prompt')
            p, mt = spec['prompt'], spec.get('max_tokens', 16)
            if (not isinstance(p, list) or not p
                    or not all(isinstance(t, int) and t >= 0 for t in p)):
                raise _HTTPError(400, f'requests[{i}].prompt must be a '
                                      'non-empty list of token ids')
            if not isinstance(mt, int) or mt < 1:
                raise _HTTPError(400, f'requests[{i}].max_tokens must be '
                                      'a positive integer')
            budget = max(e.cfg.max_seq for e in self.node.offline)
            if len(p) + mt > budget:
                raise _HTTPError(400, f'requests[{i}] exceeds the offline '
                                      f'budget of {budget}')
        job = self.batches.submit(reqs)
        self.driver.kick()
        await self._respond(send, 200, job.to_dict())

    def _job_or_404(self, match) -> 'object':
        job = self.batches.get(match.group('bid'))
        if job is None:
            raise _HTTPError(404, f'no batch {match.group("bid")!r}',
                             'not_found')
        return job

    async def _batch_status(self, match, scope, receive, send) -> None:
        await self._respond(send, 200, self._job_or_404(match).to_dict())

    async def _batch_cancel(self, match, scope, receive, send) -> None:
        self._job_or_404(match)
        job = self.batches.cancel(match.group('bid'))
        await self._respond(send, 200, job.to_dict())

    async def _batch_results(self, match, scope, receive, send) -> None:
        job = self._job_or_404(match)
        results = self.batches.results(job.job_id)
        if results is None:
            raise _HTTPError(409, f'batch {job.job_id!r} is {job.status}, '
                                  'not terminal', 'conflict')
        for r in results:
            r['text'] = token_text(r['tokens'])
        await self._respond(send, 200,
                            {'id': job.job_id, 'object': 'batch.results',
                             'results': results})

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    async def _metrics(self, match, scope, receive, send) -> None:
        await self._respond(send, 200, self.node.metrics())

    async def _health(self, match, scope, receive, send) -> None:
        await self._respond(send, 200, {
            'status': 'ok',
            'online': self.node.online is not None,
            'offline_engines': len(self.node.offline),
            'has_work': self.node.has_work(),
        })
