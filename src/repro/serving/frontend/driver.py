"""AsyncNodeDriver — one event loop owns the runtime.

The serving front-end's execution model: a single asyncio task pumps
``NodeOrchestrator.step()`` cooperatively with request intake (no
thread-per-request, no locks — every handler and the pump interleave at
``await`` points on one loop).  The pump yields to the loop after every
node tick, so SSE writers flush token deltas and new submissions land
between dispatches; when the node goes idle it parks on an event and is
kicked by the next submission, burning neither CPU nor virtual time.

Token delivery is a *tap*, not an engine hook: after each tick the driver
diffs every streamed request's ``generated`` list against what its
:class:`OnlineStream` has already emitted and pushes the deltas.  The
engine (and the Valve patch surface) stays untouched — streaming is a
front-end concern, and the ≤ 13-LOC framework patch cannot grow.

Cancellation (client disconnect, batch abort) routes to
:meth:`Engine.cancel`: the lease is released on the spot, which drops the
invalidation route with it (route lifetime == lease lifetime), so a
dropped stream can never pin reserved KV pages and starve MIAD.

Clock discipline: everything that waits goes through :func:`clock_sleep`
— under a :class:`~repro.core.clock.VirtualClock` waits *advance* the
clock instead of sleeping, so the protocol tests and the trace-replay
load generator are deterministic and never wall-clock sleep.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Sequence

from repro.launch.node import NodeOrchestrator
from repro.serving.frontend.batches import BatchManager
from repro.serving.scheduler import ReqState

__all__ = ['AsyncNodeDriver', 'OnlineStream', 'TokenEvent', 'DriverStats',
           'clock_sleep']


async def clock_sleep(clock, dt: float) -> None:
    """Sleep ``dt`` on the runtime's clock: wall sleep under a RealClock,
    a pure advance (plus one loop yield) under a VirtualClock — the one
    primitive that keeps pacing/timeout tests deterministic."""
    if getattr(clock, 'virtual', False):
        if dt > 0:
            clock.advance(dt)
        await asyncio.sleep(0)
    else:
        await asyncio.sleep(max(dt, 0.0))


class TokenEvent(NamedTuple):
    """One streamed token delta (``token is None`` marks the terminal
    event carrying only the finish reason)."""
    token: Optional[int]
    index: int
    finish_reason: Optional[str]    # 'stop' | 'length' | 'cancelled'


class OnlineStream:
    """Async iterator over one online request's tokens as the engine
    produces them.  Created by :meth:`AsyncNodeDriver.submit_stream`."""

    def __init__(self, driver: 'AsyncNodeDriver', req_id: str):
        self.driver = driver
        self.req_id = req_id
        self.emitted = 0                 # tokens already pushed to the queue
        self.finish_reason: Optional[str] = None
        self._q: asyncio.Queue = asyncio.Queue()

    def __aiter__(self) -> 'OnlineStream':
        return self

    async def __anext__(self) -> TokenEvent:
        if self.finish_reason is not None and self._q.empty():
            raise StopAsyncIteration
        ev: TokenEvent = await self._q.get()
        if ev.finish_reason is not None:
            self.finish_reason = ev.finish_reason
            if ev.token is None:
                raise StopAsyncIteration
        return ev

    async def cancel(self) -> bool:
        """Abandon this stream's request (idempotent)."""
        return self.driver.cancel_stream(self.req_id)

    async def collect(self) -> List[int]:
        """Drain the stream to completion; returns all generated tokens."""
        return [ev.token async for ev in self if ev.token is not None]


@dataclass
class DriverStats:
    ticks: int = 0                   # node steps pumped
    streams_opened: int = 0
    streams_finished: int = 0
    streams_cancelled: int = 0
    idle_parks: int = 0              # pump waits for a kick


class AsyncNodeDriver:
    """Pumps one :class:`NodeOrchestrator` inside the event loop and
    exposes async submission surfaces (online streams + batch jobs)."""

    def __init__(self, node: NodeOrchestrator, *,
                 ticks_per_yield: int = 1):
        self.node = node
        self.clock = node.clock
        self.batches = BatchManager(node)
        self.stats = DriverStats()
        # ≥1 node steps per loop yield: raising this trades intake latency
        # for pump throughput under heavy traffic (benchmarked, not guessed)
        self.ticks_per_yield = max(1, int(ticks_per_yield))
        self._streams: Dict[str, OnlineStream] = {}
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._stopping = False

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    async def __aenter__(self) -> 'AsyncNodeDriver':
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    def start(self) -> None:
        """Start the pump task (must run inside the owning event loop)."""
        assert self._task is None, 'driver already started'
        self._stopping = False
        self._task = asyncio.get_running_loop().create_task(self._pump())

    async def stop(self) -> None:
        """Stop the pump (idempotent).  In-flight requests stay in the
        engines; a restarted driver resumes them."""
        self._stopping = True
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None

    def kick(self) -> None:
        """Wake an idle pump (new work arrived)."""
        self._wake.set()

    # ------------------------------------------------------------------
    # Online streaming surface
    # ------------------------------------------------------------------
    def submit_stream(self, prompt: Sequence[int],
                      max_new_tokens: int = 32) -> OnlineStream:
        """Submit one online request; returns its token stream."""
        eng = self.node.online
        assert eng is not None, 'node has no online engine'
        rid = eng.submit(list(prompt), max_new_tokens)
        stream = OnlineStream(self, rid)
        self._streams[rid] = stream
        self.stats.streams_opened += 1
        self.kick()
        return stream

    def _engine_holding(self, req_id: str):
        """Resolve which engine holds ``req_id`` right now.  On a plain
        node that is ``node.online``; nodes/planes that move requests
        between engines (cross-pool rescue, disaggregated prefill→decode
        handoff) expose ``engine_of`` and the driver follows the request
        wherever it lives."""
        finder = getattr(self.node, 'engine_of', None)
        eng = finder(req_id) if finder is not None else None
        return eng if eng is not None else self.node.online

    def cancel_stream(self, req_id: str) -> bool:
        """Cancel an online request (client disconnect path): the holding
        engine releases its lease immediately — on whichever pool the
        request sits, including mid-handoff — and the stream gets a
        terminal ``cancelled`` event."""
        eng = self._engine_holding(req_id)
        cancelled = eng is not None and eng.cancel(req_id)
        if cancelled:
            self.stats.streams_cancelled += 1
        self._flush_streams()
        return cancelled

    def _flush_streams(self) -> None:
        """Diff streamed requests against emitted counts; push deltas and
        terminal events.  Requests may live on different engines (a
        disaggregated handoff moves them mid-stream); each holding engine
        flushes its fused-path lazy tokens once per pass."""
        if not self._streams:
            return
        flushed: set = set()
        done: List[str] = []
        for rid, stream in self._streams.items():
            eng = self._engine_holding(rid)
            if id(eng) not in flushed:
                flushed.add(id(eng))
                eng.flush_tokens()   # resolve fused-path lazy tokens
            req = eng.requests[rid]
            while stream.emitted < len(req.generated):
                stream._q.put_nowait(TokenEvent(
                    req.generated[stream.emitted], stream.emitted, None))
                stream.emitted += 1
            if req.state is ReqState.FINISHED:
                reason = ('length'
                          if len(req.generated) >= req.max_new_tokens
                          else 'stop')
                stream._q.put_nowait(TokenEvent(None, stream.emitted, reason))
                self.stats.streams_finished += 1
                done.append(rid)
            elif req.state is ReqState.CANCELLED:
                stream._q.put_nowait(
                    TokenEvent(None, stream.emitted, 'cancelled'))
                done.append(rid)
        for rid in done:
            del self._streams[rid]

    # ------------------------------------------------------------------
    # The pump
    # ------------------------------------------------------------------
    def _has_work(self) -> bool:
        return self.node.has_work()

    async def _pump(self) -> None:
        while not self._stopping:
            if not self._has_work():
                self._flush_streams()
                self._wake.clear()
                if self._has_work() or self._stopping:
                    continue        # a submit raced the clear (same task
                                    # can't, but a re-kick costs nothing)
                self.stats.idle_parks += 1
                await self._wake.wait()
                continue
            for _ in range(self.ticks_per_yield):
                if not self._has_work():
                    break
                self.node.step()
                self.stats.ticks += 1
            self._flush_streams()
            self.batches.poll()
            # hand the loop to intake / SSE writers between dispatches
            await asyncio.sleep(0)

    async def drain(self, max_ticks: int = 100_000) -> None:
        """Pump until the node is idle WITHOUT a running pump task (test
        and benchmark convenience; mirrors ``NodeOrchestrator.drain``)."""
        assert self._task is None, 'drain() conflicts with a running pump'
        for _ in range(max_ticks):
            if not self._has_work():
                self._flush_streams()
                self.batches.poll()
                return
            self.node.step()
            self.stats.ticks += 1
            self._flush_streams()
            self.batches.poll()
            await asyncio.sleep(0)
        raise RuntimeError('drain exceeded max_ticks')
