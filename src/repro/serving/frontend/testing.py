"""Deterministic in-process ASGI test client — no sockets, no threads.

The protocol test harness (``tests/test_frontend.py``,
``tests/test_sse.py``) and the trace-replay load generator drive the
front-end through this client: it calls the ASGI app coroutine directly
on the current event loop, so requests, the driver pump, and SSE delivery
interleave at deterministic ``await`` points.  Combined with a
:class:`~repro.core.clock.VirtualClock` on the node, an entire
timeout/pacing scenario runs without a single wall-clock sleep.

Mid-stream client disconnects are first-class:
:meth:`StreamingResponse.disconnect` makes the app's next ``receive()``
return ``{'type': 'http.disconnect'}`` — exactly what a real server does
when the TCP peer drops — which is how the cancellation/leak regression
tests sever a stream at a precise token boundary.
"""
from __future__ import annotations

import asyncio
import json as _json
from typing import AsyncIterator, Dict, List, Optional, Tuple

from repro.serving.frontend.sse import SSEEvent, SSEParser

__all__ = ['ASGIClient', 'Response', 'StreamingResponse']


class Response:
    """A fully-buffered HTTP response."""

    def __init__(self, status: int, headers: List[Tuple[bytes, bytes]],
                 body: bytes):
        self.status = status
        self.headers: Dict[str, str] = {
            k.decode().lower(): v.decode() for k, v in headers}
        self.body = body

    def json(self):
        return _json.loads(self.body)

    def __repr__(self) -> str:
        return f'Response({self.status}, {len(self.body)}B)'


def _scope(method: str, path: str, headers: List[Tuple[bytes, bytes]]):
    return {
        'type': 'http', 'asgi': {'version': '3.0'},
        'http_version': '1.1', 'method': method.upper(),
        'scheme': 'http', 'path': path, 'raw_path': path.encode(),
        'query_string': b'', 'headers': headers,
        'client': ('testclient', 0), 'server': ('testserver', 80),
    }


class StreamingResponse:
    """Handle on an in-flight streaming request (async context manager).

    The app runs as a task on the same loop; body chunks surface through
    :meth:`chunks` and parsed SSE events through :meth:`events`.
    """

    def __init__(self, app, scope: dict, body: bytes):
        self._app = app
        self._scope = scope
        self._body = body
        self._sent_body = False
        self._disconnected = asyncio.Event()
        self._chunks: asyncio.Queue = asyncio.Queue()
        self._started = asyncio.Event()
        self.status: Optional[int] = None
        self.headers: Dict[str, str] = {}
        self._task: Optional[asyncio.Task] = None

    # -- ASGI plumbing ------------------------------------------------------
    async def _receive(self) -> dict:
        if not self._sent_body:
            self._sent_body = True
            return {'type': 'http.request', 'body': self._body,
                    'more_body': False}
        await self._disconnected.wait()
        return {'type': 'http.disconnect'}

    async def _send(self, msg: dict) -> None:
        if msg['type'] == 'http.response.start':
            self.status = msg['status']
            self.headers = {k.decode().lower(): v.decode()
                            for k, v in msg.get('headers', [])}
            self._started.set()
        elif msg['type'] == 'http.response.body':
            body = msg.get('body', b'')
            if body:
                self._chunks.put_nowait(body)
            if not msg.get('more_body', False):
                self._chunks.put_nowait(None)          # EOF marker

    async def _run(self) -> None:
        try:
            await self._app(self._scope, self._receive, self._send)
        finally:
            self._started.set()
            self._chunks.put_nowait(None)

    # -- public surface -----------------------------------------------------
    async def __aenter__(self) -> 'StreamingResponse':
        self._task = asyncio.get_running_loop().create_task(self._run())
        await self._started.wait()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def aclose(self) -> None:
        """Sever the stream (client hang-up) and join the app task."""
        self._disconnected.set()
        if self._task is not None:
            await self._task
            self._task = None

    async def disconnect(self) -> None:
        """Simulate the TCP peer dropping mid-stream, then wait for the
        app to observe it and unwind (cancellation path)."""
        await self.aclose()

    async def chunks(self) -> AsyncIterator[bytes]:
        """Raw body chunks exactly as the app sent them."""
        while True:
            chunk = await self._chunks.get()
            if chunk is None:
                return
            yield chunk

    async def events(self, *, strict: bool = True
                     ) -> AsyncIterator[SSEEvent]:
        """Parsed SSE events (including the ``[DONE]`` terminator)."""
        parser = SSEParser(strict=strict)
        async for chunk in self.chunks():
            for ev in parser.feed(chunk):
                yield ev


class ASGIClient:
    """In-process client for one ASGI app."""

    def __init__(self, app):
        self.app = app

    def _prep(self, method: str, path: str, json=None, body: bytes = b''
              ) -> Tuple[dict, bytes]:
        headers = [(b'host', b'testserver')]
        if json is not None:
            body = _json.dumps(json).encode()
            headers.append((b'content-type', b'application/json'))
        headers.append((b'content-length', str(len(body)).encode()))
        return _scope(method, path, headers), body

    async def request(self, method: str, path: str, *, json=None,
                      body: bytes = b'') -> Response:
        """Run one non-streaming request to completion."""
        scope, body = self._prep(method, path, json, body)
        sr = StreamingResponse(self.app, scope, body)
        async with sr:
            buf = b''
            async for chunk in sr.chunks():
                buf += chunk
        assert sr.status is not None, 'app sent no response'
        return Response(sr.status,
                        [(k.encode(), v.encode())
                         for k, v in sr.headers.items()], buf)

    async def get(self, path: str) -> Response:
        return await self.request('GET', path)

    async def post(self, path: str, *, json=None) -> Response:
        return await self.request('POST', path, json=json)

    def stream(self, method: str, path: str, *,
               json=None) -> StreamingResponse:
        """Open a streaming request: ``async with client.stream(...) as s``.
        Iterate ``s.events()``; call ``s.disconnect()`` to drop mid-way."""
        scope, body = self._prep(method, path, json)
        return StreamingResponse(self.app, scope, body)
