"""Async serving front-end over :class:`~repro.launch.node.NodeOrchestrator`.

One event loop owns the runtime (:class:`AsyncNodeDriver` pumps
``node.step()`` cooperatively with request intake); the HTTP surface is a
framework-free ASGI app (:class:`FrontendApp`) with an OpenAI-style
streaming online API (``POST /v1/completions`` + SSE) and an offline
batch-job API (``POST /v1/batches`` submit → poll → fetch).  See
``docs/API.md`` § Serving endpoints.

Submodules (import the ones you need — keeps ``tests/test_sse.py`` free
of the engine/jax dependency chain):

- :mod:`.sse`      — SSE wire format (encoder + incremental parser)
- :mod:`.driver`   — the asyncio pump, online token streams, cancellation
- :mod:`.batches`  — batch jobs over the offline plane (lazy allocation)
- :mod:`.app`      — the ASGI application
- :mod:`.testing`  — deterministic in-process ASGI client (no sockets)
- :mod:`.http`     — minimal HTTP/1.1 ⇄ ASGI socket adapter
- :mod:`.loadgen`  — trace-replay async load generator
"""
from __future__ import annotations

__all__ = ['AsyncNodeDriver', 'FrontendApp', 'BatchManager', 'SSEParser',
           'encode_sse']


def __getattr__(name):
    # lazy: `import repro.serving.frontend` must not drag in jax via the
    # driver's NodeOrchestrator import unless those symbols are touched
    if name in ('AsyncNodeDriver', 'OnlineStream', 'TokenEvent'):
        from repro.serving.frontend import driver
        return getattr(driver, name)
    if name == 'FrontendApp':
        from repro.serving.frontend.app import FrontendApp
        return FrontendApp
    if name == 'BatchManager':
        from repro.serving.frontend.batches import BatchManager
        return BatchManager
    if name in ('SSEParser', 'SSEEvent', 'encode_sse'):
        from repro.serving.frontend import sse
        return getattr(sse, name)
    raise AttributeError(name)
