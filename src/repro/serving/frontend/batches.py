"""Offline batch-job API — the offline plane as a product, not just backfill.

A **batch job** is a set of generation requests submitted together
(``POST /v1/batches``), executed on the node's OFFLINE engines, and
fetched as one result set when complete (submit → poll → fetch, the cloud
batch-API shape).  Jobs are first-class *preemptible* work: each item is a
plain offline-engine request, so admission goes through the engine's
:class:`~repro.core.api.ValveSession` (``session.admit`` at schedule
time), dispatch obeys the Valve gates, and reclamation can invalidate and
resume items like any other offline work — the batch API adds bookkeeping,
never a second admission path.

Allocation is *lazy by construction*: ``submit`` only enqueues items into
engine FIFO queues; no KV page is leased until the scheduler admits an
item.  Cancelling a job whose items are still queued therefore provably
never allocates (pinned by ``tests/test_frontend.py``).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.serving.scheduler import ReqState

__all__ = ['BatchItem', 'BatchJob', 'BatchManager']

# job lifecycle: queued → in_progress → completed, or → cancelled
_TERMINAL = ('completed', 'cancelled')


@dataclass
class BatchItem:
    """One generation request inside a job."""
    index: int
    prompt: List[int]
    max_new_tokens: int
    req_id: Optional[str] = None
    engine: Optional[object] = None      # the owning offline Engine

    @property
    def request(self):
        return self.engine.requests[self.req_id]


@dataclass
class BatchJob:
    job_id: str
    items: List[BatchItem]
    created_at: float
    status: str = 'queued'
    completed_at: Optional[float] = None

    def counts(self) -> Dict[str, int]:
        c = {'total': len(self.items), 'queued': 0, 'in_progress': 0,
             'completed': 0, 'cancelled': 0}
        for it in self.items:
            st = it.request.state
            if st is ReqState.FINISHED:
                c['completed'] += 1
            elif st is ReqState.CANCELLED:
                c['cancelled'] += 1
            elif st is ReqState.WAITING and not it.request.pages:
                c['queued'] += 1
            else:
                c['in_progress'] += 1
        return c

    def to_dict(self) -> Dict[str, object]:
        return {
            'id': self.job_id,
            'object': 'batch',
            'status': self.status,
            'created_at': self.created_at,
            'completed_at': self.completed_at,
            'request_counts': self.counts(),
        }


class BatchManager:
    """Owns batch jobs over one node's offline engines (round-robin
    placement across heterogeneous engines, mirroring how the drain demos
    spread their backlog)."""

    def __init__(self, node):
        self.node = node
        self.jobs: Dict[str, BatchJob] = {}
        self._seq = itertools.count()
        self._rr = 0

    # ------------------------------------------------------------------
    def submit(self, requests: Sequence[dict]) -> BatchJob:
        """Create a job from ``[{prompt, max_tokens}, ...]`` and enqueue
        every item on an offline engine (allocation stays deferred until
        scheduler admission)."""
        offline = self.node.offline
        assert offline, 'node has no offline engines'
        assert requests, 'empty batch'
        items: List[BatchItem] = []
        for i, spec in enumerate(requests):
            prompt = list(map(int, spec['prompt']))
            max_new = int(spec.get('max_tokens', 16))
            eng = offline[self._rr % len(offline)]
            self._rr += 1
            assert len(prompt) + max_new <= eng.cfg.max_seq, \
                (len(prompt), max_new, eng.cfg.max_seq)
            items.append(BatchItem(i, prompt, max_new,
                                   req_id=eng.submit(prompt, max_new),
                                   engine=eng))
        job = BatchJob(f'batch-{next(self._seq)}', items,
                       created_at=self.node.clock.now())
        self.jobs[job.job_id] = job
        return job

    def get(self, job_id: str) -> Optional[BatchJob]:
        job = self.jobs.get(job_id)
        if job is not None:
            self._refresh(job)
        return job

    # ------------------------------------------------------------------
    def poll(self) -> None:
        """Advance every live job's status from its items' request states
        (called by the driver pump after each tick)."""
        for job in self.jobs.values():
            self._refresh(job)

    def _refresh(self, job: BatchJob) -> None:
        if job.status in _TERMINAL:
            return
        c = job.counts()
        if c['completed'] == c['total']:
            job.status = 'completed'
            job.completed_at = self.node.clock.now()
        elif c['queued'] < c['total']:
            job.status = 'in_progress'

    # ------------------------------------------------------------------
    def cancel(self, job_id: str) -> Optional[BatchJob]:
        """Cancel every unfinished item (engine releases whatever each
        item holds; queued items never allocated, so there is nothing to
        release).  Finished items keep their results."""
        job = self.jobs.get(job_id)
        if job is None:
            return None
        if job.status not in _TERMINAL:
            for it in job.items:
                it.engine.cancel(it.req_id)
            job.status = 'cancelled'
            job.completed_at = self.node.clock.now()
        return job

    def results(self, job_id: str) -> Optional[List[Dict[str, object]]]:
        """Per-item outputs, available once the job is terminal."""
        job = self.get(job_id)
        if job is None or job.status not in _TERMINAL:
            return None
        out = []
        for it in job.items:
            req = it.request
            out.append({
                'index': it.index,
                'status': ('completed' if req.state is ReqState.FINISHED
                           else 'cancelled'),
                'tokens': list(req.generated),
                'n_prompt_tokens': len(it.prompt),
                'engine': it.engine.mcfg.name,
            })
        return out
