"""Server-Sent Events wire format — encoder + incremental parser.

The online streaming API (``POST /v1/completions`` with ``stream: true``)
speaks SSE (`text/event-stream`): UTF-8 frames of ``field: value`` lines
separated by a blank line, terminated by the OpenAI-style ``data: [DONE]``
sentinel.  This module is the single source of truth for that framing on
both sides of the wire — the app encodes with :func:`encode_sse`, and the
test harness / load generator decode with :class:`SSEParser`, an
incremental parser that is correct under arbitrary chunk boundaries (a
frame split anywhere, including mid-codepoint, reassembles exactly).

``tests/test_sse.py`` is the conformance suite: split-across-chunks
frames, CR/CRLF/LF line endings, multi-line data joining, comment lines,
``[DONE]`` termination, and malformed-frame rejection in strict mode.
"""
from __future__ import annotations

from typing import Iterator, List, NamedTuple, Optional, Union

__all__ = ['SSEEvent', 'SSEParser', 'SSEProtocolError', 'encode_sse',
           'DONE_DATA', 'DONE_FRAME']

# the OpenAI streaming termination sentinel (a data-only frame)
DONE_DATA = '[DONE]'
DONE_FRAME = b'data: [DONE]\n\n'

# fields the SSE spec defines; anything else is malformed in strict mode
# (the spec says "ignore", but our own encoder never emits them, so a
# strict consumer treats one as a corrupted stream)
_KNOWN_FIELDS = ('data', 'event', 'id', 'retry')


class SSEProtocolError(ValueError):
    """A frame violated the event-stream grammar (strict mode)."""


class SSEEvent(NamedTuple):
    """One dispatched server-sent event."""
    data: str
    event: str = 'message'
    id: Optional[str] = None
    retry: Optional[int] = None

    @property
    def done(self) -> bool:
        """True for the ``data: [DONE]`` stream terminator."""
        return self.data == DONE_DATA


def encode_sse(data: str, *, event: Optional[str] = None,
               id: Optional[str] = None,
               retry: Optional[int] = None) -> bytes:
    """Encode one event frame.  Multi-line ``data`` becomes one ``data:``
    line per line (the parser re-joins them with ``\\n``)."""
    parts: List[str] = []
    if event is not None:
        assert '\n' not in event and '\r' not in event, event
        parts.append(f'event: {event}')
    if id is not None:
        assert '\n' not in id and '\r' not in id and '\0' not in id, id
        parts.append(f'id: {id}')
    if retry is not None:
        assert retry >= 0, retry
        parts.append(f'retry: {int(retry)}')
    for line in data.split('\n'):
        parts.append(f'data: {line}')
    return ('\n'.join(parts) + '\n\n').encode('utf-8')


def encode_done() -> bytes:
    return DONE_FRAME


class SSEParser:
    """Incremental ``text/event-stream`` parser.

    Feed raw byte chunks exactly as they arrive off the wire; each call
    returns the events *completed* by that chunk.  Partial lines, partial
    UTF-8 sequences and partial frames are buffered across calls, so any
    split of the byte stream parses identically to the unsplit stream.

    ``strict=True`` (the default — what the protocol tests run) raises
    :class:`SSEProtocolError` on frames our encoder could never have
    produced: unknown field names, a non-integer ``retry``, a frame that
    dispatches without any ``data`` line, or invalid UTF-8.
    """

    def __init__(self, *, strict: bool = True):
        self.strict = strict
        self._buf = b''          # undecoded bytes (may end mid-codepoint)
        self._tail = ''          # decoded text of the current partial line
        self._data: List[str] = []
        self._event: Optional[str] = None
        self._id: Optional[str] = None
        self._retry: Optional[int] = None
        self._saw_field = False  # current frame carried any field line
        self.closed = False      # saw the [DONE] terminator

    # ------------------------------------------------------------------
    def feed(self, chunk: Union[bytes, str]) -> List[SSEEvent]:
        """Consume one wire chunk; return the events it completed."""
        if isinstance(chunk, str):
            chunk = chunk.encode('utf-8')
        self._buf += chunk
        text, self._buf = self._decode_progress(self._buf)
        events: List[SSEEvent] = []
        # normalize CRLF/CR to LF, honoring a CR that ends the chunk (the
        # matching LF may arrive in the next chunk)
        text = self._tail + text
        self._tail = ''
        if text.endswith('\r'):
            text, self._tail = text[:-1], '\r'
        text = text.replace('\r\n', '\n').replace('\r', '\n')
        lines = text.split('\n')
        # the last element is an incomplete line — buffer it
        self._tail = lines.pop() + self._tail
        for line in lines:
            ev = self._line(line)
            if ev is not None:
                events.append(ev)
        return events

    def finish(self) -> List[SSEEvent]:
        """Signal end-of-stream.  A CR held back in case an LF followed is
        now known to be a bare-CR terminator — flush it.  After that, a
        dangling partial frame is a protocol error in strict mode (frames
        end with a blank line)."""
        events: List[SSEEvent] = []
        if self._tail.endswith('\r'):
            line, self._tail = self._tail[:-1], ''
            ev = self._line(line)
            if ev is not None:
                events.append(ev)
        if self.strict and (self._tail or self._buf or self._saw_field):
            raise SSEProtocolError('stream ended mid-frame')
        return events

    # ------------------------------------------------------------------
    def _decode_progress(self, buf: bytes) -> tuple:
        """Decode the longest valid UTF-8 prefix; keep the rest buffered.
        A partial multi-byte sequence at the end is not an error — it
        completes with the next chunk."""
        try:
            return buf.decode('utf-8'), b''
        except UnicodeDecodeError as e:
            # only a *suffix* shorter than a max-length codepoint may be
            # incomplete; anything else is real corruption
            if len(buf) - e.start <= 3 and e.reason.startswith(
                    ('unexpected end of data', 'invalid continuation')):
                try:
                    return buf[:e.start].decode('utf-8'), buf[e.start:]
                except UnicodeDecodeError:
                    pass
            if self.strict:
                raise SSEProtocolError(f'invalid UTF-8 in stream: {e}')
            return buf.decode('utf-8', errors='replace'), b''

    def _line(self, line: str) -> Optional[SSEEvent]:
        if line == '':
            return self._dispatch()
        if line.startswith(':'):         # comment (keep-alive pings)
            return None
        if ':' in line:
            field, _, value = line.partition(':')
            if value.startswith(' '):
                value = value[1:]
        else:
            field, value = line, ''
        self._saw_field = True
        if field == 'data':
            self._data.append(value)
        elif field == 'event':
            self._event = value
        elif field == 'id':
            if '\0' not in value:
                self._id = value
        elif field == 'retry':
            if value.isdigit():
                self._retry = int(value)
            elif self.strict:
                raise SSEProtocolError(f'non-integer retry: {value!r}')
        elif self.strict:
            raise SSEProtocolError(f'unknown SSE field: {field!r}')
        return None

    def _dispatch(self) -> Optional[SSEEvent]:
        saw_field, self._saw_field = self._saw_field, False
        data, self._data = self._data, []
        event, self._event = self._event, None
        retry, self._retry = self._retry, None
        if not data:
            # per spec a dataless frame dispatches nothing; our encoder
            # never produces one, so strict mode rejects it (unless the
            # "frame" was pure comments/blank lines — those are fine)
            if saw_field and self.strict:
                raise SSEProtocolError('frame dispatched without data')
            return None
        ev = SSEEvent(data='\n'.join(data), event=event or 'message',
                      id=self._id, retry=retry)
        if ev.done:
            self.closed = True
        return ev


def parse_sse_stream(chunks: Iterator[bytes], *,
                     strict: bool = True) -> Iterator[SSEEvent]:
    """Convenience: parse an iterable of wire chunks into events."""
    p = SSEParser(strict=strict)
    for chunk in chunks:
        yield from p.feed(chunk)
    p.finish()
