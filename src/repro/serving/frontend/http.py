"""Minimal HTTP/1.1 ⇄ ASGI adapter over asyncio streams.

The container ships no ASGI server (no uvicorn/hypercorn), so this module
bridges real sockets to the front-end app: request parsing, chunked
streaming responses (what SSE rides on), and client-disconnect
propagation (a dropped TCP peer surfaces to the app as
``{'type': 'http.disconnect'}`` — the same contract the in-process test
client implements, so the cancellation path is identical on a live
socket).

Deliberately small: HTTP/1.1 only, ``Connection: close`` semantics, one
request per connection, no TLS — a demo/benchmark entry point
(``python -m repro.launch.serve --http``), not a production edge.  The
protocol tests run in-process via :mod:`repro.serving.frontend.testing`;
this adapter's own smoke coverage lives in ``tests/test_frontend.py``
(loopback, gated behind an opt-in to keep CI socket-free).
"""
from __future__ import annotations

import asyncio
from typing import Optional, Tuple

__all__ = ['serve_asgi', 'AsgiHttpServer']

_MAX_HEADER = 65536


async def _read_request(reader: asyncio.StreamReader
                        ) -> Optional[Tuple[str, str, list, bytes]]:
    """Parse one request; returns (method, path, headers, body)."""
    try:
        head = await reader.readuntil(b'\r\n\r\n')
    except (asyncio.IncompleteReadError, ConnectionError):
        return None
    except asyncio.LimitOverrunError:
        return None
    if len(head) > _MAX_HEADER:
        return None
    lines = head.decode('latin-1').split('\r\n')
    try:
        method, target, _version = lines[0].split(' ', 2)
    except ValueError:
        return None
    headers = []
    for line in lines[1:]:
        if not line:
            continue
        name, _, value = line.partition(':')
        headers.append((name.strip().lower().encode('latin-1'),
                        value.strip().encode('latin-1')))
    length = 0
    for k, v in headers:
        if k == b'content-length':
            try:
                length = int(v)
            except ValueError:
                return None
    body = await reader.readexactly(length) if length else b''
    path = target.split('?', 1)[0]
    return method, path, headers, body


class AsgiHttpServer:
    """Serve one ASGI app on a listening socket."""

    def __init__(self, app, host: str = '127.0.0.1', port: int = 8080):
        self.app = app
        self.host = host
        self.port = port
        self._server: Optional[asyncio.base_events.Server] = None

    async def start(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        sock = self._server.sockets[0]
        self.port = sock.getsockname()[1]     # resolve port 0

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def serve_forever(self) -> None:
        assert self._server is not None, 'call start() first'
        await self._server.serve_forever()

    # ------------------------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            req = await _read_request(reader)
            if req is None:
                return
            method, path, headers, body = req
            await self._dispatch(method, path, headers, body,
                                 reader, writer)
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _dispatch(self, method, path, headers, body,
                        reader, writer) -> None:
        scope = {
            'type': 'http', 'asgi': {'version': '3.0'},
            'http_version': '1.1', 'method': method.upper(),
            'scheme': 'http', 'path': path, 'raw_path': path.encode(),
            'query_string': b'', 'headers': headers,
            'client': writer.get_extra_info('peername'),
            'server': (self.host, self.port),
        }
        sent_body = False
        disconnected = asyncio.Event()

        async def watch_peer() -> None:
            # after the body, any read returning b'' means the peer closed
            # (we never pipeline, so nothing legitimate arrives here)
            try:
                data = await reader.read(1)
                if not data:
                    disconnected.set()
            except (ConnectionError, OSError):
                disconnected.set()

        watcher = asyncio.get_running_loop().create_task(watch_peer())

        async def receive() -> dict:
            nonlocal sent_body
            if not sent_body:
                sent_body = True
                return {'type': 'http.request', 'body': body,
                        'more_body': False}
            await disconnected.wait()
            return {'type': 'http.disconnect'}

        state = {'started': False, 'chunked': False}

        async def send(msg: dict) -> None:
            if disconnected.is_set():
                return                      # peer gone: drop silently
            try:
                if msg['type'] == 'http.response.start':
                    state['started'] = True
                    hdrs = list(msg.get('headers', []))
                    has_len = any(k.lower() == b'content-length'
                                  for k, _ in hdrs)
                    lines = [f'HTTP/1.1 {msg["status"]} '
                             f'{_reason(msg["status"])}'.encode('latin-1')]
                    for k, v in hdrs:
                        lines.append(k + b': ' + v)
                    if not has_len:
                        state['chunked'] = True
                        lines.append(b'transfer-encoding: chunked')
                    lines.append(b'connection: close')
                    writer.write(b'\r\n'.join(lines) + b'\r\n\r\n')
                elif msg['type'] == 'http.response.body':
                    data = msg.get('body', b'')
                    if state['chunked']:
                        if data:
                            writer.write(
                                f'{len(data):x}\r\n'.encode() + data
                                + b'\r\n')
                        if not msg.get('more_body', False):
                            writer.write(b'0\r\n\r\n')
                    else:
                        writer.write(data)
                    await writer.drain()
            except (ConnectionError, OSError):
                disconnected.set()

        try:
            await self.app(scope, receive, send)
        finally:
            watcher.cancel()


def _reason(status: int) -> str:
    return {200: 'OK', 400: 'Bad Request', 404: 'Not Found',
            409: 'Conflict', 500: 'Internal Server Error',
            503: 'Service Unavailable'}.get(status, 'Unknown')


async def serve_asgi(app, host: str = '127.0.0.1', port: int = 8080
                     ) -> AsgiHttpServer:
    """Start serving ``app``; returns the (started) server handle."""
    server = AsgiHttpServer(app, host, port)
    await server.start()
    return server
