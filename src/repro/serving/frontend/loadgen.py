"""Trace-replay async load generator — "heavy traffic" as a measured claim.

Replays a timed arrival trace against the front-end through the
in-process ASGI client: online entries open concurrent SSE streams (TTFT
= clock time from POST to first token frame), batch entries submit
offline jobs.  Runs on the node's own clock — deterministic pacing under
a :class:`~repro.core.clock.VirtualClock` (tests), wall-clock arrival
jitter under a :class:`RealClock` (``benchmarks/serve_throughput.py`` →
``BENCH_serve.json``: requests/s + p99 TTFT at ≥ 64 concurrent streams
with offline backfill active).
"""
from __future__ import annotations

import asyncio
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.frontend.driver import clock_sleep
from repro.serving.frontend.testing import ASGIClient

__all__ = ['TraceEntry', 'StreamRecord', 'LoadReport', 'LoadGenerator',
           'make_online_trace']


@dataclass(frozen=True)
class TraceEntry:
    """One arrival.  ``kind='online'`` opens one SSE stream;
    ``kind='batch'`` submits one offline job of ``n_requests`` items."""
    t: float                      # arrival offset from replay start
    kind: str = 'online'          # 'online' | 'batch'
    prompt_len: int = 12
    max_new_tokens: int = 8
    n_requests: int = 1           # batch items (kind='batch')
    seed: int = 0                 # per-entry prompt seed


def make_online_trace(n: int, *, horizon_s: float, prompt_len: int = 12,
                      max_new_tokens: int = 8, seed: int = 0,
                      burst_frac: float = 0.5) -> List[TraceEntry]:
    """``n`` online arrivals over ``horizon_s``: a front-loaded burst
    (``burst_frac`` of them land in the first 10% of the horizon — what
    drives peak concurrency) plus uniform background."""
    rng = np.random.default_rng(seed)
    n_burst = int(n * burst_frac)
    ts = np.concatenate([
        rng.uniform(0.0, 0.1 * horizon_s, n_burst),
        rng.uniform(0.0, horizon_s, n - n_burst),
    ])
    return [TraceEntry(t=float(t), prompt_len=prompt_len,
                       max_new_tokens=max_new_tokens, seed=seed + i)
            for i, t in enumerate(np.sort(ts))]


@dataclass
class StreamRecord:
    entry: TraceEntry
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    n_tokens: int = 0
    status: str = 'pending'       # 'completed' | 'failed'

    @property
    def ttft(self) -> Optional[float]:
        if self.status != 'completed' or self.n_tokens == 0:
            return None
        return self.t_first_token - self.t_submit


@dataclass
class LoadReport:
    n_online: int = 0
    completed: int = 0
    failed: int = 0
    duration_s: float = 0.0       # replay span on the node clock
    tokens_streamed: int = 0
    peak_concurrent_streams: int = 0
    batch_jobs: int = 0
    ttfts: List[float] = field(default_factory=list)

    @property
    def requests_per_s(self) -> float:
        return self.completed / self.duration_s if self.duration_s else 0.0

    def ttft_pct(self, q: float) -> Optional[float]:
        if not self.ttfts:
            return None
        return float(np.percentile(np.asarray(self.ttfts), q))

    def to_dict(self) -> Dict[str, object]:
        return {
            'n_online': self.n_online,
            'completed': self.completed,
            'failed': self.failed,
            'batch_jobs': self.batch_jobs,
            'duration_s': self.duration_s,
            'requests_per_s': self.requests_per_s,
            'tokens_streamed': self.tokens_streamed,
            'peak_concurrent_streams': self.peak_concurrent_streams,
            'ttft_p50_s': self.ttft_pct(50),
            'ttft_p99_s': self.ttft_pct(99),
        }


class LoadGenerator:
    """Replays a trace against one front-end app."""

    def __init__(self, client: ASGIClient, clock, *, vocab_size: int):
        self.client = client
        self.clock = clock
        self.vocab_size = vocab_size
        self._live = 0
        self._report = LoadReport()

    def _prompt(self, entry: TraceEntry) -> List[int]:
        rng = np.random.default_rng(entry.seed)
        return rng.integers(1, self.vocab_size,
                            entry.prompt_len).tolist()

    async def _run_stream(self, entry: TraceEntry,
                          rec: StreamRecord) -> None:
        r = self._report
        self._live += 1
        r.peak_concurrent_streams = max(r.peak_concurrent_streams,
                                        self._live)
        rec.t_submit = self.clock.now()
        try:
            sr = self.client.stream(
                'POST', '/v1/completions',
                json={'prompt': self._prompt(entry),
                      'max_tokens': entry.max_new_tokens, 'stream': True})
            async with sr:
                if sr.status != 200:
                    rec.status = 'failed'
                    return
                async for ev in sr.events():
                    if ev.done:
                        break
                    chunk = json.loads(ev.data)
                    if chunk['choices'][0].get('token') is not None:
                        if rec.n_tokens == 0:
                            rec.t_first_token = self.clock.now()
                        rec.n_tokens += 1
            rec.t_done = self.clock.now()
            rec.status = ('completed' if rec.n_tokens == entry.max_new_tokens
                          else 'failed')
        finally:
            self._live -= 1
            if rec.status == 'completed':
                r.completed += 1
                r.tokens_streamed += rec.n_tokens
                if rec.ttft is not None:
                    r.ttfts.append(rec.ttft)
            else:
                r.failed += 1

    async def _run_batch(self, entry: TraceEntry) -> None:
        reqs = [{'prompt': self._prompt(
                    TraceEntry(0, seed=entry.seed + 1000 + i,
                               prompt_len=entry.prompt_len)),
                 'max_tokens': entry.max_new_tokens}
                for i in range(entry.n_requests)]
        resp = await self.client.post('/v1/batches',
                                      json={'requests': reqs})
        if resp.status == 200:
            self._report.batch_jobs += 1

    async def replay(self, trace: Sequence[TraceEntry]
                     ) -> LoadReport:
        """Replay arrivals at their trace offsets; wait for every stream
        to finish; return the report."""
        self._report = LoadReport()
        t0 = self.clock.now()
        tasks: List[asyncio.Task] = []
        loop = asyncio.get_running_loop()
        for entry in sorted(trace, key=lambda e: e.t):
            dt = (t0 + entry.t) - self.clock.now()
            if dt > 0:
                await clock_sleep(self.clock, dt)
            if entry.kind == 'online':
                self._report.n_online += 1
                rec = StreamRecord(entry)
                tasks.append(loop.create_task(
                    self._run_stream(entry, rec)))
            else:
                tasks.append(loop.create_task(self._run_batch(entry)))
        if tasks:
            await asyncio.gather(*tasks)
        self._report.duration_s = self.clock.now() - t0
        return self._report
