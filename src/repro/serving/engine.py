"""Continuous-batching inference engine with the Valve patch surface.

A production-shaped engine (vLLM-style): FIFO admission, paged KV through the
global pool (page 0 = quarantine), chunked prefill, one-token decode
iterations over the running batch.  Padding keeps all dispatches at fixed
shapes so each entry point compiles once.

Valve integration points (and *only* these — Table 1's deployability claim):

- **online side**: lifecycle notifications (`runtime.on_online_*`) around
  requests/iterations, and page allocation through the runtime;
- **offline side**: a gate check before each dispatch unit (decode iteration
  or prefill chunk), and the < 20-LOC invalidation patch
  (:meth:`Engine.on_pages_invalidated` — counted by
  ``tests/test_patch_surface.py``).
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clock import RealClock
from repro.models import dense
from repro.models.api import Model
from repro.serving.kvpool import QUARANTINE_PAGE
from repro.serving.sampler import sample

I32 = jnp.int32


class ReqState(enum.Enum):
    WAITING = 'waiting'
    PREFILL = 'prefill'
    RUNNING = 'running'
    FINISHED = 'finished'


@dataclass
class Request:
    req_id: str
    prompt: List[int]
    max_new_tokens: int
    state: ReqState = ReqState.WAITING
    generated: List[int] = field(default_factory=list)
    pages: List[int] = field(default_factory=list)
    n_prefilled: int = 0
    recomputes: int = 0
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None
    decode_steps: int = 0

    @property
    def context(self) -> List[int]:
        """Prompt + already-generated tokens (what recompute re-prefills)."""
        return self.prompt + self.generated

    @property
    def target_len(self) -> int:
        return len(self.prompt) + self.max_new_tokens

    # -- latency metrics ---------------------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def tpot(self) -> Optional[float]:
        if self.t_last_token is None or self.t_first_token is None:
            return None
        n = len(self.generated) - 1
        if n <= 0:
            return 0.0
        return (self.t_last_token - self.t_first_token) / n


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 512              # prompt + generation budget per request
    prefill_chunk: int = 64         # offline preemptible dispatch unit
    temperature: float = 0.0
    seed: int = 0
    klass: str = 'offline'          # 'online' | 'offline'
    eos_token: Optional[int] = None
    # Decode attention through the Pallas paged kernel (pages stream
    # HBM→VMEM via the page table) instead of the full-gather oracle.
    # None → auto: kernel on TPU, oracle elsewhere (the interpreter would
    # only slow CPU runs down; parity is covered by the kernel test suite).
    decode_kernel: Optional[bool] = None


@dataclass
class EngineStats:
    steps: int = 0
    prefill_chunks: int = 0
    decode_iterations: int = 0
    tokens_generated: int = 0
    tokens_recomputed: int = 0
    invalidations: int = 0
    blocked_dispatches: int = 0     # offline dispatches skipped while gated


class Engine:
    """One engine = one model instance on one node's devices."""

    def __init__(self, model: Model, params, pool,
                 cfg: Optional[EngineConfig] = None, *,
                 runtime=None, clock=None):
        self.model = model
        self.mcfg = model.cfg
        self.cfg = cfg or EngineConfig()
        self.params = params
        self.runtime = runtime
        self.pool = runtime.pool if runtime is not None else pool
        assert self.pool is not None, 'engine needs a KVPool or a runtime'
        self.clock = clock or (runtime.clock if runtime else RealClock())
        self.cache = model.init_cache(None, engine_pages=self.pool.n_pages)
        self.pg = self.mcfg.page_size
        self.maxp = self.cfg.max_seq // self.pg
        self._ids = itertools.count()
        self.requests: Dict[str, Request] = {}
        self.queue: List[str] = []       # FIFO waiting queue
        self.running: List[str] = []     # admitted (PREFILL or RUNNING)
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(self.cfg.seed)
        assert self.mcfg.family in ('dense', 'vlm', 'moe'), \
            'engine serves paged-KV decoder-only families'
        decode_kernel = self.cfg.decode_kernel
        if decode_kernel is None:
            decode_kernel = jax.default_backend() == 'tpu'
        self._decode = jax.jit(
            lambda p, c, b, k=decode_kernel: model.decode_fn(
                p, c, b, use_pallas=k))
        chunk_fn = model.mod.prefill_chunk
        self._prefill_chunk = jax.jit(
            lambda p, c, b: chunk_fn(self.mcfg, p, c, b))

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               req_id: Optional[str] = None) -> str:
        rid = req_id or f'{self.cfg.klass}-{next(self._ids)}'
        assert len(prompt) + max_new_tokens <= self.cfg.max_seq, \
            (len(prompt), max_new_tokens, self.cfg.max_seq)
        req = Request(rid, list(map(int, prompt)), max_new_tokens,
                      t_submit=self.clock.now())
        self.requests[rid] = req
        self.queue.append(rid)
        return rid

    # ------------------------------------------------------------------
    # Valve patch surface — the complete framework-side modification.
    # LOC counted by tests/test_patch_surface.py (paper Table 1: < 20).
    # ------------------------------------------------------------------
    # >>> VALVE-PATCH-BEGIN
    def on_pages_invalidated(self, invalidated: Dict[str, List[int]]) -> None:
        for rid in invalidated:
            req = self.requests.get(rid)
            if req is None or req.state == ReqState.FINISHED:
                continue
            req.pages = []
            req.n_prefilled = 0
            req.recomputes += 1
            req.state = ReqState.WAITING
            if rid in self.running:
                self.running.remove(rid)
            self.queue.insert(0, rid)
            self.stats.invalidations += 1
            self.stats.tokens_recomputed += len(req.context)
    # >>> VALVE-PATCH-END

    # ------------------------------------------------------------------
    # Memory plumbing
    # ------------------------------------------------------------------
    def _alloc(self, rid: str, n_pages: int) -> Optional[List[int]]:
        if self.runtime is None:
            return self.pool.alloc(rid, n_pages, klass=self.cfg.klass)
        if self.cfg.klass == 'online':
            return self.runtime.alloc_online(rid, n_pages)
        return self.runtime.alloc_offline(rid, n_pages)

    def _free(self, rid: str) -> None:
        self.pool.free(rid)

    def _page_table(self, req: Request) -> np.ndarray:
        pt = np.full((self.maxp,), QUARANTINE_PAGE, np.int32)
        pt[: len(req.pages)] = req.pages
        return pt

    # ------------------------------------------------------------------
    # Scheduling step
    # ------------------------------------------------------------------
    def _gated(self) -> bool:
        return (self.cfg.klass == 'offline' and self.runtime is not None
                and not self.runtime.offline_may_dispatch())

    def _admit(self) -> None:
        while self.queue and len(self.running) < self.cfg.max_batch:
            rid = self.queue[0]
            req = self.requests[rid]
            need = -(-req.target_len // self.pg)
            # lifecycle first: the request's arrival closes the gates BEFORE
            # any allocation can trigger reclamation (one preemption covers
            # both, and the wake check can't reopen gates mid-admission)
            if self.runtime is not None and self.cfg.klass == 'online':
                self.runtime.on_online_request_start(rid)
            pages = self._alloc(rid, need)
            if pages is None:
                if self.runtime is not None and self.cfg.klass == 'online':
                    self.runtime.on_online_request_end(rid)
                break  # head-of-line blocks until memory frees up
            self.queue.pop(0)
            req.pages = pages
            req.state = ReqState.PREFILL
            req.n_prefilled = 0
            self.running.append(rid)

    def _finish(self, req: Request) -> None:
        req.state = ReqState.FINISHED
        self.running.remove(req.req_id)
        self._free(req.req_id)
        req.pages = []
        if self.runtime is not None and self.cfg.klass == 'online':
            self.runtime.on_online_request_end(req.req_id)

    # -- prefill -----------------------------------------------------------
    def _prefill_one(self, req: Request) -> None:
        """Dispatch the next prefill chunk for ``req`` (fixed chunk shape)."""
        ctx = req.context
        chunk = self.cfg.prefill_chunk
        lo = req.n_prefilled
        hi = min(lo + chunk, len(ctx))
        toks = np.zeros((1, chunk), np.int32)
        poss = np.full((1, chunk), max(hi - 1, 0), np.int32)
        pids = np.full((1, chunk), QUARANTINE_PAGE, np.int32)
        offs = np.zeros((1, chunk), np.int32)
        n = hi - lo
        toks[0, :n] = ctx[lo:hi]
        poss[0, :n] = np.arange(lo, hi)
        abs_pos = np.arange(lo, hi)
        pt = self._page_table(req)
        pids[0, :n] = pt[abs_pos // self.pg]
        offs[0, :n] = abs_pos % self.pg
        batch = {
            'tokens': jnp.asarray(toks),
            'positions': jnp.asarray(poss),
            'page_table': jnp.asarray(pt[None]),
            'page_ids': jnp.asarray(pids),
            'offsets': jnp.asarray(offs),
            'kv_len': jnp.asarray([hi], I32),
            'last_idx': jnp.asarray([n - 1], I32),
        }
        self.cache, logits = self._prefill_chunk(self.params, self.cache, batch)
        self.stats.prefill_chunks += 1
        req.n_prefilled = hi
        if hi == len(ctx):
            req.state = ReqState.RUNNING
            # the final chunk's logits predict the token after the context —
            # the first token on a fresh prefill, the resume token after an
            # invalidation recompute; either way we sample it here
            tok = self._sample(logits)[0]
            self._append_token(req, int(tok))

    # -- decode -------------------------------------------------------------
    def _decode_batch(self) -> None:
        batch_reqs = [self.requests[r] for r in self.running
                      if self.requests[r].state == ReqState.RUNNING]
        if not batch_reqs:
            return
        bmax = self.cfg.max_batch
        batch_reqs = batch_reqs[:bmax]
        toks = np.zeros((bmax,), np.int32)
        poss = np.zeros((bmax,), np.int32)
        pts = np.full((bmax, self.maxp), QUARANTINE_PAGE, np.int32)
        for i, req in enumerate(batch_reqs):
            # the last context token was sampled but its KV never written:
            # decode embeds it, writes KV at its position, predicts the next
            toks[i] = req.context[-1]
            poss[i] = len(req.context) - 1
            pts[i] = self._page_table(req)
        # padded slots write into quarantine (page 0) — harmless by design
        db = {'tokens': jnp.asarray(toks), 'positions': jnp.asarray(poss),
              'page_table': jnp.asarray(pts)}
        if self.runtime is not None and self.cfg.klass == 'online':
            self.runtime.on_online_iteration_start()
        self.cache, logits = self._decode(self.params, self.cache, db)
        if self.runtime is not None and self.cfg.klass == 'online':
            self.runtime.on_online_iteration_end()
        self.stats.decode_iterations += 1
        new = np.asarray(self._sample(logits))
        for i, req in enumerate(batch_reqs):
            req.decode_steps += 1
            self._append_token(req, int(new[i]))

    def _sample(self, logits):
        if self.cfg.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            return sample(logits, temperature=self.cfg.temperature, key=sub)
        return sample(logits)

    def _append_token(self, req: Request, tok: int) -> None:
        req.generated.append(tok)
        now = self.clock.now()
        if req.t_first_token is None:
            req.t_first_token = now
        req.t_last_token = now
        self.stats.tokens_generated += 1
        done = (len(req.generated) >= req.max_new_tokens
                or (self.cfg.eos_token is not None
                    and tok == self.cfg.eos_token))
        if done:
            self._finish(req)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One scheduling step; returns True if any dispatch happened."""
        if self._gated():
            self.stats.blocked_dispatches += 1
            return False
        self._admit()
        self.stats.steps += 1
        prefilling = [self.requests[r] for r in self.running
                      if self.requests[r].state == ReqState.PREFILL]
        if prefilling:
            self._prefill_one(prefilling[0])
            return True
        if any(self.requests[r].state == ReqState.RUNNING
               for r in self.running):
            self._decode_batch()
            return True
        return False

    def run_to_completion(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not (self.queue or self.running):
                return
            if not self.step() and self._gated():
                raise RuntimeError('offline engine gated; drive via runtime')
        raise RuntimeError('run_to_completion exceeded max_steps')

    # ------------------------------------------------------------------
    @property
    def finished(self) -> List[Request]:
        return [r for r in self.requests.values()
                if r.state == ReqState.FINISHED]

    def output_tokens(self, rid: str) -> List[int]:
        return list(self.requests[rid].generated)
