"""Continuous-batching inference engine with the Valve patch surface.

The execution layer of the serving plane.  Scheduling policy lives in
:mod:`repro.serving.scheduler` (:class:`BatchScheduler` composes each
dispatch: budgeted multi-request chunked prefill + piggybacked decode
slots); this module turns a :class:`ScheduledBatch` into one fixed-shape
JAX dispatch over preallocated host buffers, so each entry point compiles
once and no step reallocates numpy arrays.

Valve integration points (and *only* these — Table 1's deployability claim):
the engine holds ONE class-scoped :class:`~repro.core.api.ValveSession`
(``runtime.open_session``), whose calls — admit/finish bundles, iteration
notifications, the gate check — are tagged ``# VALVE-SESSION`` and counted
by ``tests/test_patch_surface.py`` alongside the ≤ 13-LOC invalidation
patch (:meth:`Engine.on_pages_invalidated`).  The session owns invalidation
routing by allocation ownership, so there is no per-request bind/unbind
and no engine-instance id discriminator anymore.

Memory-plane API v1: ``session.admit`` returns a
:class:`~repro.core.memory.KVLease` (list-compatible with the old page
list).  The engine passes each request's prompt so page-aligned shared
prefixes attach copy-on-write (prefill skips them — the scheduler reads
``lease.resume_tokens``), reports fill progress via ``lease.note_filled``
(which publishes prefix pages for later admissions), and the invalidation
patch resumes recompute from the surviving prefix the
:class:`~repro.core.memory.LeaseInvalidation` carries instead of
restarting at token 0.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import PoolSession
from repro.core.clock import RealClock
from repro.kernels.paged_attention.prefix import build_shared_runs
from repro.serving.kvpool import QUARANTINE_PAGE
from repro.serving.sampler import sample
from repro.serving.scheduler import (
    BatchScheduler, DecodeSlot, Request, ReqState, ScheduledBatch,
    SchedulerConfig)

# re-exported for compatibility: request bookkeeping moved to scheduler.py
__all__ = ['Engine', 'EngineConfig', 'EngineStats', 'Request', 'ReqState']

# jaxlib 0.4.3x CPU async dispatch intermittently corrupts the fused
# lazy-token chain (sampled tokens feeding the next dispatch on-device with
# no host sync in between) when host-side scheduling runs concurrently with
# an executing dispatch.  The flag is read once, when the CPU client is
# created, so it must be set at import time — any realistic flow imports
# this module before touching jax.  ``Engine._dispatch_decode`` additionally
# blocks on each fused step's tokens as a backstop for processes whose
# client predates this import.  TPU/GPU are unaffected by either.
try:
    jax.config.update('jax_cpu_enable_async_dispatch', False)
except AttributeError:          # flag absent on this jax version
    pass


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 512              # prompt + generation budget per request
    prefill_chunk: int = 64         # per-request prefill tokens per dispatch
    max_prefill_reqs: int = 4       # prefill rows per mixed dispatch
    # total prefill tokens per dispatch; None → max_prefill_reqs × chunk
    prefill_budget: Optional[int] = None
    piggyback_decode: bool = True   # decode slots ride along with prefill
    temperature: float = 0.0
    seed: int = 0
    klass: str = 'offline'          # 'online' | 'offline'
    eos_token: Optional[int] = None
    # Decode attention through the Pallas paged kernel (pages stream
    # HBM→VMEM via the page table) instead of the full-gather oracle.
    # None → auto: kernel on TPU, oracle elsewhere (the interpreter would
    # only slow CPU runs down; parity is covered by the kernel test suite).
    decode_kernel: Optional[bool] = None
    # Fused decode+sampling fast path: the decode dispatch returns sampled
    # (B,) tokens instead of (B, V) logits (fused unembed+argmax — logits
    # never round-trip to HBM), tokens stay on device between decode
    # iterations (no per-step host sync; values are fetched lazily for
    # stream emission via Engine.flush_tokens / output_tokens), and the KV
    # cache is donated to the jitted step on accelerator backends.  Greedy
    # drain output is bit-identical to the unfused path.  With eos_token
    # set, tokens are fetched every step (the stop check needs the value).
    fused_sampling: bool = False
    # Deduplicate copy-on-write shared prefix pages across each decode
    # batch (kernels.paged_attention.prefix): each shared physical page is
    # read once per batch instead of once per request.
    prefix_shared_attention: bool = False
    # Tensor-parallel serving: a jax.sharding.Mesh to run every dispatch
    # across.  Params/cache shard by SERVE_RULES (heads/kv_heads/ffn/vocab
    # over 'model', batch over 'data'; the KV page axis stays unsharded so
    # the pool's handle space is mesh-global), resolved shape-aware so
    # indivisible dims relocate instead of failing.  None — the default —
    # is the identity single-device path: drain output is bit-identical.
    # With a mesh, decode_kernel=None resolves to the oracle path (GSPMD
    # partitions the jnp attention; the Pallas kernel is opted into
    # explicitly where the backend supports sharded custom calls).
    mesh: Optional[object] = None

    def scheduler_config(self) -> SchedulerConfig:
        return SchedulerConfig(
            max_batch=self.max_batch, chunk=self.prefill_chunk,
            max_prefill_reqs=min(self.max_prefill_reqs, self.max_batch),
            prefill_budget=self.prefill_budget,
            piggyback_decode=self.piggyback_decode)


@dataclass
class EngineStats:
    steps: int = 0
    dispatches: int = 0             # actual device dispatches issued
    mixed_dispatches: int = 0       # dispatches carrying ≥1 prefill slot
    prefill_chunks: int = 0         # prefill slots executed (per-request)
    decode_iterations: int = 0      # dispatches carrying ≥1 decode slot
    tokens_generated: int = 0
    tokens_recomputed: int = 0
    invalidations: int = 0
    blocked_dispatches: int = 0     # offline dispatches skipped while gated
    spills: int = 0                 # surviving prefixes dropped under pressure
    cancellations: int = 0          # requests abandoned before finishing
    token_flushes: int = 0          # lazy device→host token syncs (fused path)
    shared_page_reads_saved: int = 0  # page reads deduped by prefix sharing


class Engine:
    """One engine = one model instance on one node's devices."""

    def __init__(self, model, params, pool,
                 cfg: Optional[EngineConfig] = None, *,
                 runtime=None, clock=None):
        self.model = model
        self.mcfg = model.cfg
        self.cfg = cfg or EngineConfig()
        self.params = params
        self.runtime = runtime
        # with a runtime, the node-shared pool is authoritative; passing a
        # DIFFERENT pool alongside it would silently serve divergent state
        assert runtime is None or pool is None or pool is runtime.pool, \
            'pool conflicts with runtime.pool'
        self.pool = runtime.pool if runtime is not None else pool
        assert self.pool is not None, 'engine needs a KVPool or a runtime'
        self.clock = clock or (runtime.clock if runtime else RealClock())
        # the complete Valve control-plane integration: one class-scoped
        # session (alloc/notify/gate/invalidation-routing); a bare pool
        # gets the same interface with no runtime behind it
        if runtime is not None:
            self.session = runtime.open_session(                # VALVE-SESSION
                self.cfg.klass, on_invalidate=self.on_pages_invalidated)
        else:
            self.session = PoolSession(self.pool, self.cfg.klass)
        self.cache = model.init_cache(None, engine_pages=self.pool.n_pages)
        # tensor-parallel plane: commit params and KV cache to their
        # SERVE_RULES shardings up front so every dispatch compiles against
        # stable shardings (no per-call input resharding / signature churn)
        self.mesh = self.cfg.mesh
        self._c_sharding = None
        if self.mesh is not None:
            from repro.distributed.sharding import (SERVE_RULES,
                                                    tree_spec_shaped)
            p_sh = tree_spec_shaped(model.param_axes(), self.params,
                                    SERVE_RULES, self.mesh)
            self._c_sharding = tree_spec_shaped(
                model.cache_axes(None, engine_pages=self.pool.n_pages),
                self.cache, SERVE_RULES, self.mesh)
            self.params = jax.device_put(self.params, p_sh)
            self.cache = jax.device_put(self.cache, self._c_sharding)
        self.pg = self.mcfg.page_size
        self.maxp = self.cfg.max_seq // self.pg
        self.requests: Dict[str, Request] = {}
        self.sched = BatchScheduler(self.cfg.scheduler_config())
        # the scheduler owns the lists; the engine (and the Valve patch)
        # aliases them — same objects, never rebound
        self.queue: List[str] = self.sched.queue
        self.running: List[str] = self.sched.running
        self.stats = EngineStats()
        self._key = jax.random.PRNGKey(self.cfg.seed)
        assert self.mcfg.family in ('dense', 'vlm', 'moe'), \
            'engine serves paged-KV decoder-only families'
        decode_kernel = self.cfg.decode_kernel
        if decode_kernel is None:
            decode_kernel = (jax.default_backend() == 'tpu'
                             and self.mesh is None)
        # donate the KV cache buffers to the jitted step so the pools
        # update in place (donation is a no-op on CPU and would only warn)
        donate = (1,) if jax.default_backend() in ('tpu', 'gpu') else ()
        # mesh path: trace under the SERVE_RULES context so the models'
        # `constrain` calls become real sharding constraints, and pin the
        # cache's output sharding to its input sharding so the carried
        # cache never drifts (drift would re-specialize the jit signature
        # every step)
        if self.mesh is not None:
            from repro.distributed.sharding import SERVE_RULES, axis_rules
            mesh = self.mesh

            def _traced(fn):
                def wrapped(*args):
                    with axis_rules(mesh, SERVE_RULES):
                        return fn(*args)
                return wrapped
            jit_kw = {'out_shardings': (self._c_sharding, None)}
        else:
            def _traced(fn):
                return fn
            jit_kw = {}
        self._decode = jax.jit(
            _traced(lambda p, c, b, k=decode_kernel: model.decode_fn(
                p, c, b, use_pallas=k)),
            donate_argnums=donate, **jit_kw)
        if self.cfg.fused_sampling:
            temp = float(self.cfg.temperature)

            def fused_fn(p, c, b, k=decode_kernel, t=temp):
                # next-token feed: rows whose last sampled token is still
                # on device read it straight from the previous dispatch's
                # output instead of a host-staged value
                db = dict(b)
                db['tokens'] = jnp.where(db.pop('use_prev') > 0,
                                         db.pop('prev')[db.pop('src')],
                                         db['tokens'])
                return model.decode_sample_fn(p, c, db, use_pallas=k,
                                              temperature=t)
            self._fused_decode = jax.jit(_traced(fused_fn),
                                         donate_argnums=donate, **jit_kw)
            # see the module-import async-dispatch note at the top of this
            # file; the per-step block below is the backstop for processes
            # whose CPU client predates that config update
            self._cpu_step_sync = jax.default_backend() == 'cpu'
        chunk_fn = model.mod.prefill_chunk
        self._mixed = jax.jit(
            _traced(lambda p, c, b: chunk_fn(self.mcfg, p, c, b)), **jit_kw)
        self._init_buffers()
        # lazy-token bookkeeping (fused path): device arrays whose values
        # have not been copied to req.generated yet, and the row map of
        # the newest decode output (the device-feed source)
        self._pending: List[tuple] = []
        # staged-device-array cache for decode dispatch inputs (see
        # _dispatch_decode): keyed by the exact host bytes they derive from
        self._stage: Dict = {}
        self._pending_rids: set = set()
        self._prev_tokens = jnp.zeros((self.cfg.max_batch,), jnp.int32)
        self._prev_rows: Dict[str, int] = {}
        self._seed_ctr = itertools.count()

    def _init_buffers(self) -> None:
        """Preallocate the fixed-shape host staging buffers (one mixed
        dispatch shape, one decode dispatch shape) — filled in place each
        step, never reallocated."""
        b, c = self.cfg.max_batch, self.cfg.prefill_chunk
        self._mix = {
            'toks': np.zeros((b, c), np.int32),
            'poss': np.zeros((b, c), np.int32),
            'pids': np.zeros((b, c), np.int32),
            'offs': np.zeros((b, c), np.int32),
            'pts': np.zeros((b, self.maxp), np.int32),
            'kv_len': np.zeros((b,), np.int32),
            'last_idx': np.zeros((b,), np.int32),
        }
        self._dec = {
            'toks': np.zeros((b,), np.int32),
            'poss': np.zeros((b,), np.int32),
            'pts': np.zeros((b, self.maxp), np.int32),
            # fused path: per-row device-feed selectors (see fused_fn)
            'use_prev': np.zeros((b,), np.int32),
            'src': np.zeros((b,), np.int32),
        }

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               req_id: Optional[str] = None) -> str:
        # no bind step: invalidation routing follows allocation ownership
        # (the session records it at admit, releases it at finish/reclaim)
        rid = req_id or self.session.new_request_id()       # VALVE-SESSION
        assert len(prompt) > 0, 'empty prompt'
        assert len(prompt) + max_new_tokens <= self.cfg.max_seq, \
            (len(prompt), max_new_tokens, self.cfg.max_seq)
        req = Request(rid, list(map(int, prompt)), max_new_tokens,
                      t_submit=self.clock.now())
        self.requests[rid] = req
        self.sched.submit(rid)
        return rid

    # ------------------------------------------------------------------
    # Valve patch surface — the complete framework-side modification.
    # LOC counted by tests/test_patch_surface.py (paper Table 1: < 20).
    # ------------------------------------------------------------------
    # >>> VALVE-PATCH-BEGIN
    def on_pages_invalidated(self, invalidated: Dict[str, List[int]]) -> None:
        for rid, inv in invalidated.items():
            # session routing delivers only ids holding a live lease, so
            # the request exists and is not FINISHED
            req = self.requests[rid]
            # recompute charge: a queued victim hit again loses only the
            # shrink from its old resume point (0 for duplicate deliveries)
            base = req.n_prefilled if rid in self.queue else len(req.context)
            self.stats.tokens_recomputed += base - inv.resume
            # keep the surviving prefix: prefill resumes at inv.resume
            req.pages, req.n_prefilled = req.pages[:inv.keep], inv.resume
            if rid in self.queue:
                continue
            req.state, req.recomputes = ReqState.WAITING, req.recomputes + 1
            self.running.remove(rid)
            self.queue.insert(0, rid)
            self.stats.invalidations += 1
    # >>> VALVE-PATCH-END

    # ------------------------------------------------------------------
    # Memory plumbing
    # ------------------------------------------------------------------
    def _fill_page_table(self, row: np.ndarray, req: Request) -> np.ndarray:
        row.fill(QUARANTINE_PAGE)
        row[: len(req.pages)] = req.pages
        return row

    # ------------------------------------------------------------------
    # Scheduling step
    # ------------------------------------------------------------------
    def _gated(self) -> bool:
        return not self.session.may_dispatch()              # VALVE-SESSION

    def _try_admit(self, req: Request) -> Optional[List[int]]:
        """Admission callback for the scheduler.  The session bundles the
        lifecycle notification with the lease — lifecycle first, so the
        request's arrival closes the gates BEFORE any allocation can
        trigger reclamation (one preemption covers both).  Passing the
        prompt opts into copy-on-write prefix sharing: an already-
        materialized page-aligned prefix is attached instead of recomputed
        (``lease.resume_tokens`` tells the scheduler where prefill starts);
        re-admitting a partially-invalidated request extends its live lease
        and keeps the surviving prefix."""
        need = -(-req.target_len // self.pg)
        lease = self.session.admit(                         # VALVE-SESSION
            req.req_id, need, req.prompt)
        if lease is not None:
            # None must NOT clobber req.lease: a failed RE-admission leaves
            # the surviving lease live in the plane, and _spill needs the
            # handle to actually release it
            req.lease = lease
        return lease

    def _spill(self, req: Request) -> None:
        """Scheduler deadlock valve: drop a waiting request's surviving-
        prefix pages under sustained admission pressure (degrades to the
        legacy whole-request recompute)."""
        if req.lease is not None:
            req.lease.release()
        # the forfeited surviving prefix becomes recompute work
        self.stats.tokens_recomputed += req.n_prefilled
        req.pages, req.n_prefilled, req.lease = [], 0, None
        self.stats.spills += 1

    def _finish(self, req: Request) -> None:
        req.state = ReqState.FINISHED
        self.running.remove(req.req_id)
        self.session.finish(req.req_id)                     # VALVE-SESSION
        req.pages, req.lease = [], None

    # ------------------------------------------------------------------
    # Cancellation (client disconnect / batch-job abort)
    # ------------------------------------------------------------------
    def cancel(self, req_id: str) -> bool:
        """Abandon a submitted request; returns False if unknown/terminal.

        A RUNNING/PREFILL request goes through the normal terminal bundle
        (``session.finish``: lease + route + lifecycle end — for online
        requests the lifecycle start fired at admission, so the pairing
        stays balanced).  A QUEUED request was never admitted, so there is
        no lifecycle notification to unwind; its only possible KV is a
        surviving prefix kept across an invalidation, and releasing the
        lease drops the route with it (route lifetime == lease lifetime).
        A dropped stream therefore can never pin reserved pages."""
        req = self.requests.get(req_id)
        if req is None or req.state in (ReqState.FINISHED,
                                        ReqState.CANCELLED):
            return False
        if req_id in self.queue:
            self.queue.remove(req_id)
            if req.lease is not None and not req.lease.released:
                req.lease.release()
            req.pages, req.lease = [], None
        else:
            self._finish(req)
        req.state = ReqState.CANCELLED
        self.stats.cancellations += 1
        return True

    # -- mixed prefill(+decode) dispatch -------------------------------------
    def _dispatch_mixed(self, batch: ScheduledBatch) -> None:
        """Execute one composed dispatch through the chunked-prefill entry:
        prefill rows write/attend their chunk; decode rows are one-token
        chunks (embed the last sampled token, write its KV, predict the
        next) — one fixed (max_batch × chunk) iteration for all of it."""
        # prefill rows (and piggybacked decode rows) re-read context token
        # VALUES, so lazily-held device tokens must land first; the
        # newest-output row map dies with this dispatch (rows resample)
        self.flush_tokens()
        self._prev_rows = {}
        m = self._mix
        m['toks'].fill(0)
        m['poss'].fill(0)
        m['pids'].fill(QUARANTINE_PAGE)
        m['offs'].fill(0)
        m['pts'].fill(QUARANTINE_PAGE)
        m['kv_len'].fill(1)        # padding rows attend 1 quarantine slot
        m['last_idx'].fill(0)
        row = 0
        for ps in batch.prefill:
            req = self.requests[ps.req_id]
            lo, hi = ps.start, ps.start + ps.length
            pos = np.arange(lo, hi)
            m['toks'][row, :ps.length] = req.context[lo:hi]
            m['poss'][row, :ps.length] = pos
            m['poss'][row, ps.length:] = hi - 1
            pt = self._fill_page_table(m['pts'][row], req)
            m['pids'][row, :ps.length] = pt[pos // self.pg]
            m['offs'][row, :ps.length] = pos % self.pg
            m['kv_len'][row] = hi
            m['last_idx'][row] = ps.length - 1
            row += 1
        for ds in batch.decode:
            req = self.requests[ds.req_id]
            # the last context token was sampled but its KV never written:
            # this row embeds it, writes KV at its position, predicts next
            pos = len(req.context) - 1
            m['toks'][row, 0] = req.context[-1]
            m['poss'][row, :] = pos
            pt = self._fill_page_table(m['pts'][row], req)
            m['pids'][row, 0] = pt[pos // self.pg]
            m['offs'][row, 0] = pos % self.pg
            m['kv_len'][row] = pos + 1
            m['last_idx'][row] = 0
            row += 1
        mb = {
            'tokens': jnp.asarray(m['toks']),
            'positions': jnp.asarray(m['poss']),
            'page_table': jnp.asarray(m['pts']),
            'page_ids': jnp.asarray(m['pids']),
            'offsets': jnp.asarray(m['offs']),
            'kv_len': jnp.asarray(m['kv_len']),
            'last_idx': jnp.asarray(m['last_idx']),
        }
        self.session.iteration_start()                      # VALVE-SESSION
        self.cache, logits = self._mixed(self.params, self.cache, mb)
        self.session.iteration_end()                        # VALVE-SESSION
        self.stats.dispatches += 1
        self.stats.mixed_dispatches += 1
        self.stats.prefill_chunks += len(batch.prefill)
        if batch.decode:
            self.stats.decode_iterations += 1
        new = np.asarray(self._sample(logits))
        row = 0
        for ps in batch.prefill:
            req = self.requests[ps.req_id]
            req.n_prefilled = ps.start + ps.length
            if req.lease is not None:   # fill fact → prefix publication
                req.lease.note_filled(req.n_prefilled)
            if req.n_prefilled == len(req.context):
                req.state = ReqState.RUNNING
                # the final chunk's logits predict the token after the
                # context — the first token on a fresh prefill, the resume
                # token after an invalidation recompute
                self._append_token(req, int(new[row]))
            row += 1
        for ds in batch.decode:
            req = self.requests[ds.req_id]
            req.decode_steps += 1
            self._append_token(req, int(new[row]))
            row += 1

    # -- pure decode dispatch -------------------------------------------------
    def _dispatch_decode(self, slots: List[DecodeSlot]) -> None:
        """Decode-only iteration through the paged-attention fast path.

        With ``fused_sampling`` the dispatch returns sampled tokens, not
        logits: each row's next-token input is read on-device from the
        previous dispatch's output (``use_prev``/``src`` feed), and the
        new tokens are recorded as placeholders resolved lazily by
        :meth:`flush_tokens` — the per-step device→host sync is gone."""
        fused = self.cfg.fused_sampling
        if fused and any(ds.req_id in self._pending_rids
                         and ds.req_id not in self._prev_rows
                         for ds in slots):
            # a slot's pending token predates the newest device array (the
            # request sat out a step): resolve to host values once
            self.flush_tokens()
        d = self._dec
        d['toks'].fill(0)
        d['poss'].fill(0)
        d['pts'].fill(QUARANTINE_PAGE)
        d['use_prev'].fill(0)
        d['src'].fill(0)
        for i, ds in enumerate(slots):
            req = self.requests[ds.req_id]
            if fused and ds.req_id in self._pending_rids:
                d['use_prev'][i] = 1
                d['src'][i] = self._prev_rows[ds.req_id]
            else:
                d['toks'][i] = req.context[-1]
            d['poss'][i] = len(req.context) - 1
            self._fill_page_table(d['pts'][i], req)
        # padded slots write into quarantine (page 0) — harmless by design.
        # Staging cache: the page tables — and the shared-run structure
        # derived from (tables, length//pg) — only change when a page is
        # appended, remapped, or the batch recomposes, so the staged device
        # arrays are reused between changes (host→device staging and the
        # shared-run rebuild otherwise dominate CPU step latency).
        st = self._stage
        key = (d['pts'].tobytes(), ((d['poss'] + 1) // self.pg).tobytes())
        if st.get('key') != key:
            st['key'] = key
            st['pts'] = jnp.asarray(d['pts'])
            st['shared'] = None
            if self.cfg.prefix_shared_attention:
                runs = build_shared_runs(d['pts'], d['poss'] + 1, self.pg)
                if runs['n_slots']:
                    # each shared physical page is read once per batch; the
                    # saving is (participants − 1) reads per slot
                    st['saved'] = int(runs['mask'].sum()) - runs['n_slots']
                    # bucket the slot axis to the next power of two: the
                    # full maxp-wide padding would double the shared-phase
                    # FLOPs; a few buckets cost a few compiles.  The tail
                    # axis stays maxp-wide on purpose — its live width
                    # grows every page crossing, so bucketing it would
                    # recompile the dispatch mid-decode
                    cap = 1
                    while cap < runs['n_slots']:
                        cap <<= 1
                    st['shared'] = {
                        'pages': jnp.asarray(runs['pages'][:cap]),
                        'pos': jnp.asarray(runs['pos'][:cap]),
                        'mask': jnp.asarray(runs['mask'][:, :cap]),
                        'tail_pt': jnp.asarray(runs['tail_pt']),
                        'start': jnp.asarray(runs['start'])}
        db = {'positions': jnp.asarray(d['poss']),
              'page_table': st['pts']}
        if st.get('shared') is not None:
            self.stats.shared_page_reads_saved += st['saved']
            db['shared'] = st['shared']
        if fused:
            # steady-state decode feeds every row from the previous device
            # output, so (tokens, use_prev, src) are byte-stable — restage
            # only when a row resolves to host values or rows move
            fkey = (d['toks'].tobytes(), d['use_prev'].tobytes(),
                    d['src'].tobytes())
            if st.get('fkey') != fkey:
                st['fkey'] = fkey
                st['toks'] = jnp.asarray(d['toks'])
                st['use_prev'] = jnp.asarray(d['use_prev'])
                st['src'] = jnp.asarray(d['src'])
            db['tokens'] = st['toks']
            db['use_prev'] = st['use_prev']
            db['src'] = st['src']
            db['prev'] = self._prev_tokens
            if self.cfg.temperature > 0:
                db['seed'] = jnp.asarray(
                    [(self.cfg.seed * 2654435761 + next(self._seed_ctr))
                     & 0x7FFFFFFF], np.int32)
            else:
                # greedy ignores the sampling noise — stage the seed once
                if 'seed0' not in st:
                    st['seed0'] = jnp.zeros((1,), jnp.int32)
                db['seed'] = st['seed0']
        else:
            db['tokens'] = jnp.asarray(d['toks'])
        self.session.iteration_start()                      # VALVE-SESSION
        if fused:
            self.cache, toks = self._fused_decode(self.params, self.cache, db)
        else:
            self.cache, logits = self._decode(self.params, self.cache, db)
        self.session.iteration_end()                        # VALVE-SESSION
        self.stats.dispatches += 1
        self.stats.decode_iterations += 1
        if not fused:
            new = np.asarray(self._sample(logits))
            for i, ds in enumerate(slots):
                req = self.requests[ds.req_id]
                req.decode_steps += 1
                self._append_token(req, int(new[i]))
            return
        if self._cpu_step_sync:
            jax.block_until_ready(toks)  # see module header: dispatch race
        if hasattr(toks, 'copy_to_host_async'):
            toks.copy_to_host_async()   # overlap the eventual flush
        records: List[tuple] = []
        self._prev_tokens, self._prev_rows = toks, {}
        for i, ds in enumerate(slots):
            req = self.requests[ds.req_id]
            req.decode_steps += 1
            self._prev_rows[ds.req_id] = i
            self._append_pending(req, i, records)
        self._pending.append((toks, records))
        self._pending_rids.update(r[0] for r in records)
        if self.cfg.eos_token is not None:
            # the stop check needs token values — fetch every step (the
            # documented fused-path fallback for eos-terminated serving)
            self.flush_tokens()
            for ds in slots:
                req = self.requests[ds.req_id]
                if (req.state == ReqState.RUNNING and req.generated
                        and req.generated[-1] == self.cfg.eos_token):
                    self._finish(req)

    def _sample(self, logits):
        if self.cfg.temperature > 0:
            self._key, sub = jax.random.split(self._key)
            return sample(logits, temperature=self.cfg.temperature, key=sub)
        return sample(logits)

    def _append_pending(self, req: Request, row: int,
                        records: List[tuple]) -> None:
        """Fused-path append: the sampled value is still on device, so a
        placeholder lands in ``generated`` (patched by flush_tokens) while
        every count-based fact — fill progress, timestamps, length-based
        finish — is recorded eagerly (none of it reads the value)."""
        req.generated.append(-1)
        records.append((req.req_id, len(req.generated) - 1, row))
        if req.lease is not None:
            req.lease.note_filled(len(req.context) - 1)
        now = self.clock.now()
        if req.t_first_token is None:
            req.t_first_token = now
        req.t_last_token = now
        self.stats.tokens_generated += 1
        if len(req.generated) >= req.max_new_tokens:
            self._finish(req)

    def flush_tokens(self) -> None:
        """Resolve lazily-held sampled tokens to host ints (fused path).

        The fused decode path leaves placeholders in ``Request.generated``
        and keeps values on device; anything that reads token VALUES —
        stream emission, prefill re-reads after invalidation, eos checks —
        calls this first.  No-op when nothing is pending, so callers may
        invoke it unconditionally."""
        if not self._pending:
            return
        for arr, records in self._pending:
            vals = np.asarray(arr)
            for rid, gi, row in records:
                self.requests[rid].generated[gi] = int(vals[row])
        self._pending.clear()
        self._pending_rids.clear()
        self.stats.token_flushes += 1

    def _append_token(self, req: Request, tok: int) -> None:
        req.generated.append(tok)
        if req.lease is not None:
            # KV is materialized for every context token but the new one
            req.lease.note_filled(len(req.context) - 1)
        now = self.clock.now()
        if req.t_first_token is None:
            req.t_first_token = now
        req.t_last_token = now
        self.stats.tokens_generated += 1
        done = (len(req.generated) >= req.max_new_tokens
                or (self.cfg.eos_token is not None
                    and tok == self.cfg.eos_token))
        if done:
            self._finish(req)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One scheduling step; returns True if any dispatch happened."""
        if self._gated():
            self.stats.blocked_dispatches += 1
            return False
        batch = self.sched.schedule(self.requests, self._try_admit,
                                    self._spill)
        self.stats.steps += 1
        if batch.empty:
            return False
        if batch.prefill:
            self._dispatch_mixed(batch)
        else:
            self._dispatch_decode(batch.decode)
        return True

    def run_to_completion(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not (self.queue or self.running):
                return
            if not self.step() and self._gated():
                raise RuntimeError('offline engine gated; drive via runtime')
        raise RuntimeError('run_to_completion exceeded max_steps')

    # ------------------------------------------------------------------
    @property
    def finished(self) -> List[Request]:
        return [r for r in self.requests.values()
                if r.state == ReqState.FINISHED]

    def output_tokens(self, rid: str) -> List[int]:
        self.flush_tokens()
        return list(self.requests[rid].generated)
