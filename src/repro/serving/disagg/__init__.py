"""Disaggregated prefill/decode serving plane (see ``plane.py``)."""
from repro.serving.disagg.plane import DisaggPlane, DisaggStats

__all__ = ['DisaggPlane', 'DisaggStats']
