"""Disaggregated prefill/decode serving plane (migration-based KV handoff).

Production disaggregation splits the two phases of online inference onto
separate engine sets so their interference profiles separate: *prefill*
(compute-bound, bursty, long dispatches) runs on one pool, *decode*
(memory-bound, steady, short dispatches) on another.  The classic cost of
the split is the KV handoff — the prefilled cache must reach the decode
workers without recomputing it.

:class:`DisaggPlane` builds the split out of mechanisms this repo already
trusts, rather than a new transfer protocol:

- **two full Valve nodes** — each side is an ordinary
  :class:`~repro.launch.node.NodeOrchestrator` (own
  :class:`~repro.core.runtime.ValveRuntime`, own
  :class:`~repro.serving.kvpool.KVPool` + gates + MIAD + telemetry),
  constructed with ``disaggregated=True`` so cross-pool migration
  completion is delegated here instead of to the node's rescue handler;
- **handoff == lease migration** — when a request's prefill completes on
  the prefill node's online engine, :meth:`step` calls
  ``MemoryPlane.migrate(rid, decode_plane)``: the proven cross-pool
  data-plane path (``KVPool.transfer_pages``) allocates pages on the
  decode pool, publishes a :class:`~repro.core.events.PageMigration`, and
  this plane's subscriber — running synchronously inside the publish,
  before the freed source pages can be reallocated — copies the physical
  KV rows between the engine caches and re-homes the ``Request`` onto the
  decode engine;
- **zero recompute, bit-identical** — the migrated lease carries its fill
  point, so decode-side admission resumes at ``lease.resume_tokens``:
  exactly one un-materialized token (the last sampled one, whose KV a
  plain decode step would write anyway) flows through the prefill entry,
  and greedy output is bit-identical to a colocated single-pool run;
- **refusal == deferral** — a falsy
  :class:`~repro.core.memory.MigrationRefusal` (decode pool full, shared
  pages) leaves the source untouched; the request simply keeps decoding on
  the prefill engine — the colocated fallback, still bit-identical — and
  the handoff is retried next step;
- **both pools backfill** — each node keeps its own offline engines behind
  its own gates.  The prefill side frees its online lifecycle at handoff
  (``session.finish``), so once its queue drains, T_cool elapses and its
  gates wake offline work while decode is still streaming — harvesting
  exactly the idleness disaggregation creates.  Each runtime keeps the
  ≤ 1-preemption-per-(request, device) bound independently; devices are
  disjoint between the nodes, so the joint bound holds per (request,
  device).

Every completed handoff publishes a typed
:class:`~repro.core.events.PrefillHandoff` on BOTH runtimes' buses
(latency, pages copied, per-pool queue depths), folded into each
:class:`~repro.core.telemetry.TelemetryRegistry`.

The plane duck-types the :class:`NodeOrchestrator` driver surface
(``clock``/``online``/``offline``/``has_work``/``step``/``metrics``/
``engine_of``), so the async front-end (``AsyncNodeDriver``, the SSE app,
batch jobs) runs over it unchanged — streams keep flowing across the
handoff because the driver resolves each request's holding engine per
flush.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.events import PageMigration, PrefillHandoff
from repro.launch.node import NodeOrchestrator
from repro.serving.engine import Engine
from repro.serving.scheduler import ReqState

__all__ = ['DisaggPlane', 'DisaggStats']


@dataclass
class DisaggStats:
    steps: int = 0
    handoffs: int = 0               # prefill → decode lease moves completed
    handoffs_deferred: int = 0      # migrate refusals (retried next step)
    pages_copied: int = 0           # physical KV rows moved between caches
    rescues: int = 0                # offline cross-pool rescues completed


class DisaggPlane:
    """Two Valve nodes — prefill and decode — joined by lease migration.

    Both nodes must share one clock (one virtual timeline), be constructed
    with ``disaggregated=True`` (this plane is the single cross-pool
    migration completer), and have distinct pool names (names key
    PageMigration provenance).  Online engines on the two sides must be
    the same architecture with identical parameters — the bit-identity
    contract of the handoff; ``_try_handoff`` asserts the architecture.
    """

    def __init__(self, prefill: NodeOrchestrator, decode: NodeOrchestrator):
        assert prefill is not decode, 'prefill and decode must be two nodes'
        assert prefill.disaggregated and decode.disaggregated, \
            'both nodes must be built with disaggregated=True (the plane ' \
            'is the single cross-pool migration completer)'
        assert prefill.clock is decode.clock, \
            'disaggregated nodes must share one clock'
        assert prefill.pool.name != decode.pool.name, \
            f'pool names must differ (both {prefill.pool.name!r})'
        assert prefill.pool.page_size == decode.pool.page_size, \
            (prefill.pool.page_size, decode.pool.page_size)
        self.prefill = prefill
        self.decode = decode
        self.clock = prefill.clock
        self.stats = DisaggStats()
        self.handoffs: List[Tuple[str, str, str]] = []  # (rid, src, dst)
        # set by pair_cheapest: (src_node, dst_node, tier, cost) — the
        # interconnect the KV handoff crosses (placement.TopologyModel)
        self.link: Optional[Tuple[str, str, str, float]] = None
        # one subscription sees every migration between the two pools:
        # transfer_pages publishes on each DISTINCT bus involved (src and
        # dst), so the prefill bus carries both directions exactly once
        prefill.runtime.subscribe(self._on_migration, PageMigration)

    # ------------------------------------------------------------------
    # Topology-aware pairing (cluster placement plane)
    # ------------------------------------------------------------------
    @classmethod
    def pair_cheapest(cls, prefill_nodes: Dict[str, 'NodeOrchestrator'],
                      decode_nodes: Dict[str, 'NodeOrchestrator'],
                      topology) -> 'DisaggPlane':
        """Build the plane over the candidate pair joined by the cheapest
        interconnect link.

        ``prefill_nodes``/``decode_nodes`` map cluster node names (the
        ``TopologyModel``'s coordinates) to candidate orchestrators;
        ``topology.cheapest_pair`` picks where the prefill→decode KV copy
        is cheapest (NVLink/PCIe inside a node beat node-local, which
        beats cross-rack).  The chosen link is recorded on ``plane.link``
        and reported in :meth:`metrics` as ``handoff_link``.
        """
        src, dst, tier, cost = topology.cheapest_pair(
            list(prefill_nodes), list(decode_nodes))
        pre, dec = prefill_nodes[src], decode_nodes[dst]
        assert pre is not dec, \
            'cheapest pair resolved to one orchestrator — need two pools'
        plane = cls(pre, dec)
        plane.link = (src, dst, tier, cost)
        return plane

    # ------------------------------------------------------------------
    # Optional: cross-pool rescue of offline reclamation victims
    # ------------------------------------------------------------------
    def enable_cross_rescue(self) -> None:
        """Link the two memory planes as mutual migration targets, so a
        reclamation victim on either pool is first offered a rescue to the
        other (``MemoryPlane._rescue_victims``) instead of truncation.
        Call after registering engines: each side needs ≥ 1 offline engine
        to re-home rescued requests onto."""
        assert self.prefill.offline and self.decode.offline, \
            'cross-rescue needs an offline engine on both nodes'
        pp, dp = self.prefill.runtime.memory, self.decode.runtime.memory
        if dp not in pp.migration_targets:
            pp.migration_targets = pp.migration_targets + [dp]
        if pp not in dp.migration_targets:
            dp.migration_targets = dp.migration_targets + [pp]

    # ------------------------------------------------------------------
    # NodeOrchestrator driver surface (duck-typed for the front-end)
    # ------------------------------------------------------------------
    @property
    def online(self) -> Optional[Engine]:
        """The submission surface: new online requests enter at prefill."""
        return self.prefill.online

    @property
    def offline(self) -> List[Engine]:
        return list(self.prefill.offline) + list(self.decode.offline)

    @property
    def engines(self) -> List[Engine]:
        return self.prefill.engines + self.decode.engines

    def submit(self, prompt, max_new_tokens: int = 32) -> str:
        assert self.prefill.online is not None, 'plane has no online engine'
        return self.prefill.online.submit(prompt, max_new_tokens)

    def engine_of(self, req_id: str) -> Optional[Engine]:
        """The engine currently holding ``req_id``, on either node — the
        front-end cancel/flush paths follow the request across the
        handoff through this."""
        eng = self.prefill.engine_of(req_id)
        if eng is not None:
            return eng
        return self.decode.engine_of(req_id)

    def has_work(self) -> bool:
        return self.prefill.has_work() or self.decode.has_work()

    def step(self) -> bool:
        """One plane tick: prefill node, then the handoff pump, then the
        decode node — a prefill that completes in this tick's first phase
        reaches the decode engine before its next dispatch."""
        self.stats.steps += 1
        progressed = self.prefill.step()
        self._pump_handoffs()
        if self.decode.step():
            progressed = True
        return progressed

    def run(self, steps: int) -> None:
        for _ in range(steps):
            self.step()

    def drain(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.has_work():
                return
            self.step()
        raise RuntimeError('drain exceeded max_steps')

    # ------------------------------------------------------------------
    # The handoff scheduler
    # ------------------------------------------------------------------
    def _pump_handoffs(self) -> None:
        """Move every prefill-complete online request to the decode node.

        A request is ready exactly when it sits RUNNING on the prefill
        engine: its last prefill chunk executed and produced the first
        token.  (FINISHED requests — e.g. ``max_new_tokens == 1`` — never
        hand off; CANCELLED ones released their lease already.)"""
        pe, de = self.prefill.online, self.decode.online
        if pe is None or de is None:
            return
        for rid in list(pe.running):
            req = pe.requests[rid]
            if req.state is ReqState.RUNNING:
                self._try_handoff(req)

    def _try_handoff(self, req) -> bool:
        pe, de = self.prefill.online, self.decode.online
        # bit-identity contract: the decode engine replays the request's
        # remaining tokens through identical weights
        assert pe.mcfg.name == de.mcfg.name, (pe.mcfg.name, de.mcfg.name)
        assert req.target_len <= de.cfg.max_seq, \
            (req.target_len, de.cfg.max_seq)
        rid = req.req_id
        moved = self.prefill.runtime.memory.migrate(
            rid, self.decode.runtime.memory)
        if not moved:
            # explicit refusal (decode pool full, shared pages): source
            # untouched — the request keeps decoding on the prefill engine
            # (colocated fallback, still bit-identical), retried next step
            self.stats.handoffs_deferred += 1
            return False
        # the PageMigration subscriber already ran inside migrate(): KV
        # rows copied and the Request re-homed onto the decode engine
        assert rid in de.requests and rid not in pe.requests, rid
        # balance the prefill-side online lifecycle (started at submit
        # admission): free() no-ops — the lease left this plane — and
        # request_end lets the prefill node reach T_cool idle and wake its
        # own offline backfill while decode streams
        pe.session.finish(rid)
        # prefill materialized KV for every context token but the last
        # sampled one; the lease's resume point must say exactly that —
        # anything less would be recomputed on decode (contract: 0)
        recompute = max(0, (len(req.context) - 1) - moved.resume_tokens)
        now = self.clock.now()
        t0 = req.t_first_token if req.t_first_token is not None else now
        fields = dict(
            req_id=rid,
            src_pool=self.prefill.pool.name,
            dst_pool=self.decode.pool.name,
            pages_copied=moved.n_pages,
            latency_s=now - t0,
            recompute_tokens=recompute,
            prefill_queue_depth=len(pe.queue) + len(pe.running),
            decode_queue_depth=len(de.queue) + len(de.running))
        # both telemetry registries fold the handoff (each side's report
        # stands alone); the buses are distinct so nothing double-counts
        for bus in (self.prefill.runtime.bus, self.decode.runtime.bus):
            bus.publish(PrefillHandoff, **fields)
        self.stats.handoffs += 1
        self.handoffs.append(
            (rid, self.prefill.pool.name, self.decode.pool.name))
        return True

    # ------------------------------------------------------------------
    # Cross-pool migration completion (PageMigration subscriber)
    # ------------------------------------------------------------------
    def _node_of_pool(self, pool_name: str) -> Optional[NodeOrchestrator]:
        if pool_name == self.prefill.pool.name:
            return self.prefill
        if pool_name == self.decode.pool.name:
            return self.decode
        return None

    def _pick_engine(self, node: NodeOrchestrator, pool_name: str,
                     klass: str, arch: str) -> Optional[Engine]:
        """Destination engine for a re-homed request: must serve the
        destination pool in the same class (an offline rescue must stay
        offline); same architecture preferred (physical KV rows copy)."""
        cands = [e for e in node.engines
                 if e.pool.name == pool_name and e.cfg.klass == klass]
        for e in cands:
            if e.mcfg.name == arch:
                return e
        return cands[0] if cands else None

    def _on_migration(self, ev: PageMigration) -> None:
        """Complete a cross-pool move between the two nodes: copy the KV
        cache rows behind the moved pages and re-home the ``Request``.

        Runs synchronously inside the ``transfer_pages`` publish — i.e.
        inside ``MemoryPlane.migrate`` — while the source engine is
        quiescent and before the freed source pages can be reallocated
        and overwritten.  Handles both directions (online handoff,
        optional offline rescue) through one code path."""
        if not ev.cross_pool:
            return
        src_node = self._node_of_pool(ev.src_pool)
        dst_node = self._node_of_pool(ev.dst_pool)
        if src_node is None or dst_node is None or src_node is dst_node:
            return
        src = src_node._engine_for_pool(ev.src_pool, holding=ev.owner)
        if src is None:
            return              # not a serving-engine lease — no handoff
        dst = self._pick_engine(dst_node, ev.dst_pool,
                                src.cfg.klass, src.mcfg.name)
        if dst is None or dst is src:
            return
        # data plane: same-architecture engines move the physical KV rows
        # (page axis 1 of the engine pool layout)
        if ev.src_pages and src.mcfg.name == dst.mcfg.name:
            s = np.asarray(ev.src_pages)
            d = np.asarray(ev.dst_pages)
            dst.cache = jax.tree_util.tree_map(
                lambda dc, sc: dc.at[:, d].set(sc[:, s]),
                dst.cache, src.cache)
            self.stats.pages_copied += len(ev.src_pages)
        # control plane: hand the request off.  Pending fused-path tokens
        # reference src.requests by id — resolve them before the pop.
        src.flush_tokens()
        req = src.requests.pop(ev.owner)
        if ev.owner in src.queue:
            src.queue.remove(ev.owner)
        if ev.owner in src.running:
            src.running.remove(ev.owner)
        req.state = ReqState.WAITING
        req.pages, req.blocked_admits = [], 0
        dst.requests[ev.owner] = req
        dst.sched.submit(ev.owner)
        # admission on dst finds the migrated live lease in its plane and
        # resumes at lease.resume_tokens — nothing recomputes
        if src.cfg.klass == 'offline':
            self.stats.rescues += 1

    # ------------------------------------------------------------------
    # Metrics / invariants
    # ------------------------------------------------------------------
    def finished_online(self) -> List[object]:
        """All finished online requests, wherever they ended: handed-off
        requests finish on the decode engine, deferred-forever (or
        single-token) ones on the prefill engine."""
        out = []
        for node in (self.prefill, self.decode):
            if node.online is not None:
                out.extend(node.online.finished)
        return out

    def metrics(self) -> Dict[str, object]:
        fin = self.finished_online()
        ttfts = [r.ttft for r in fin if r.ttft is not None]
        tpots = [r.tpot for r in fin if r.tpot and r.tpot > 0]
        tel_p = self.prefill.runtime.telemetry.snapshot()
        tel_d = self.decode.runtime.telemetry.snapshot()
        return {
            'online_finished': len(fin),
            'online_ttft_p50': float(np.median(ttfts)) if ttfts else None,
            'online_tpot_p50': float(np.median(tpots)) if tpots else None,
            'offline_tokens': sum(e.stats.tokens_generated
                                  for e in self.offline),
            'offline_finished': sum(len(e.finished) for e in self.offline),
            'handoffs': self.stats.handoffs,
            'handoffs_deferred': self.stats.handoffs_deferred,
            'handoff_link': self.link,   # (src, dst, tier, cost) | None

            'pages_copied': self.stats.pages_copied,
            'rescues': self.stats.rescues,
            # each registry folded the same PrefillHandoff stream
            'handoff_pages': tel_p['handoff_pages'],
            'handoff_recompute_tokens': tel_p['handoff_recompute_tokens'],
            'handoff_latency': tel_p['handoff_latency'],
            # the joint preemption bound is per (request, device); devices
            # are disjoint between the nodes, so report the worst side
            'max_preemptions_per_request': max(
                tel_p['max_preemptions_per_request'],
                tel_d['max_preemptions_per_request']),
            'prefill': self.prefill.metrics(),
            'decode': self.decode.metrics(),
        }

    def check_invariants(self) -> None:
        """Both runtimes' §4–5 invariants (event ordering, ≤ 1 preemption
        per request per device, wake rule, memory-plane consistency)."""
        self.prefill.runtime.check_invariants()
        self.decode.runtime.check_invariants()
