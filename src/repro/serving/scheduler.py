"""Batch-composition scheduler — the policy layer of the serving plane.

The engine used to decide *what to run next* inline in ``Engine.step()``:
one request's prefill chunk (batch 1) **or** one decode iteration, never
both.  This module owns that decision as an explicit layer.  Each call to
:meth:`BatchScheduler.schedule` composes one *dispatch*:

- **budgeted multi-request chunked prefill** — the per-dispatch prefill
  token budget is filled FIFO across *multiple* waiting-to-prefill requests
  (each row capped at ``chunk`` tokens, at most ``max_prefill_reqs`` rows);
- **piggybacked decode** — every request already in the RUNNING state gets a
  one-token decode slot in the *same* iteration,

so each engine step does strictly more work per compile-once dispatch while
the dispatch unit stays fixed-shape (``max_batch`` rows × ``chunk`` width —
the preemptible unit the Valve gates check between).

The scheduler is engine-agnostic: it never touches tensors, allocators or
the runtime.  Admission is delegated through a caller-supplied
``try_admit`` callable — in the Valve integration that is one
``session.admit`` call (the :class:`~repro.core.api.ValveSession` bundle:
lifecycle notification, then allocation, with rollback on failure) — which
keeps the FIFO head-of-line-blocking policy here and the control-plane
plumbing behind the session API.  Request bookkeeping (:class:`Request`,
:class:`ReqState`) lives here too — requests are scheduler domain; the
engine re-exports them for compatibility.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class ReqState(enum.Enum):
    WAITING = 'waiting'
    PREFILL = 'prefill'
    RUNNING = 'running'
    FINISHED = 'finished'
    CANCELLED = 'cancelled'         # abandoned by the client (terminal)


@dataclass
class Request:
    req_id: str
    prompt: List[int]
    max_new_tokens: int
    state: ReqState = ReqState.WAITING
    generated: List[int] = field(default_factory=list)
    pages: List[int] = field(default_factory=list)
    # the memory-plane handle behind ``pages`` (None until admitted, or
    # when admission went through a plain page-list allocator)
    lease: Optional[object] = None
    n_prefilled: int = 0
    recomputes: int = 0
    blocked_admits: int = 0       # consecutive failed admission attempts
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_last_token: Optional[float] = None
    decode_steps: int = 0

    @property
    def context(self) -> List[int]:
        """Prompt + already-generated tokens (what recompute re-prefills)."""
        return self.prompt + self.generated

    @property
    def target_len(self) -> int:
        return len(self.prompt) + self.max_new_tokens

    # -- latency metrics ---------------------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def tpot(self) -> Optional[float]:
        if self.t_last_token is None or self.t_first_token is None:
            return None
        n = len(self.generated) - 1
        if n <= 0:
            return 0.0
        return (self.t_last_token - self.t_first_token) / n


@dataclass
class SchedulerConfig:
    max_batch: int = 8              # dispatch rows (prefill + decode slots)
    chunk: int = 64                 # row width: max prefill tokens per row
    max_prefill_reqs: int = 4       # prefill rows per dispatch
    # total prefill tokens per dispatch; None → max_prefill_reqs × chunk
    prefill_budget: Optional[int] = None
    # decode slots ride along with prefill rows in one mixed dispatch;
    # False reproduces the seed engine's prefill-XOR-decode alternation
    piggyback_decode: bool = True
    # after this many consecutive failed admissions of the queue head,
    # waiting requests' surviving-prefix pages are spilled (released) one
    # at a time until the head fits — partial KV retention is a luxury
    # that must degrade to whole-request recompute, never deadlock
    # admission on pages held by requests that cannot run
    spill_after_blocked: int = 3

    @property
    def budget(self) -> int:
        if self.prefill_budget is not None:
            return self.prefill_budget
        return self.max_prefill_reqs * self.chunk


@dataclass(frozen=True)
class PrefillSlot:
    """One row of chunked prefill: context[start : start+length]."""
    req_id: str
    start: int
    length: int


@dataclass(frozen=True)
class DecodeSlot:
    """One piggybacked single-token decode row."""
    req_id: str


@dataclass
class ScheduledBatch:
    """One composed dispatch: prefill rows first, then decode rows."""
    prefill: List[PrefillSlot] = field(default_factory=list)
    decode: List[DecodeSlot] = field(default_factory=list)

    @property
    def empty(self) -> bool:
        return not (self.prefill or self.decode)

    @property
    def n_slots(self) -> int:
        return len(self.prefill) + len(self.decode)

    @property
    def prefill_tokens(self) -> int:
        return sum(s.length for s in self.prefill)


# try_admit(request) → the request's KVLease (or a plain page list), or
# None to block admission (the request stays at the queue head — FIFO
# head-of-line blocking).  For a partially-invalidated request the lease
# is *extended*: its ``resume_tokens`` is where prefill resumes.
AdmitFn = Callable[[Request], Optional[List[int]]]

# spill(request) → release a waiting request's surviving-prefix pages
# (scheduler-driven deadlock valve; see SchedulerConfig.spill_after_blocked)
SpillFn = Callable[[Request], None]


class BatchScheduler:
    """FIFO continuous-batching policy over one engine's request set.

    Owns the waiting ``queue`` and admitted ``running`` lists (the engine
    aliases them, so the < 20-LOC Valve patch keeps mutating the same
    objects).  ``schedule()`` admits, then composes the next dispatch.
    """

    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        self.cfg = cfg or SchedulerConfig()
        assert self.cfg.max_prefill_reqs <= self.cfg.max_batch
        self.queue: List[str] = []       # FIFO waiting queue
        self.running: List[str] = []     # admitted (PREFILL or RUNNING)

    # ------------------------------------------------------------------
    def submit(self, req_id: str) -> None:
        self.queue.append(req_id)

    def has_work(self) -> bool:
        return bool(self.queue or self.running)

    # ------------------------------------------------------------------
    def admit(self, requests: Dict[str, Request], try_admit: AdmitFn,
              spill: Optional[SpillFn] = None) -> int:
        """FIFO admission until memory or the batch cap blocks; returns the
        number of requests admitted.

        When the head has been blocked ``spill_after_blocked`` times in a
        row and a ``spill`` callback is given, waiting requests' surviving-
        prefix pages are released one at a time (head first) until the head
        fits — sustained pressure degrades partial retention to the legacy
        whole-request recompute instead of deadlocking on pages held by
        requests that cannot run.
        """
        admitted = 0
        while self.queue and len(self.running) < self.cfg.max_batch:
            req = requests[self.queue[0]]
            res = try_admit(req)
            if res is None and spill is not None:
                req.blocked_admits += 1
                if req.blocked_admits >= self.cfg.spill_after_blocked:
                    for rid in list(self.queue):
                        if not requests[rid].pages:
                            continue
                        spill(requests[rid])
                        res = try_admit(req)
                        if res is not None:
                            break
            if res is None:
                break                    # head-of-line blocks until pages free
            self.queue.pop(0)
            req.blocked_admits = 0
            req.pages = list(res)
            req.state = ReqState.PREFILL
            # a lease resumes where its valid KV ends (0 when fresh): the
            # shared prefix at first admission, the surviving prefix on a
            # post-invalidation re-admission
            req.n_prefilled = getattr(res, 'resume_tokens', 0)
            self.running.append(req.req_id)
            admitted += 1
        return admitted

    def compose(self, requests: Dict[str, Request]) -> ScheduledBatch:
        """Compose the next dispatch from the admitted set (no admission)."""
        batch = ScheduledBatch()
        budget = self.cfg.budget
        for rid in self.running:         # FIFO by admission order
            if len(batch.prefill) >= self.cfg.max_prefill_reqs or budget <= 0:
                break
            req = requests[rid]
            if req.state is not ReqState.PREFILL:
                continue
            n = min(len(req.context) - req.n_prefilled, self.cfg.chunk, budget)
            if n <= 0:
                continue
            batch.prefill.append(PrefillSlot(rid, req.n_prefilled, n))
            budget -= n
        if batch.prefill and not self.cfg.piggyback_decode:
            return batch
        # decode slots: every RUNNING request rides along.  Row capacity is
        # never the binding constraint — len(running) ≤ max_batch and prefill
        # rows come out of the same admitted set — but guard anyway.
        rows_left = self.cfg.max_batch - len(batch.prefill)
        for rid in self.running:
            if rows_left <= 0:
                break
            if requests[rid].state is ReqState.RUNNING:
                batch.decode.append(DecodeSlot(rid))
                rows_left -= 1
        return batch

    def schedule(self, requests: Dict[str, Request], try_admit: AdmitFn,
                 spill: Optional[SpillFn] = None) -> ScheduledBatch:
        """One scheduling decision: admit, then compose the dispatch."""
        self.admit(requests, try_admit, spill)
        return self.compose(requests)
