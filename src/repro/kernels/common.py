"""Shared kernel toolkit + JAX version-compat shim.

Every version-sensitive JAX surface the kernels touch goes through this
module, so an API rename in a jax upgrade is a one-file fix instead of a
sweep over every ``kernel.py``:

- **compiler params**: ``pltpu.CompilerParams`` (jax ≥ 0.5) vs
  ``pltpu.TPUCompilerParams`` (jax 0.4.x) — :func:`compiler_params`;
- **shard_map**: ``jax.shard_map(..., check_vma=)`` (jax ≥ 0.6) vs
  ``jax.experimental.shard_map.shard_map(..., check_rep=)`` —
  :func:`shard_map`;
- **cost analysis**: ``Compiled.cost_analysis()`` returns a dict on new jax
  and a one-element list of dicts on 0.4.x — :func:`cost_analysis_dict`.

It also centralizes the machinery all three Pallas kernels (flash, paged,
wkv6) previously re-implemented:

- TPU-lane-aligned block/tile-size selection and padding
  (:func:`pick_block`, :func:`pad_axis_to`);
- the online-softmax running max/denominator update carried across the
  sequential grid axis (:func:`online_softmax_init` /
  :func:`online_softmax_update` / :func:`online_softmax_finalize`);
- causal and length ("quarantine") masking on score blocks
  (:func:`mask_block_scores`);
- automatic interpret-mode fallback off-TPU (:func:`resolve_interpret`) so
  the parity suite runs everywhere.
"""
from __future__ import annotations

import functools
import inspect
import re
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    'NEG_INF', 'LANES', 'SUBLANES',
    'jax_version', 'jax_at_least',
    'compiler_params', 'shard_map', 'cost_analysis_dict',
    'resolve_interpret',
    'ceil_div', 'round_up', 'pick_block', 'pad_axis_to',
    'online_softmax_init', 'online_softmax_update', 'online_softmax_finalize',
    'block_positions', 'mask_block_scores',
    'hash_u32', 'gumbel_hash_noise',
]

# Softmax mask fill value: large-negative but finite in f32, so a fully
# masked row underflows exp() to 0 instead of producing NaN via inf - inf.
NEG_INF = -1e30

# TPU register tiling: last dim is always 128 lanes; the f32 sublane count
# is 8 (doubles for bf16 / quadruples for int8 — see the Pallas guide).
LANES = 128
SUBLANES = 8


# ---------------------------------------------------------------------------
# Version detection
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def jax_version() -> Tuple[int, ...]:
    """``jax.__version__`` as an int tuple ('0.4.37' → (0, 4, 37))."""
    return tuple(int(p) for p in
                 re.findall(r'\d+', jax.__version__)[:3])


def jax_at_least(*version: int) -> bool:
    return jax_version() >= tuple(version)


# ---------------------------------------------------------------------------
# Compat shims
# ---------------------------------------------------------------------------

# jax 0.5 renamed TPUCompilerParams → CompilerParams (and kept a deprecation
# alias for a while); 0.4.x only has the TPU-prefixed name.
_COMPILER_PARAMS_CLS = getattr(pltpu, 'CompilerParams', None) \
    or getattr(pltpu, 'TPUCompilerParams')


def compiler_params(*, dimension_semantics: Optional[Sequence[str]] = None,
                    **kwargs):
    """Construct Mosaic compiler params under either jax naming.

    Kernels must use this instead of touching ``pltpu.*CompilerParams``
    directly (enforced by the kernel parity suite staying green across jax
    upgrades).
    """
    return _COMPILER_PARAMS_CLS(dimension_semantics=dimension_semantics,
                                **kwargs)


def shard_map(f, mesh, *, in_specs, out_specs, check_replication: bool = True):
    """Version-portable ``shard_map``.

    Two independent API moves are absorbed here: the promotion from
    ``jax.experimental.shard_map.shard_map`` to ``jax.shard_map``, and the
    ``check_rep`` → ``check_vma`` kwarg rename — they landed in different
    jax releases, so the kwarg is probed from the actual signature rather
    than inferred from where the function lives.
    """
    if hasattr(jax, 'shard_map'):
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    check_kw = 'check_vma' if 'check_vma' in params else 'check_rep'
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{check_kw: check_replication})


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a flat dict.

    jax 0.4.x returns a one-element list of per-program dicts; newer jax
    returns the dict directly (and may return None for trivial programs).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret`` tri-state: None → auto.

    Mosaic kernels only compile for TPU backends; everywhere else (the CPU
    parity/CI suites, GPU dev boxes) the same kernel runs under the Pallas
    interpreter, which lowers to plain HLO.  Passing an explicit bool always
    wins — tests pin ``interpret=True`` so they are hermetic.
    """
    if interpret is None:
        return jax.default_backend() != 'tpu'
    return interpret


# ---------------------------------------------------------------------------
# Block / tile selection and padding
# ---------------------------------------------------------------------------

def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, multiple: int) -> int:
    return ceil_div(x, multiple) * multiple


def pick_block(dim: int, preferred: int, *, align: int = SUBLANES) -> int:
    """Block size for a ``dim``-long *sequence* axis: ``preferred``, shrunk
    for short axes but always a multiple of ``align`` so tiles stay
    sublane-aligned (the last/lane dim of a tile is the head dim and is
    fixed by the caller, so the default alignment here is the f32 sublane
    count).

    A 1024-token axis at preferred 128 → 128; a 50-token axis → 56 (one
    near-fit block beats a mostly-padded 128); a 300-token axis at
    preferred 512 → 304.
    """
    assert preferred % align == 0, (preferred, align)
    if dim >= preferred:
        return preferred
    return max(align, min(preferred, round_up(dim, align)))


def pad_axis_to(x, axis: int, multiple: int, *, value=0):
    """Zero-pad (or ``value``-pad) one axis of ``x`` up to a multiple.

    Returns ``x`` unchanged when already aligned — the common case at
    production shapes, so no copy is inserted.
    """
    size = x.shape[axis]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


# ---------------------------------------------------------------------------
# Online softmax (the running-max/denominator state all attention kernels
# carry across their sequential KV/page grid axis)
# ---------------------------------------------------------------------------

def online_softmax_init(m_ref, l_ref, acc_ref) -> None:
    """Reset the VMEM scratch carried across the sequential grid axis."""
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def online_softmax_update(s, v, m_prev, l_prev, acc_prev):
    """One online-softmax step over a masked score block.

    s: (rows, cols) f32 scores (masked entries at NEG_INF); v: (cols, D).
    Returns the rescaled ``(m_new, l_new, acc_new)`` running state.  Fully
    masked rows are safe: ``exp(NEG_INF - m)`` underflows to 0.
    """
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_new = (acc_prev * alpha[:, None]
               + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32))
    return m_new, l_new, acc_new


def online_softmax_finalize(acc, l):
    """acc / l with fully-masked rows (l == 0) mapped to 0, not NaN."""
    safe = jnp.where(l == 0.0, 1.0, l)
    return acc / safe[:, None]


# ---------------------------------------------------------------------------
# Counter-based sampling noise (shared by the fused sampling kernel and its
# jnp reference so kernel-vs-ref parity is bit-identical)
# ---------------------------------------------------------------------------

def hash_u32(x):
    """Stateless u32 avalanche hash (splitmix-style finalizer).

    Pure element-wise integer ops, so it lowers identically inside a Pallas
    kernel and in plain jnp — the property the fused-sampling parity suite
    relies on.  Input is cast to uint32; multiplication wraps mod 2**32.
    """
    x = jnp.asarray(x).astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def gumbel_hash_noise(seed, rows, cols):
    """Deterministic Gumbel(0, 1) noise per (row, col) counter.

    ``argmax(logits / T + gumbel)`` is an exact sample from
    ``softmax(logits / T)`` (the Gumbel-max trick), so the fused sampling
    kernel can carry temperature sampling as a pure argmax reduction — no
    cumulative-sum search, no logits round-trip.  The noise is a counter
    hash (seed, row, col), not a stream: any tile of the (B, V) grid can be
    generated independently inside its kernel block and matches the jnp
    reference bit-for-bit.
    """
    seed = jnp.asarray(seed).astype(jnp.uint32)
    h = hash_u32(seed ^ (jnp.asarray(rows).astype(jnp.uint32)
                         * jnp.uint32(0x9E3779B9)))
    bits = hash_u32(h ^ jnp.asarray(cols).astype(jnp.uint32))
    # top 24 bits → uniform on the open interval (0, 1): representable
    # exactly in f32, never 0 or 1, so the double log below stays finite
    u = ((bits >> jnp.uint32(8)).astype(jnp.float32)
         * jnp.float32(2.0 ** -24) + jnp.float32(2.0 ** -25))
    return -jnp.log(-jnp.log(u))


# ---------------------------------------------------------------------------
# Masking (causal + length/quarantine)
# ---------------------------------------------------------------------------

def block_positions(block_index, block_size: int, shape, dim: int):
    """Absolute positions of a tile's rows/cols: block offset + iota."""
    return block_index * block_size + jax.lax.broadcasted_iota(
        jnp.int32, shape, dim)


def mask_block_scores(s, *, q_pos=None, k_pos=None, causal: bool = False,
                      kv_len=None):
    """Apply causal and/or valid-length masking to a score block.

    ``kv_len`` bounds valid KV positions — this is the quarantine contract:
    tokens past a request's length (including garbage streamed from the
    always-mapped quarantine page) are forced to NEG_INF so they cannot
    contribute, which is what makes page reclamation harmless for healthy
    requests (paper §5).
    """
    mask = None
    if kv_len is not None:
        assert k_pos is not None
        mask = k_pos < kv_len
    if causal:
        assert q_pos is not None and k_pos is not None
        cmask = q_pos >= k_pos
        mask = cmask if mask is None else (mask & cmask)
    if mask is None:
        return s
    return jnp.where(mask, s, NEG_INF)
