"""jit'd public wrapper for the flash-attention kernel.

Layout contract with the model code: q (B, Sq, Hq, D), k/v (B, Skv, Hkv, D)
— same as models.common.attention.  The wrapper flattens heads batch-major
so the kernel's GQA index maps work.  ``interpret=None`` (the default)
auto-falls back to the Pallas interpreter off-TPU (see
``repro.kernels.common.resolve_interpret``); tests pin ``interpret=True``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=(
    'causal', 'scale', 'block_q', 'block_k', 'interpret'))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    # (B, S, H, D) → (B·H, S, D), heads batch-major so bh // group aligns
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    of = flash_attention_bhsd(qf, kf, vf, causal=causal, scale=scale,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return of.reshape(b, hq, sq, d).transpose(0, 2, 1, 3)
