"""Flash-attention Pallas TPU kernel (prefill/train hot spot).

Grid ``(B·Hq, n_q_blocks, n_kv_blocks)`` — the kv axis is innermost and
sequential ('arbitrary'); online-softmax running state (m, l, acc) lives in
VMEM scratch and is carried across kv steps, so scores never materialize in
HBM (the dominant traffic term the dry-run finds on the XLA oracle path).

GQA is handled in the K/V BlockSpec index maps (``h // group``) — no KV
head replication is materialized.  Causal blocks above the diagonal are
masked in-kernel; with a Mosaic grid the skipped blocks cost ~nothing on the
MXU because every lane is masked (a fully-skipped variant would use
``pl.when`` on the block index).

Block sizes default to (128, 128): q/k/v tiles of 128×Dh bf16 keep the
working set ≤ ~200 KB in VMEM at Dh=128 and align to the 128-lane MXU.
Shared machinery (online softmax, masking, padding, compiler-params
construction) comes from :mod:`repro.kernels.common` — this file contains
only the flash-specific grid/BlockSpec layout.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as kc


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, scale: float, block_q: int, block_k: int,
                  kv_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        kc.online_softmax_init(m_ref, l_ref, acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (Bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (Bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (Bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = kc.block_positions(iq, block_q, s.shape, 0)
    k_pos = kc.block_positions(ik, block_k, s.shape, 1)
    s = kc.mask_block_scores(s, q_pos=q_pos, k_pos=k_pos, causal=causal,
                             kv_len=kv_len)

    m_ref[...], l_ref[...], acc_ref[...] = kc.online_softmax_update(
        s, v, m_ref[...], l_ref[...], acc_ref[...])

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0] = kc.online_softmax_finalize(
            acc_ref[...], l_ref[...]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         scale: Optional[float] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: Optional[bool] = None):
    """q: (BHq, Sq, D); k/v: (BHkv, Skv, D); BHq = BHkv · group.

    Heads are flattened batch-major (b·H + h) so the kv index map recovers
    (b, h // group) arithmetically.
    """
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    assert bhq % bhkv == 0, (bhq, bhkv)
    group = bhq // bhkv  # (b·H + h) // g == b·Hkv + h // g since g | H
    scale = d ** -0.5 if scale is None else scale
    interpret = kc.resolve_interpret(interpret)

    q = kc.pad_axis_to(q, 1, block_q)
    k = kc.pad_axis_to(k, 1, block_k)
    v = kc.pad_axis_to(v, 1, block_k)
    sq_pad, skv_pad = q.shape[1], k.shape[1]

    grid = (bhq, sq_pad // block_q, skv_pad // block_k)

    kernel = functools.partial(
        _flash_kernel, causal=causal, scale=scale,
        block_q=block_q, block_k=block_k, kv_len=skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, iq, ik, g=group: (h // g, ik, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, iq, ik, g=group: (h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # m
            pltpu.VMEM((block_q,), jnp.float32),        # l
            pltpu.VMEM((block_q, d), jnp.float32),      # acc
        ],
        compiler_params=kc.compiler_params(
            dimension_semantics=('parallel', 'parallel', 'arbitrary')),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
