"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.models import common as cm


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: Optional[float] = None):
    """q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D) → (B, Sq, Hq, D)."""
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32), (b, sq))
    kv_pos = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (b, skv))
    return cm.attention(q, k, v, q_positions=q_pos, kv_positions=kv_pos,
                        causal=causal, scale=scale)
