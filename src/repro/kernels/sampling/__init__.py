from repro.kernels.sampling.ops import fused_unembed_sample  # noqa: F401
