"""jnp reference for the fused unembed+sample kernel.

Two jobs:

- **Parity oracle**: same math, same counter-hash Gumbel noise
  (``kernels.common.gumbel_hash_noise``), so kernel-vs-ref token parity is
  bit-identical — no tolerance window hiding an off-by-one in the argmax
  tie-break.
- **Off-TPU fast path**: on CPU/GPU the engine dispatches here instead of
  running the kernel under the Pallas interpreter (same policy as the
  paged decode kernel — the interpreter would only slow non-TPU runs
  down).  The greedy branch is deliberately the *exact* computation the
  unfused engine path performs (native-dtype matmul, ``jnp.argmax``), which
  is what makes the fused/unfused drain bit-identity test meaningful.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common as kc


def unembed_sample_ref(last, unembed, seed=0, *, temperature: float = 0.0):
    """last: (B, D); unembed: (D, V); returns (B,) int32 sampled tokens."""
    logits = last @ unembed                     # native dtype, as the
    if temperature > 0.0:                       # unfused engine path does
        b, v = logits.shape
        row = jax.lax.broadcasted_iota(jnp.int32, (b, v), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (b, v), 1)
        seed = jnp.asarray(seed, jnp.int32).reshape(-1)[0]
        logits = (logits.astype(jnp.float32) / temperature
                  + kc.gumbel_hash_noise(seed, row, col))
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
