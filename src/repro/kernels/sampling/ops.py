"""Dispatch wrapper for the fused unembed+sample tail.

``backend=None`` auto-selects: the Pallas kernel on TPU, the jnp reference
everywhere else (bit-identical math; the interpreter would only slow
CPU/GPU runs down — same policy as ``EngineConfig.decode_kernel``).  Tests
pin ``backend='pallas', interpret=True`` to exercise the real kernel under
the interpreter.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.sampling.kernel import unembed_sample_pallas
from repro.kernels.sampling.ref import unembed_sample_ref


def fused_unembed_sample(last, unembed, seed=0, *, temperature: float = 0.0,
                         block_v: Optional[int] = None,
                         backend: Optional[str] = None,
                         interpret: Optional[bool] = None):
    """Sample one token per row from ``softmax(last @ unembed / T)``.

    last: (B, D) final-norm hidden state; unembed: (D, V); seed: int or
    int32 array (ignored at temperature 0).  Returns (B,) int32 tokens.
    Greedy (T=0) is bit-identical to ``argmax(last @ unembed)``; T>0 is an
    exact categorical sample via the Gumbel-max trick with counter-hash
    noise, reproducible across backends.
    """
    if backend is None:
        backend = 'pallas' if jax.default_backend() == 'tpu' else 'ref'
    if backend == 'ref':
        return unembed_sample_ref(last, unembed, seed,
                                  temperature=temperature)
    assert backend == 'pallas', backend
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(-1)[:1]
    return unembed_sample_pallas(last, unembed, seed_arr,
                                 temperature=temperature, block_v=block_v,
                                 interpret=interpret)
