"""Fused unembed + sampling Pallas TPU kernel.

The decode tail the engine's unfused path runs is

    logits = last_hidden @ unembed        # (B, V) to HBM
    token  = argmax(logits)               # separate dispatch (+ host sync)

At production vocab sizes the (B, V) logits tensor is the largest
intermediate of the whole decode step and exists only to be argmax'd.
This kernel tiles the unembed matmul over the vocab axis and carries the
logits→token argmax *reduction* across tiles in VMEM scratch, so logits
never round-trip to HBM: each grid step computes one (B, block_v) score
tile and folds it into a running (best value, best index) pair per row;
the final tile's flush phase writes the (B,) sampled tokens.

Greedy is a plain argmax.  Temperature sampling rides the same reduction
via the Gumbel-max trick (``kernels.common.gumbel_hash_noise``): perturbing
``logits / T`` with counter-hashed Gumbel noise turns exact categorical
sampling into an argmax, which is what makes sampling *fusable* — there is
no normalizer to materialize.

Tie-breaking matches ``jnp.argmax`` bit-for-bit: within a tile the argmax
takes the first occurrence; across tiles a strict ``>`` keeps the earlier
tile's winner, so the composition is the global first-occurrence argmax.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as kc


def _sample_kernel(seed_ref, last_ref, w_ref, o_ref, best_val_ref,
                   best_idx_ref, *, block_v: int, vocab: int,
                   temperature: float):
    iv = pl.program_id(0)
    nv = pl.num_programs(0)

    @pl.when(iv == 0)
    def _init():
        best_val_ref[...] = jnp.full_like(best_val_ref, kc.NEG_INF)
        best_idx_ref[...] = jnp.zeros_like(best_idx_ref)

    last = last_ref[...].astype(jnp.float32)          # (B, D)
    w = w_ref[...].astype(jnp.float32)                # (D, block_v)
    s = jax.lax.dot_general(last, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    col = kc.block_positions(iv, block_v, s.shape, 1)  # global vocab ids
    if temperature > 0.0:
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        s = s / temperature + kc.gumbel_hash_noise(seed_ref[0], row, col)
    # vocab padding tiles (and the ragged last tile) must never win
    s = jnp.where(col < vocab, s, kc.NEG_INF)

    tile_max = jnp.max(s, axis=1)
    tile_arg = jnp.argmax(s, axis=1).astype(jnp.int32) + iv * block_v
    better = tile_max > best_val_ref[...]   # strict: first occurrence wins
    best_idx_ref[...] = jnp.where(better, tile_arg, best_idx_ref[...])
    best_val_ref[...] = jnp.where(better, tile_max, best_val_ref[...])

    @pl.when(iv == nv - 1)
    def _flush():
        o_ref[...] = best_idx_ref[...][:, None]


@functools.partial(jax.jit,
                   static_argnames=('temperature', 'block_v', 'interpret'))
def unembed_sample_pallas(last, unembed, seed, *, temperature: float = 0.0,
                          block_v: Optional[int] = None,
                          interpret: Optional[bool] = None):
    """last: (B, D) final-norm hidden; unembed: (D, V); seed: (1,) int32.

    Returns (B,) int32 sampled tokens.  ``temperature`` is static (the
    engine config pins it); the seed is a traced array so per-step reseeds
    never recompile.
    """
    b, d = last.shape
    v = unembed.shape[1]
    bv = block_v or kc.pick_block(v, 1024, align=kc.LANES)
    wp = kc.pad_axis_to(unembed, 1, bv)
    nv = wp.shape[1] // bv
    interpret = kc.resolve_interpret(interpret)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nv,),
        in_specs=[
            pl.BlockSpec((b, d), lambda iv, sd: (0, 0)),
            pl.BlockSpec((d, bv), lambda iv, sd: (0, iv)),
        ],
        out_specs=pl.BlockSpec((b, 1), lambda iv, sd: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((b,), jnp.float32),
            pltpu.VMEM((b,), jnp.int32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_sample_kernel, block_v=bv, vocab=v,
                          temperature=float(temperature)),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        compiler_params=kc.compiler_params(
            dimension_semantics=('arbitrary',)),
        interpret=interpret,
    )(jnp.asarray(seed, jnp.int32), last, wp)
    return out[:, 0]
