"""Chunked WKV6 (RWKV-6 'Finch') linear-attention Pallas TPU kernel.

The recurrence  S_t = diag(w_t)·S_{t-1} + k_tᵀ v_t,  y_t = r_t·(S_{t-1} +
diag(u)·k_tᵀv_t)  is evaluated chunk-parallel: within a chunk of c tokens
everything is (c×K)·(K×c) MXU matmuls against cumulative-decay-weighted
r/k; the (K, V) state carries across chunks in VMEM scratch.  This is the
TPU-native adaptation of the CUDA wkv kernels: instead of one thread per
(b, h) scanning tokens serially, the chunk dimension feeds the 128×128 MXU
and only the O(T/c) chunk boundary is sequential.

Grid ``(B, H, n_chunks)`` — chunks innermost/sequential ('arbitrary');
state scratch (K, V) f32.  Padding tokens must carry w=1, k=0, r=0 (decay
no-op, no state contribution) — the wrapper guarantees this.  Padding and
compiler-params construction go through :mod:`repro.kernels.common` (wkv6
has no softmax, so the online-softmax helpers don't apply here).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as kc


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sout_ref, state_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)            # (c, K)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (c, V)
    w = w_ref[0, :, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                  # (K,)

    logw = jnp.log(jnp.maximum(w, 1e-30))
    logA = jnp.cumsum(logw, axis=0)                   # inclusive (c, K)
    a_end = jnp.exp(logA[-1])                         # (K,)
    r_dec = r * jnp.exp(logA - logw)                  # r_t ∘ A_{t-1} (≤ A_0)
    k_end = k * jnp.exp(logA[-1:] - logA)             # (A_T/A_i) ∘ k_i (≤ 1)
    # intra-chunk scores in midpoint-normalized decay space: the factored
    # form r·A_{t-1} × k/A_s overflows f32 when the in-chunk decay range
    # exceeds ~85 nats; normalizing both sides by A_{mid} bounds each factor
    # by exp(range/2) while every causal product stays ≤ 1
    mid = logA[chunk // 2]
    r_dec_m = r * jnp.exp(logA - logw - mid[None, :])
    k_inc_m = k * jnp.exp(mid[None, :] - logA)

    dot = functools.partial(jax.lax.dot_general,
                            preferred_element_type=jnp.float32)
    scores = dot(r_dec_m, k_inc_m, (((1,), (1,)), ((), ())))  # (c, c)
    ti = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    si = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(ti > si, scores, 0.0)                  # strictly causal
    y = dot(scores, v, (((1,), (0,)), ((), ())))              # intra
    y += jnp.sum(r * (u[None, :] * k), axis=1, keepdims=True) * v   # diag
    state = state_ref[...]
    y += dot(r_dec, state, (((1,), (0,)), ((), ())))          # inter

    state_ref[...] = (a_end[:, None] * state
                      + dot(k_end, v, (((0,), (0,)), ((), ()))))
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _flush():
        sout_ref[0, 0] = state_ref[...].astype(sout_ref.dtype)


def wkv6_bthk(r, k, v, w, u, state, *, chunk: int = 64,
              interpret: Optional[bool] = None):
    """r/k/v/w: (B, T, H, K); u: (H, K); state: (B, H, K, V) f32.

    Returns (y (B, T, H, V), state_out (B, H, K, V)).
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    interpret = kc.resolve_interpret(interpret)
    t_pad = kc.round_up(t, chunk)
    if t_pad != t:
        r = kc.pad_axis_to(r, 1, chunk)
        k = kc.pad_axis_to(k, 1, chunk)
        v = kc.pad_axis_to(v, 1, chunk)
        w = kc.pad_axis_to(w, 1, chunk, value=1.0)    # decay no-op

    grid = (b, h, t_pad // chunk)
    io_spec = lambda: pl.BlockSpec((1, chunk, 1, dk),
                                   lambda ib, ih, ic: (ib, ic, ih, 0))
    y, sout = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            io_spec(), io_spec(),
            pl.BlockSpec((1, chunk, 1, dv), lambda ib, ih, ic: (ib, ic, ih, 0)),
            io_spec(),
            pl.BlockSpec((1, dk), lambda ib, ih, ic: (ih, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, 1, dv), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda ib, ih, ic: (ib, ih, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, t_pad, h, dv), r.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=kc.compiler_params(
            dimension_semantics=('parallel', 'parallel', 'arbitrary')),
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y[:, :t], sout
