"""Pure-jnp oracles for WKV6: the sequential recurrence and the chunked
form (both from the model definition — the kernel must match them)."""
from repro.models.rwkv6 import wkv6_chunked, wkv6_ref  # noqa: F401
