"""jit'd wrapper for the WKV6 kernel (model layout passthrough)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_bthk


@functools.partial(jax.jit, static_argnames=('chunk', 'interpret'))
def wkv6(r, k, v, w, u, state, *, chunk: int = 64,
         interpret: Optional[bool] = None):
    """r/k/v/w: (B, T, H, K); u: (H, K); state: (B, H, K, V) f32.

    Matches models.rwkv6.wkv6_ref / wkv6_chunked.
    """
    return wkv6_bthk(r, k, v, w, u, state.astype(jnp.float32),
                     chunk=chunk, interpret=interpret)
