"""Shared-prefix page-run structure for prefix-aware paged attention.

The memory plane's copy-on-write prefix sharing (PR 5) makes several
requests' page tables point at the *same* physical pages for their common
prompt prefix.  The stock paged kernel still streams each physical page
once per request; with B requests sharing a K-page prefix that is B×K page
reads for K pages of data.  The prefix-aware variant splits attention into
two online-softmax phases — a batch-wide pass over the deduplicated shared
pages (each physical page read once), then a per-request pass over the
remaining tail — and merges them through the associativity of the running
(m, l, acc) state.

:func:`build_shared_runs` is the host-side (numpy) builder that turns a
decode batch's page tables into the fixed-shape kernel inputs.  It works
*only* from the page tables the batch already holds: a slot is emitted only
for a physical page that appears at the same logical index in ≥ 2 rows.
That closure property is the kernel-boundary form of the plane's sharing
invariant — a page lands in two tables only via publication (fill-gated),
so the kernel can never be steered into another session's unpublished
lease.  ``tests`` pin this.

:func:`prefix_shared_ref` is the jnp reference: numerically-stable joint
softmax over the concatenated shared+tail score blocks.  It is both the
parity oracle for the Pallas two-phase kernel and the off-TPU fast path —
the shared K/V gather is (S·pg) once per batch instead of (B·maxp·pg), so
the dedup win is real on CPU/GPU too.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import common as kc

QUARANTINE_PAGE = 0


def build_shared_runs(page_tables, lengths, page_size: int, *,
                      quarantine: int = QUARANTINE_PAGE,
                      max_slots: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Deduplicate shared leading page runs across a decode batch.

    page_tables: (B, maxp) physical page ids (``quarantine`` = padding);
    lengths: (B,) valid KV tokens per row (``positions + 1``).  Returns a
    dict of fixed-shape numpy arrays (``max_slots`` defaults to maxp, so
    the downstream dispatch compiles once):

    - ``pages`` (S,): deduped physical ids of shared pages (padding →
      quarantine, masked out everywhere);
    - ``pos`` (S,): the logical page index each slot sits at (a physical
      page has exactly one logical index — chain-keyed CoW);
    - ``mask`` (B, S) f32 0/1: row b attends shared slot s;
    - ``tail_pt`` (B, maxp): each row's page table with its shared run
      removed (shifted left, quarantine-padded);
    - ``start`` (B,): pages removed per row (= tail position offset);
    - ``n_slots`` int: live slots (0 → nothing shared, use the stock path).

    Only *fully-filled* pages dedup (``(j+1)·pg ≤ length`` for every
    participant) and a row's run must be a leading prefix — both hold by
    construction for CoW-attached prefixes, and are re-enforced here so a
    hand-built table cannot produce an unsound slot.
    """
    pts = np.asarray(page_tables)
    lengths = np.asarray(lengths)
    b, maxp = pts.shape
    s_cap = maxp if max_slots is None else max_slots
    n_full = lengths // page_size        # fully-filled pages per row

    # candidate: page occupied, fully filled, and shared with another row
    # that ALSO holds it fully filled at the same logical index (vectorized
    # pairwise equality per column — this builder runs on the per-step
    # decode hot path)
    valid = (pts != quarantine) & (np.arange(maxp)[None, :] < n_full[:, None])
    eq = pts[None, :, :] == pts[:, None, :]
    dup = (eq & valid[None, :, :]).sum(axis=1) >= 2
    cand = dup & valid

    # a row's shared run is its leading candidate streak
    n_share = np.where(cand.all(axis=1), maxp,
                       np.argmin(cand, axis=1)).astype(np.int32)

    # collect slots in logical-index order; on slot-budget overflow clamp
    # every run at the first index that no longer fits (rare: many distinct
    # share groups) — correctness is unaffected, those pages stay in tails
    slot_of: Dict[tuple, int] = {}
    for j in range(int(n_share.max()) if b else 0):
        new = []
        for i in range(b):
            key = (j, pts[i, j])
            if j < n_share[i] and key not in slot_of:
                slot_of[key] = len(slot_of)
                new.append(key)
        if len(slot_of) > s_cap:
            for key in new:
                del slot_of[key]
            n_share = np.minimum(n_share, j)
            break

    pages = np.full(s_cap, quarantine, np.int32)
    pos = np.zeros(s_cap, np.int32)
    mask = np.zeros((b, s_cap), np.float32)
    for (j, p), si in slot_of.items():
        pages[si], pos[si] = p, j
    for i in range(b):
        for j in range(int(n_share[i])):
            mask[i, slot_of[(j, pts[i, j])]] = 1.0

    tail_pt = np.full_like(pts, quarantine)
    for i in range(b):
        ns = int(n_share[i])
        tail_pt[i, :maxp - ns] = pts[i, ns:]

    return {'pages': pages, 'pos': pos, 'mask': mask, 'tail_pt': tail_pt,
            'start': n_share.astype(np.int32), 'n_slots': len(slot_of)}


def prefix_shared_ref(q, pool_k, pool_v, shared_pages, share_pos, share_mask,
                      tail_pt, start_pages, lengths, *,
                      scale: Optional[float] = None):
    """Reference prefix-aware paged attention (joint softmax over the
    concatenated shared-run + tail score blocks).

    q: (B, Hq, D); pools: (P, pg, Hkv, D); the remaining args are the
    :func:`build_shared_runs` outputs plus lengths (B,).  Matches
    ``models.common.paged_attention_ref(q, pools, page_table, lengths)`` on
    the original (undeduplicated) page tables.
    """
    b, hq, d = q.shape
    pg, hkv = pool_k.shape[1], pool_k.shape[2]
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32)

    # shared phase: ONE gather of the deduped pages for the whole batch
    ks = pool_k[shared_pages].astype(jnp.float32)      # (S, pg, Hkv, D)
    vs = pool_v[shared_pages].astype(jnp.float32)
    s_sh = jnp.einsum('bkgd,spkd->bkgsp', qf, ks) * scale
    sh_ok = share_mask[:, None, None, :, None] > 0
    s_sh = jnp.where(sh_ok, s_sh, kc.NEG_INF)
    s_sh = s_sh.reshape(b, hkv, g, -1)

    # tail phase: per-request gather over the shifted tables
    kt = pool_k[tail_pt].astype(jnp.float32)           # (B, T, pg, Hkv, D)
    vt = pool_v[tail_pt].astype(jnp.float32)
    t = tail_pt.shape[1]
    s_tl = jnp.einsum('bkgd,btpkd->bkgtp', qf, kt) * scale
    tpos = ((start_pages[:, None] + jnp.arange(t))[:, :, None] * pg
            + jnp.arange(pg)[None, None, :])           # (B, T, pg)
    tl_ok = tpos < lengths[:, None, None]
    s_tl = jnp.where(tl_ok[:, None, None], s_tl, kc.NEG_INF)
    s_tl = s_tl.reshape(b, hkv, g, -1)

    p = jax.nn.softmax(jnp.concatenate([s_sh, s_tl], axis=-1), axis=-1)
    ns = s_sh.shape[-1]
    p_sh = p[..., :ns].reshape(b, hkv, g, -1, pg)
    p_tl = p[..., ns:].reshape(b, hkv, g, t, pg)
    out = (jnp.einsum('bkgsp,spkd->bkgd', p_sh, vs)
           + jnp.einsum('bkgtp,btpkd->bkgd', p_tl, vt))
    return out.reshape(b, hq, d).astype(q.dtype)
