"""jit'd wrapper: model layout (B, Hq, D) ↔ kernel layout (B, Hkv, G, D)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_bhgd


@functools.partial(jax.jit, static_argnames=('scale', 'interpret'))
def paged_attention(q, pool_k, pool_v, page_table, lengths, *,
                    scale: Optional[float] = None, interpret: bool = False):
    """Decode attention through the page table.

    q: (B, Hq, D); pools: (P, pg, Hkv, D); page_table: (B, maxp);
    lengths: (B,) valid tokens per request.  Matches
    models.common.paged_attention_ref.
    """
    b, hq, d = q.shape
    hkv = pool_k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    out = paged_attention_bhgd(qg, pool_k, pool_v, page_table,
                               lengths.astype(jnp.int32), scale=scale,
                               interpret=interpret)
    return out.reshape(b, hq, d)
