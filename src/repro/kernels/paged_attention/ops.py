"""jit'd wrapper: model layout (B, Hq, D) ↔ kernel layout (B, Hkv, G, D).

Also home of :func:`paged_attention_decode`, the decode-specialized entry
point the serving engine's hot path dispatches through (one new token per
request; see ``serving/engine.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common as kc
from repro.kernels.paged_attention.kernel import (
    paged_attention_bhgd, paged_attention_prefix_shared_bhgd)


@functools.partial(jax.jit, static_argnames=('scale', 'interpret'))
def paged_attention(q, pool_k, pool_v, page_table, lengths, *,
                    scale: Optional[float] = None,
                    interpret: Optional[bool] = None):
    """Decode attention through the page table.

    q: (B, Hq, D); pools: (P, pg, Hkv, D); page_table: (B, maxp);
    lengths: (B,) valid tokens per request.  Matches
    models.common.paged_attention_ref.
    """
    b, hq, d = q.shape
    hkv = pool_k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    out = paged_attention_bhgd(qg, pool_k, pool_v, page_table,
                               lengths.astype(jnp.int32), scale=scale,
                               interpret=interpret)
    return out.reshape(b, hq, d)


def paged_attention_decode(q, pool_k, pool_v, page_table, lengths, *,
                           scale: Optional[float] = None,
                           interpret: Optional[bool] = None):
    """Single-token decode attention — the serving hot path.

    Unlike the oracle (``models.common.paged_attention_ref``), which gathers
    the request's FULL ``(B, maxp·pg, Hkv, Dh)`` KV out of the pool and runs
    dense attention over it every iteration, this streams pages HBM→VMEM
    through the page table inside the Pallas kernel: the decode step never
    materializes full-sequence attention shapes, and traffic is bounded by
    the pages a request actually owns rather than by ``max_seq``.

    Layout dispatch: the global 4-D pool ``(P, pg, Hkv, Dh)`` — the engine
    layout Valve's quarantine remap operates on — takes the kernel; the
    region 5-D layout ``(B, R, pg, Hkv, Dh)`` is already a batch-aligned
    ``take_along_axis`` under SPMD and keeps the reference path (the kernel's
    scalar-prefetch page indirection is not SPMD-partitionable).

    q: (B, Hq, Dh); lengths: (B,) — context length *including* the token
    being decoded (the engine passes ``positions + 1``).
    """
    if pool_k.ndim == 5:
        from repro.models.common import paged_attention_ref
        return paged_attention_ref(q, pool_k, pool_v, page_table, lengths,
                                   scale=scale)
    return paged_attention(q, pool_k, pool_v, page_table, lengths,
                           scale=scale,
                           interpret=kc.resolve_interpret(interpret))


def paged_attention_prefix_shared(q, pool_k, pool_v, shared_pages, share_pos,
                                  share_mask, tail_pt, start_pages, lengths,
                                  *, scale: Optional[float] = None,
                                  backend: Optional[str] = None,
                                  interpret: Optional[bool] = None):
    """Prefix-shared-aware decode attention.

    When the memory plane's copy-on-write sharing points several requests at
    the same physical prefix pages, the stock kernel still reads each page
    once *per request*.  This variant takes the deduplicated shared-run
    structure (``prefix.build_shared_runs``) and reads each shared physical
    page once *per batch*: a batch-wide shared-run pass (per-request
    participation masking — the quarantine-mask machinery applied to
    sharing) feeds its partial online-softmax state into the stock tail
    walk.  Output matches ``paged_attention_decode`` on the original
    undeduplicated tables.

    q: (B, Hq, D); pools: (P, pg, Hkv, D) — global paged layout only (the
    shared-run indirection is not SPMD-partitionable, like the stock
    kernel).  ``backend=None`` auto-selects the Pallas two-phase kernel on
    TPU and the jnp reference elsewhere (the reference performs the same
    dedup, so the bandwidth win is real off-TPU too).
    """
    assert pool_k.ndim == 4, 'prefix-shared attention needs the global pool'
    if backend is None:
        backend = 'pallas' if jax.default_backend() == 'tpu' else 'ref'
    from repro.kernels.paged_attention.prefix import prefix_shared_ref
    if backend == 'ref':
        return prefix_shared_ref(q, pool_k, pool_v, shared_pages, share_pos,
                                 share_mask, tail_pt, start_pages, lengths,
                                 scale=scale)
    assert backend == 'pallas', backend
    b, hq, d = q.shape
    hkv = pool_k.shape[2]
    qg = q.reshape(b, hkv, hq // hkv, d)
    out = paged_attention_prefix_shared_bhgd(
        qg, pool_k, pool_v, shared_pages.astype(jnp.int32),
        share_pos.astype(jnp.int32), share_mask.astype(jnp.float32),
        tail_pt.astype(jnp.int32), start_pages.astype(jnp.int32),
        lengths.astype(jnp.int32), scale=scale,
        interpret=kc.resolve_interpret(interpret))
    return out.reshape(b, hq, d)


def paged_attention_decode_sample(q, pool_k, pool_v, page_table, lengths,
                                  wo, final_norm, unembed, *,
                                  norm_eps: float = 1e-6,
                                  temperature: float = 0.0, seed=0,
                                  scale: Optional[float] = None,
                                  backend: Optional[str] = None,
                                  interpret: Optional[bool] = None):
    """Decode attention with the sampling tail fused in — the composed
    single-layer form of the engine's fused decode step.

    Runs :func:`paged_attention_decode`, applies the decode head (output
    projection ``wo`` (Hq·D, d_model), residual-free final RMS norm, then
    the fused unembed+argmax kernel), and returns (B,) int32 sampled
    tokens.  The (B, V) logits tensor never exists in HBM: the unembed
    matmul is tiled over vocab inside the sampling kernel and reduced to a
    running argmax in VMEM (``kernels.sampling``).

    The full model fuses the same tail after its layer scan
    (``models.dense.decode_step_sample``); this entry point is the
    kernel-level composition the parity suite pins against the reference
    ops, single attention layer end-to-end.
    """
    from repro.kernels.sampling.ops import fused_unembed_sample
    from repro.models.common import rms_norm
    out = paged_attention_decode(q, pool_k, pool_v, page_table, lengths,
                                 scale=scale, interpret=interpret)
    last = out.reshape(out.shape[0], -1) @ wo
    last = rms_norm(last, final_norm, norm_eps)
    return fused_unembed_sample(last, unembed, seed, temperature=temperature,
                                backend=backend, interpret=interpret)
