"""jit'd wrapper: model layout (B, Hq, D) ↔ kernel layout (B, Hkv, G, D).

Also home of :func:`paged_attention_decode`, the decode-specialized entry
point the serving engine's hot path dispatches through (one new token per
request; see ``serving/engine.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import common as kc
from repro.kernels.paged_attention.kernel import paged_attention_bhgd


@functools.partial(jax.jit, static_argnames=('scale', 'interpret'))
def paged_attention(q, pool_k, pool_v, page_table, lengths, *,
                    scale: Optional[float] = None,
                    interpret: Optional[bool] = None):
    """Decode attention through the page table.

    q: (B, Hq, D); pools: (P, pg, Hkv, D); page_table: (B, maxp);
    lengths: (B,) valid tokens per request.  Matches
    models.common.paged_attention_ref.
    """
    b, hq, d = q.shape
    hkv = pool_k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    out = paged_attention_bhgd(qg, pool_k, pool_v, page_table,
                               lengths.astype(jnp.int32), scale=scale,
                               interpret=interpret)
    return out.reshape(b, hq, d)


def paged_attention_decode(q, pool_k, pool_v, page_table, lengths, *,
                           scale: Optional[float] = None,
                           interpret: Optional[bool] = None):
    """Single-token decode attention — the serving hot path.

    Unlike the oracle (``models.common.paged_attention_ref``), which gathers
    the request's FULL ``(B, maxp·pg, Hkv, Dh)`` KV out of the pool and runs
    dense attention over it every iteration, this streams pages HBM→VMEM
    through the page table inside the Pallas kernel: the decode step never
    materializes full-sequence attention shapes, and traffic is bounded by
    the pages a request actually owns rather than by ``max_seq``.

    Layout dispatch: the global 4-D pool ``(P, pg, Hkv, Dh)`` — the engine
    layout Valve's quarantine remap operates on — takes the kernel; the
    region 5-D layout ``(B, R, pg, Hkv, Dh)`` is already a batch-aligned
    ``take_along_axis`` under SPMD and keeps the reference path (the kernel's
    scalar-prefetch page indirection is not SPMD-partitionable).

    q: (B, Hq, Dh); lengths: (B,) — context length *including* the token
    being decoded (the engine passes ``positions + 1``).
    """
    if pool_k.ndim == 5:
        from repro.models.common import paged_attention_ref
        return paged_attention_ref(q, pool_k, pool_v, page_table, lengths,
                                   scale=scale)
    return paged_attention(q, pool_k, pool_v, page_table, lengths,
                           scale=scale,
                           interpret=kc.resolve_interpret(interpret))
