"""Paged decode-attention Pallas TPU kernel.

One new token per request attends over its KV cache *through the page
table* — the indirection Valve's quarantine remap rewrites.  The page table
and per-request lengths ride in scalar-prefetch SMEM
(PrefetchScalarGridSpec), and the K/V BlockSpec index maps dereference
``page_table[b, ip]`` to pick the physical page, so the gather never
materializes in HBM: pages stream HBM→VMEM one (page_size × Dh) tile at a
time while the online-softmax state sits in VMEM scratch.

Grid ``(B, Hkv, n_pages)``; pages is innermost/sequential.  Tokens past a
request's length are masked in-kernel; a quarantined page (id 0) streams
garbage that is either masked (healthy request) or discarded by Valve's
invalidation-recompute contract — never a fault, by construction.

GQA: q for one (b, kv-head) is the (group, Dh) block of query heads; with
group ≤ 8 and Dh = 128 the q tile is one MXU pass per page.  Shared
machinery (online softmax, length masking, compiler-params construction)
comes from :mod:`repro.kernels.common`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as kc


def _paged_kernel(page_table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page_size: int, scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        kc.online_softmax_init(m_ref, l_ref, acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (pg, D)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (pg, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = kc.block_positions(ip, page_size, s.shape, 1)
    s = kc.mask_block_scores(s, k_pos=pos, kv_len=lengths_ref[b])

    m_ref[...], l_ref[...], acc_ref[...] = kc.online_softmax_update(
        s, v, m_ref[...], l_ref[...], acc_ref[...])

    @pl.when(ip == np_ - 1)
    def _flush():
        o_ref[0, 0] = kc.online_softmax_finalize(
            acc_ref[...], l_ref[...]).astype(o_ref.dtype)


def paged_attention_bhgd(q, pool_k, pool_v, page_table, lengths, *,
                         scale: Optional[float] = None,
                         interpret: Optional[bool] = None):
    """q: (B, Hkv, G, D); pools: (P, pg, Hkv, D) — global paged layout;
    page_table: (B, maxp) physical ids (0 = quarantine); lengths: (B,)."""
    b, hkv, g, d = q.shape
    p_total, pg, _, _ = pool_k.shape
    maxp = page_table.shape[1]
    scale = d ** -0.5 if scale is None else scale
    interpret = kc.resolve_interpret(interpret)

    grid = (b, hkv, maxp)
    kernel = functools.partial(_paged_kernel, page_size=pg, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ib, ih, ip, pt, ln: (ib, ih, 0, 0)),
            # the page-table dereference: physical page for (request, step)
            pl.BlockSpec((1, pg, 1, d),
                         lambda ib, ih, ip, pt, ln: (pt[ib, ip], 0, ih, 0)),
            pl.BlockSpec((1, pg, 1, d),
                         lambda ib, ih, ip, pt, ln: (pt[ib, ip], 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, ip, pt, ln: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=kc.compiler_params(
            dimension_semantics=('parallel', 'parallel', 'arbitrary')),
        interpret=interpret,
    )(page_table, lengths, q, pool_k, pool_v)
    return out


# ---------------------------------------------------------------------------
# Prefix-shared-aware variant: two online-softmax phases merged through the
# associativity of the running (m, l, acc) state.  Phase 1 streams each
# DEDUPED shared physical page once and scores it against the whole batch's
# queries (per-row participation mask); phase 2 is the stock per-request
# page walk over the tails, seeded from phase 1's partial state instead of
# the (−inf, 0, 0) init.  Inputs come from
# :func:`repro.kernels.paged_attention.prefix.build_shared_runs`.
# ---------------------------------------------------------------------------

def _shared_run_kernel(shared_pages_ref, share_pos_ref, q_ref, k_ref, v_ref,
                       mask_ref, m_out_ref, l_out_ref, acc_out_ref,
                       m_ref, l_ref, acc_ref, *, page_size: int,
                       scale: float):
    js = pl.program_id(1)
    ns = pl.num_programs(1)

    @pl.when(js == 0)
    def _init():
        kc.online_softmax_init(m_ref, l_ref, acc_ref)

    q = q_ref[:, 0].astype(jnp.float32)               # (B, G, D)
    b, g, d = q.shape
    k = k_ref[0, :, 0].astype(jnp.float32)            # (pg, D)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q.reshape(b * g, d), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # participation mask: rows not sharing this slot (and quarantine
    # padding slots) score NEG_INF.  A row masked at every slot so far
    # carries garbage mass at m = NEG_INF; the first finite score — here
    # or in the tail phase — rescales it away (alpha = exp(-inf) = 0), so
    # no explicit reset is needed.  Shared pages are fully filled by the
    # publication contract, so no kv_len mask applies in this phase.
    ok = jnp.repeat(mask_ref[:, 0] > 0, g)            # (B*G,)
    s = jnp.where(ok[:, None], s, kc.NEG_INF)

    m_ref[...], l_ref[...], acc_ref[...] = kc.online_softmax_update(
        s, v, m_ref[...], l_ref[...], acc_ref[...])

    @pl.when(js == ns - 1)
    def _flush():
        m_out_ref[:, 0] = m_ref[...].reshape(b, g)
        l_out_ref[:, 0] = l_ref[...].reshape(b, g)
        acc_out_ref[:, 0] = acc_ref[...].reshape(b, g, d)


def _tail_kernel(tail_pt_ref, start_ref, lengths_ref, q_ref, k_ref, v_ref,
                 m0_ref, l0_ref, acc0_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 page_size: int, scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        # resume the online softmax from the shared-run partial state
        m_ref[...] = m0_ref[0, 0]
        l_ref[...] = l0_ref[0, 0]
        acc_ref[...] = acc0_ref[0, 0]

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (pg, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # tail pages sit AFTER the row's shared run: shift by start_pages
    pos = kc.block_positions(start_ref[b] + ip, page_size, s.shape, 1)
    s = kc.mask_block_scores(s, k_pos=pos, kv_len=lengths_ref[b])

    m_ref[...], l_ref[...], acc_ref[...] = kc.online_softmax_update(
        s, v, m_ref[...], l_ref[...], acc_ref[...])

    @pl.when(ip == np_ - 1)
    def _flush():
        o_ref[0, 0] = kc.online_softmax_finalize(
            acc_ref[...], l_ref[...]).astype(o_ref.dtype)


def paged_attention_prefix_shared_bhgd(q, pool_k, pool_v, shared_pages,
                                       share_pos, share_mask, tail_pt,
                                       start_pages, lengths, *,
                                       scale: Optional[float] = None,
                                       interpret: Optional[bool] = None):
    """q: (B, Hkv, G, D); pools: (P, pg, Hkv, D); shared_pages/share_pos:
    (S,); share_mask: (B, S) f32; tail_pt: (B, maxp); start_pages,
    lengths: (B,).  See ``prefix.build_shared_runs`` for the structure."""
    b, hkv, g, d = q.shape
    pg = pool_k.shape[1]
    n_slots = shared_pages.shape[0]
    maxp = tail_pt.shape[1]
    scale = d ** -0.5 if scale is None else scale
    interpret = kc.resolve_interpret(interpret)

    # phase 1: grid (Hkv, S) — each shared physical page streams HBM→VMEM
    # exactly once per kv-head for the WHOLE batch
    shared_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(hkv, n_slots),
        in_specs=[
            pl.BlockSpec((b, 1, g, d), lambda ih, js, sp, spos: (0, ih, 0, 0)),
            pl.BlockSpec((1, pg, 1, d),
                         lambda ih, js, sp, spos: (sp[js], 0, ih, 0)),
            pl.BlockSpec((1, pg, 1, d),
                         lambda ih, js, sp, spos: (sp[js], 0, ih, 0)),
            pl.BlockSpec((b, 1), lambda ih, js, sp, spos: (0, js)),
        ],
        out_specs=[
            pl.BlockSpec((b, 1, g), lambda ih, js, sp, spos: (0, ih, 0)),
            pl.BlockSpec((b, 1, g), lambda ih, js, sp, spos: (0, ih, 0)),
            pl.BlockSpec((b, 1, g, d), lambda ih, js, sp, spos: (0, ih, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((b * g,), jnp.float32),
            pltpu.VMEM((b * g,), jnp.float32),
            pltpu.VMEM((b * g, d), jnp.float32),
        ],
    )
    m0, l0, acc0 = pl.pallas_call(
        functools.partial(_shared_run_kernel, page_size=pg, scale=scale),
        grid_spec=shared_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        ],
        compiler_params=kc.compiler_params(
            dimension_semantics=('parallel', 'arbitrary')),
        interpret=interpret,
    )(shared_pages, share_pos, q, pool_k, pool_v, share_mask)

    # phase 2: the stock per-request page walk over the tails, resuming
    # from phase 1's partial (m, l, acc)
    tail_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ib, ih, ip, pt, st, ln: (ib, ih, 0, 0)),
            pl.BlockSpec((1, pg, 1, d),
                         lambda ib, ih, ip, pt, st, ln: (pt[ib, ip], 0, ih, 0)),
            pl.BlockSpec((1, pg, 1, d),
                         lambda ib, ih, ip, pt, st, ln: (pt[ib, ip], 0, ih, 0)),
            pl.BlockSpec((1, 1, g),
                         lambda ib, ih, ip, pt, st, ln: (ib, ih, 0)),
            pl.BlockSpec((1, 1, g),
                         lambda ib, ih, ip, pt, st, ln: (ib, ih, 0)),
            pl.BlockSpec((1, 1, g, d),
                         lambda ib, ih, ip, pt, st, ln: (ib, ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, ip, pt, st, ln: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_tail_kernel, page_size=pg, scale=scale),
        grid_spec=tail_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=kc.compiler_params(
            dimension_semantics=('parallel', 'parallel', 'arbitrary')),
        interpret=interpret,
    )(tail_pt, start_pages, lengths, q, pool_k, pool_v, m0, l0, acc0)
    return out
