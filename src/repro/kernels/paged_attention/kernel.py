"""Paged decode-attention Pallas TPU kernel.

One new token per request attends over its KV cache *through the page
table* — the indirection Valve's quarantine remap rewrites.  The page table
and per-request lengths ride in scalar-prefetch SMEM
(PrefetchScalarGridSpec), and the K/V BlockSpec index maps dereference
``page_table[b, ip]`` to pick the physical page, so the gather never
materializes in HBM: pages stream HBM→VMEM one (page_size × Dh) tile at a
time while the online-softmax state sits in VMEM scratch.

Grid ``(B, Hkv, n_pages)``; pages is innermost/sequential.  Tokens past a
request's length are masked in-kernel; a quarantined page (id 0) streams
garbage that is either masked (healthy request) or discarded by Valve's
invalidation-recompute contract — never a fault, by construction.

GQA: q for one (b, kv-head) is the (group, Dh) block of query heads; with
group ≤ 8 and Dh = 128 the q tile is one MXU pass per page.  Shared
machinery (online softmax, length masking, compiler-params construction)
comes from :mod:`repro.kernels.common`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common as kc


def _paged_kernel(page_table_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, page_size: int, scale: float):
    b = pl.program_id(0)
    ip = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(ip == 0)
    def _init():
        kc.online_softmax_init(m_ref, l_ref, acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (pg, D)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (pg, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = kc.block_positions(ip, page_size, s.shape, 1)
    s = kc.mask_block_scores(s, k_pos=pos, kv_len=lengths_ref[b])

    m_ref[...], l_ref[...], acc_ref[...] = kc.online_softmax_update(
        s, v, m_ref[...], l_ref[...], acc_ref[...])

    @pl.when(ip == np_ - 1)
    def _flush():
        o_ref[0, 0] = kc.online_softmax_finalize(
            acc_ref[...], l_ref[...]).astype(o_ref.dtype)


def paged_attention_bhgd(q, pool_k, pool_v, page_table, lengths, *,
                         scale: Optional[float] = None,
                         interpret: Optional[bool] = None):
    """q: (B, Hkv, G, D); pools: (P, pg, Hkv, D) — global paged layout;
    page_table: (B, maxp) physical ids (0 = quarantine); lengths: (B,)."""
    b, hkv, g, d = q.shape
    p_total, pg, _, _ = pool_k.shape
    maxp = page_table.shape[1]
    scale = d ** -0.5 if scale is None else scale
    interpret = kc.resolve_interpret(interpret)

    grid = (b, hkv, maxp)
    kernel = functools.partial(_paged_kernel, page_size=pg, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda ib, ih, ip, pt, ln: (ib, ih, 0, 0)),
            # the page-table dereference: physical page for (request, step)
            pl.BlockSpec((1, pg, 1, d),
                         lambda ib, ih, ip, pt, ln: (pt[ib, ip], 0, ih, 0)),
            pl.BlockSpec((1, pg, 1, d),
                         lambda ib, ih, ip, pt, ln: (pt[ib, ip], 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, ip, pt, ln: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=kc.compiler_params(
            dimension_semantics=('parallel', 'parallel', 'arbitrary')),
        interpret=interpret,
    )(page_table, lengths, q, pool_k, pool_v)
    return out
