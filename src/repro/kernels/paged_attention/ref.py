"""Pure-jnp oracle: models.common.paged_attention_ref (the decode path the
models execute on CPU)."""
from repro.models.common import paged_attention_ref  # noqa: F401
