# Pallas kernel layer.  Every kernel package (flash_attention,
# paged_attention, rwkv6) is <name>/kernel.py + ops.py + ref.py; import the
# public entry points from the ops modules, e.g.
#
#     from repro.kernels.flash_attention.ops import flash_attention
#     from repro.kernels.paged_attention.ops import (paged_attention,
#                                                    paged_attention_decode)
#     from repro.kernels.rwkv6.ops import wkv6
#
# This __init__ re-exports ONLY the compat/toolkit shims: the ops modules are
# deliberately not imported here — non-kernel consumers of
# repro.kernels.common (e.g. distributed/sharding.py, on every model import
# path) must not pay the Pallas ops import cost, and the function names
# shadow their subpackage names, so package-level function re-exports are an
# import-order hazard.  Shared machinery and ALL version-sensitive JAX
# surface (compiler params, shard_map, interpret fallback) live in
# repro.kernels.common.
from repro.kernels.common import (  # noqa: F401
    compiler_params, cost_analysis_dict, resolve_interpret, shard_map)
