import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the sharding config is coherent (no mismatched
collectives, fits per-device HBM at compile time) and extracts the roofline
inputs:

- ``compiled.memory_analysis()``  → bytes per device (argument/output/temp);
- ``compiled.cost_analysis()``    → HLO FLOPs + bytes accessed (per device —
  the compiled module is the per-device SPMD program);
- ``compiled.as_text()`` parsed   → collective bytes per device by op kind.

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --sweep --out results/dryrun.jsonl
    python -m repro.launch.dryrun --sweep --subprocess   # one proc per cell

Single-cell runs print a JSON record to stdout (the sweep orchestrator and
benchmarks/roofline.py consume these).
"""
import argparse
import json
import re
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax

from repro.configs import ARCHS, SHAPES, cell_supported, get_config
from repro.launch import mesh as meshlib
from repro.models.api import build_model

# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def _sds_tree(shapes_tree):
    return shapes_tree  # already ShapeDtypeStructs


def opt_state_sds(param_shapes):
    import jax.numpy as jnp
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        'step': jax.ShapeDtypeStruct((), jnp.int32),
        'mu': jax.tree.map(f32, param_shapes),
        'nu': jax.tree.map(f32, param_shapes),
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, microbatches: int = 1, zero1: bool = True,
             rules_variant: str = 'default') -> Dict[str, Any]:
    from repro.distributed import sharding as shd
    from repro.training import optimizer as opt
    from repro.training import train_step as ts

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec: Dict[str, Any] = {
        'arch': arch, 'shape': shape_name, 'mesh': mesh_kind,
        'kind': shape.kind, 'microbatches': microbatches,
        'rules_variant': rules_variant,
    }
    if not ok:
        rec.update(status=why)
        return rec

    mesh = meshlib.make_production_mesh(multi_pod=(mesh_kind == 'multi'))
    model = build_model(cfg)
    rules = shd.RULE_VARIANTS.get(rules_variant)
    t0 = time.time()

    try:
        if shape.kind == 'train':
            step_builder, make_sh = ts.make_train_step(
                model, mesh, microbatches=microbatches, zero1=zero1,
                rules=rules)
            jitted = step_builder(shape)
            args = (model.param_shapes(),
                    opt_state_sds(model.param_shapes()),
                    model.input_specs(shape))
            lowered = jitted.lower(*args)
        else:
            jitted, _specs = ts.make_serve_step(model, mesh, shape,
                                                rules=rules)
            args = (model.param_shapes(), model.cache_shapes(shape),
                    model.input_specs(shape))
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failure here is a bug in our sharding
        rec.update(status='FAILED', error=f'{type(e).__name__}: {e}')
        return rec

    from repro.kernels.common import cost_analysis_dict
    from repro.launch import hlo_analysis as ha
    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    costs = ha.analyze(compiled.as_text())

    n_chips = meshlib.chips(mesh)
    # trip-count-corrected per-device figures (cost_analysis counts while
    # bodies once — see hlo_analysis docstring); raw values kept for reference
    flops_dev = costs.flops
    bytes_dev = costs.traffic_bytes
    coll = {'bytes_by_kind': costs.coll_payload,
            'wire_bytes': costs.coll_wire,
            'n_collectives': costs.coll_count}
    hbm_bytes = {
        'argument': int(mem.argument_size_in_bytes),
        'output': int(mem.output_size_in_bytes),
        'temp': int(mem.temp_size_in_bytes),
        'alias': int(mem.alias_size_in_bytes),
        'peak': int(mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
    }

    # roofline terms (seconds) — per device
    t_comp = flops_dev / meshlib.PEAK_FLOPS_BF16
    t_mem = bytes_dev / meshlib.HBM_BW
    t_coll = coll['wire_bytes'] / meshlib.ICI_BW

    # useful-FLOPs ratio
    if shape.kind == 'train':
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * cfg.active_param_count() * tokens
    elif shape.kind == 'prefill':
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * cfg.active_param_count() * tokens
    else:
        tokens = shape.global_batch  # one token per request
        model_flops = 2 * cfg.active_param_count() * tokens
    hlo_flops_global = flops_dev * n_chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    rec.update(
        status='ok',
        chips=n_chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        raw_cost_analysis={'flops': float(cost.get('flops', 0.0)),
                           'bytes': float(cost.get('bytes accessed', 0.0))},
        hbm=hbm_bytes,
        collectives=coll,
        roofline={
            'compute_s': t_comp, 'memory_s': t_mem, 'collective_s': t_coll,
            'dominant': max((('compute', t_comp), ('memory', t_mem),
                             ('collective', t_coll)), key=lambda kv: kv[1])[0],
        },
        model_flops=model_flops,
        useful_flops_ratio=useful,
    )
    return rec


# ---------------------------------------------------------------------------
# Sweep orchestration
# ---------------------------------------------------------------------------

def all_cells(meshes=('single', 'multi')):
    for arch in ARCHS:
        for shape in SHAPES:
            for mk in meshes:
                yield arch, shape, mk


def sweep(out_path: str, *, use_subprocess: bool, meshes=('single', 'multi'),
          only_missing: bool = True):
    done = set()
    if only_missing and os.path.exists(out_path):
        with open(out_path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get('status') not in (None, 'FAILED'):
                        done.add((r['arch'], r['shape'], r['mesh']))
                except json.JSONDecodeError:
                    pass
    os.makedirs(os.path.dirname(out_path) or '.', exist_ok=True)
    cells = [c for c in all_cells(meshes) if c not in done]
    print(f'[dryrun] {len(cells)} cells to run ({len(done)} cached)',
          flush=True)
    with open(out_path, 'a') as f:
        for i, (arch, shape, mk) in enumerate(cells):
            t0 = time.time()
            if use_subprocess:
                proc = subprocess.run(
                    [sys.executable, '-m', 'repro.launch.dryrun',
                     '--arch', arch, '--shape', shape, '--mesh', mk],
                    capture_output=True, text=True,
                    env={**os.environ,
                         'PYTHONPATH': os.environ.get('PYTHONPATH', 'src')})
                try:
                    rec = json.loads(proc.stdout.strip().splitlines()[-1])
                except Exception:
                    rec = {'arch': arch, 'shape': shape, 'mesh': mk,
                           'status': 'FAILED',
                           'error': (proc.stderr or proc.stdout)[-2000:]}
            else:
                rec = run_cell(arch, shape, mk)
            f.write(json.dumps(rec) + '\n')
            f.flush()
            print(f'[dryrun {i + 1}/{len(cells)}] {arch} × {shape} × {mk}: '
                  f'{rec.get("status")} ({time.time() - t0:.1f}s)', flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default=None)
    ap.add_argument('--shape', default=None)
    ap.add_argument('--mesh', default='single', choices=['single', 'multi'])
    ap.add_argument('--sweep', action='store_true')
    ap.add_argument('--subprocess', action='store_true')
    ap.add_argument('--microbatches', type=int, default=1)
    ap.add_argument('--no-zero1', action='store_true')
    ap.add_argument('--rules', default='default',
                    help='sharding-rule variant (see RULE_VARIANTS)')
    ap.add_argument('--out', default='results/dryrun.jsonl')
    args = ap.parse_args()

    if args.sweep:
        sweep(args.out, use_subprocess=args.subprocess)
        return
    assert args.arch and args.shape, '--arch and --shape (or --sweep)'
    rec = run_cell(args.arch, args.shape, args.mesh,
                   microbatches=args.microbatches, zero1=not args.no_zero1,
                   rules_variant=args.rules)
    print(json.dumps(rec))


if __name__ == '__main__':
    main()
