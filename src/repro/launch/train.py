"""End-to-end training driver.

Runs any assigned arch (full or --reduced) with the full substrate: synthetic
data pipeline with prefetch, AdamW + ZeRO-1, checkpoint/restart (atomic,
elastic), straggler telemetry.  On CPU this trains the reduced configs for
real (examples/train_100m.py drives a ~100M model); on TPU pods the same
code runs under the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.models.api import build_model
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.data import DataConfig, Prefetcher, batch_at
from repro.training.fault_tolerance import StragglerDetector
from repro.training.train_step import make_train_step


def train(arch: str, *, steps: int = 100, batch: int = 8, seq: int = 128,
          use_reduced: bool = True, microbatches: int = 1,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          restore: bool = False, mesh=None, seed: int = 0,
          opt_cfg: Optional[opt.AdamWConfig] = None, log_every: int = 10,
          reduced_overrides: Optional[dict] = None):
    cfg = get_config(arch)
    if use_reduced:
        cfg = reduce_cfg(cfg, **(reduced_overrides or {}))
    model = build_model(cfg)

    step_fn, _ = make_train_step(model, mesh, microbatches=microbatches,
                                 opt_cfg=opt_cfg)
    if mesh is not None:
        from repro.configs.base import ShapeConfig
        step_fn = step_fn(ShapeConfig('train', seq, batch, 'train'))

    params = model.init_params(jax.random.PRNGKey(seed))
    opt_state = opt.init_opt_state(params)
    start_step = 0
    if restore and ckpt_dir and (s := ckpt.latest_step(ckpt_dir)) is not None:
        state = {'params': params, 'opt': opt_state}
        state, start_step = ckpt.restore(ckpt_dir, s, state)
        params, opt_state = state['params'], state['opt']
        print(f'[train] restored step {start_step} from {ckpt_dir}')

    dcfg = DataConfig(seq_len=seq, global_batch=batch,
                      vocab_size=cfg.vocab_size, seed=seed)
    pf = Prefetcher(dcfg, start_step=start_step)
    straggler = StragglerDetector()
    losses = []
    try:
        for _ in range(start_step, steps):
            t0 = time.time()
            step, host_batch = next(pf)
            jbatch = jax.tree.map(jax.numpy.asarray, host_batch)
            params, opt_state, metrics = step_fn(params, opt_state, jbatch)
            dt = time.time() - t0
            straggler.record(f'host{jax.process_index()}', dt)
            loss = float(metrics['loss'])
            losses.append(loss)
            if (step + 1) % log_every == 0:
                print(f'[train] step {step + 1} loss {loss:.4f} '
                      f'lr {float(metrics["lr"]):.2e} '
                      f'gnorm {float(metrics["grad_norm"]):.3f} '
                      f'{dt:.2f}s/step', flush=True)
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, step + 1,
                          {'params': params, 'opt': opt_state})
                ckpt.prune(ckpt_dir)
    finally:
        pf.close()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', required=True)
    ap.add_argument('--steps', type=int, default=100)
    ap.add_argument('--batch', type=int, default=8)
    ap.add_argument('--seq', type=int, default=128)
    ap.add_argument('--reduced', action='store_true')
    ap.add_argument('--microbatches', type=int, default=1)
    ap.add_argument('--ckpt-dir', default=None)
    ap.add_argument('--ckpt-every', type=int, default=50)
    ap.add_argument('--restore', action='store_true')
    ap.add_argument('--seed', type=int, default=0)
    args = ap.parse_args()
    _, _, losses = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        use_reduced=args.reduced, microbatches=args.microbatches,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        restore=args.restore, seed=args.seed)
    print(f'[train] done; loss {losses[0]:.4f} → {losses[-1]:.4f}')


if __name__ == '__main__':
    main()
