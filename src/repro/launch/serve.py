"""Live online-offline colocation driver (one node).

An ONLINE engine (latency-critical, bursty arrivals) and an OFFLINE engine
(throughput batch work) share one KV pool through the ValveRuntime:

- online activity closes the offline compute gates (≤ 1 preemption per
  online request, wake after T_cool);
- online memory pressure reclaims offline handles (compute-first, quarantine
  remap, the < 20-LOC invalidation callback resets offline requests);
- MIAD keeps the online reservation tracking demand.

Reports TTFT / TPOT for online and tokens/s for offline — the same metrics
the paper's Fig. 10 uses; benchmarks/colocation_matrix.py runs the full
strategy grid in simulation.

    PYTHONPATH=src python -m repro.launch.serve --steps 400
"""
from __future__ import annotations

import argparse
from typing import Optional

import jax
import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.core.clock import RealClock
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.models.api import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kvpool import KVPool


def serve_demo(*, arch: str = 'qwen3-0.6b', steps: int = 400,
               online_rate: float = 0.08, burst_every: int = 120,
               seed: int = 0, clock=None, quiet: bool = False):
    """Drive both engines for ``steps`` scheduler ticks; returns metrics."""
    rng = np.random.default_rng(seed)
    cfg = reduce_cfg(get_config(arch), page_size=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))

    pool = KVPool(n_handles=24, pages_per_handle=8, page_size=4,
                  reserved_handles=2)
    clock = clock or RealClock()
    online_eng: Optional[Engine] = None
    offline_eng: Optional[Engine] = None

    def on_invalidate(inv):
        offline_eng.on_pages_invalidated(inv)

    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=clock, on_invalidate=on_invalidate)
    online_eng = Engine(model, params,
                        pool, EngineConfig(max_batch=8, max_seq=96,
                                           prefill_chunk=16, klass='online'),
                        runtime=rt, clock=clock)
    offline_eng = Engine(model, params,
                         pool, EngineConfig(max_batch=8, max_seq=96,
                                            prefill_chunk=16,
                                            klass='offline'),
                         runtime=rt, clock=clock)

    # offline backlog: long prompts, long generations
    for _ in range(12):
        offline_eng.submit(rng.integers(1, cfg.vocab_size, 24).tolist(),
                           max_new_tokens=24)

    for t in range(steps):
        # bursty online arrivals: poisson background + periodic spike
        n_new = rng.poisson(online_rate) + (3 if t % burst_every == 0 else 0)
        for _ in range(n_new):
            online_eng.submit(rng.integers(1, cfg.vocab_size, 12).tolist(),
                              max_new_tokens=8)
        if online_eng.queue or online_eng.running:
            online_eng.step()
        else:
            offline_eng.step()
        rt.tick()

    rt.check_invariants()
    on_fin = online_eng.finished
    off_fin = offline_eng.finished
    ttfts = [r.ttft for r in on_fin if r.ttft is not None]
    tpots = [r.tpot for r in on_fin if r.tpot and r.tpot > 0]
    metrics = {
        'online_finished': len(on_fin),
        'offline_finished': len(off_fin),
        'online_ttft_p50': float(np.median(ttfts)) if ttfts else None,
        'online_tpot_p50': float(np.median(tpots)) if tpots else None,
        'offline_tokens': offline_eng.stats.tokens_generated,
        'offline_recomputed_tokens': offline_eng.stats.tokens_recomputed,
        'compute_preemptions': rt.stats.compute_preemptions,
        'offline_wakeups': rt.stats.offline_wakeups,
        'reclamations': rt.reclaimer.stats.reclamations,
        'max_preemptions_per_request': max(
            rt.lifecycle.stats.preempted_requests.values(), default=0),
    }
    if not quiet:
        for k, v in metrics.items():
            print(f'  {k}: {v}')
    return metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='qwen3-0.6b')
    ap.add_argument('--steps', type=int, default=400)
    ap.add_argument('--seed', type=int, default=0)
    args = ap.parse_args()
    serve_demo(arch=args.arch, steps=args.steps, seed=args.seed)


if __name__ == '__main__':
    main()
