"""Live online-offline colocation driver (one node).

One ONLINE engine (latency-critical, bursty arrivals) and N OFFLINE engines
(throughput batch work, **heterogeneous model configs**) share one KV pool
and one set of dispatch gates through the :class:`NodeOrchestrator`:

- online activity closes the offline compute gates (≤ 1 preemption per
  online request, wake after T_cool); offline backfills whenever the gates
  are open — the loop is driven from gate state, not ad-hoc alternation;
- online memory pressure reclaims offline handles (compute-first, quarantine
  remap); invalidations fan out to the owning engine's session (< 20-LOC
  callback, routed by allocation ownership — see ``docs/API.md``);
- MIAD keeps the online reservation tracking demand;
- every preemption/reclamation/wake-up is published on the runtime's typed
  event stream; the reported metrics derive from it (``runtime.telemetry``).

Reports TTFT / TPOT for online and tokens/s for offline — the same metrics
the paper's Fig. 10 uses; benchmarks/colocation_matrix.py runs the full
strategy grid in simulation, benchmarks/serve_throughput.py measures this
driver.

    # heterogeneous demo: online qwen3-0.6b + offline qwen3-0.6b AND
    # offline internlm2-1.8b (reduced) on one pool
    PYTHONPATH=src python -m repro.launch.serve --steps 400

    # pick the offline models explicitly (repeatable flag)
    PYTHONPATH=src python -m repro.launch.serve \\
        --offline-arch internlm2-1.8b --offline-arch qwen3-0.6b
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

import numpy as np

from repro.configs import get_config, reduced as reduce_cfg
from repro.core.clock import RealClock
from repro.core.runtime import RuntimeConfig, ValveRuntime
from repro.launch.node import NodeOrchestrator
from repro.serving.engine import EngineConfig
from repro.serving.kvpool import KVPool

DEFAULT_OFFLINE_ARCHS = ('qwen3-0.6b', 'internlm2-1.8b')


def build_node(*, arch: str = 'qwen3-0.6b',
               offline_archs: Sequence[str] = DEFAULT_OFFLINE_ARCHS,
               seed: int = 0, clock=None, page_size: int = 4,
               max_prefill_reqs: int = 4,
               piggyback_decode: bool = True,
               idle_advance: float = 1e-3) -> NodeOrchestrator:
    """One node: online ``arch`` + one offline engine per ``offline_archs``
    entry (heterogeneous model configs over one pool/runtime)."""
    pool = KVPool(n_handles=24, pages_per_handle=8, page_size=page_size,
                  reserved_handles=2)
    clock = clock or RealClock()
    rt = ValveRuntime(pool, RuntimeConfig(n_devices=1, t_cool_init=0.002),
                      clock=clock)
    node = NodeOrchestrator(rt, idle_advance=idle_advance)

    def ecfg(klass: str) -> EngineConfig:
        return EngineConfig(max_batch=8, max_seq=96, prefill_chunk=16,
                            max_prefill_reqs=max_prefill_reqs,
                            piggyback_decode=piggyback_decode, klass=klass)

    node.add_engine(reduce_cfg(get_config(arch), page_size=page_size),
                    ecfg('online'), seed=seed, name=f'online:{arch}')
    for i, oarch in enumerate(offline_archs):
        node.add_engine(reduce_cfg(get_config(oarch), page_size=page_size),
                        ecfg('offline'), seed=seed + i,
                        name=f'offline{i}:{oarch}')
    return node


def serve_demo(*, arch: str = 'qwen3-0.6b',
               offline_archs: Sequence[str] = DEFAULT_OFFLINE_ARCHS,
               steps: int = 400, online_rate: float = 0.08,
               burst_every: int = 120, seed: int = 0, clock=None,
               quiet: bool = False, max_prefill_reqs: int = 4,
               piggyback_decode: bool = True,
               node: Optional[NodeOrchestrator] = None):
    """Drive the node for ``steps`` scheduler ticks; returns metrics.

    A prebuilt ``node`` takes precedence: the build kwargs (``arch``,
    ``offline_archs``, ``max_prefill_reqs``, ``piggyback_decode``,
    ``clock``) only apply when this function builds the node itself.
    """
    rng = np.random.default_rng(seed)
    node = node or build_node(arch=arch, offline_archs=offline_archs,
                              seed=seed, clock=clock,
                              max_prefill_reqs=max_prefill_reqs,
                              piggyback_decode=piggyback_decode)
    online_eng = node.online

    # offline backlog: long prompts, long generations, spread round-robin
    # across the (heterogeneous) offline engines
    for i in range(6 * len(node.offline)):
        eng = node.offline[i % len(node.offline)]
        eng.submit(rng.integers(1, eng.mcfg.vocab_size, 24).tolist(),
                   max_new_tokens=24)

    for t in range(steps):
        # bursty online arrivals: poisson background + periodic spike
        # (an offline-only prebuilt node simply gets no arrivals)
        n_new = rng.poisson(online_rate) + (3 if t % burst_every == 0 else 0)
        for _ in range(n_new if online_eng is not None else 0):
            online_eng.submit(
                rng.integers(1, online_eng.mcfg.vocab_size, 12).tolist(),
                max_new_tokens=8)
        node.step()
    # arrivals over: drain the remaining (mostly offline) backlog so the
    # throughput metrics reflect completed work, not a truncated run
    node.drain()

    # event-log invariants (≤1 preemption/request, wakeups==gate-enables,
    # §5 ordering) + the published-event census from the typed stream
    node.runtime.check_invariants()
    metrics = node.metrics()
    metrics['events'] = dict(node.runtime.bus.published)
    metrics['live_invalidation_routes'] = \
        len(node.runtime.invalidation_routes())
    if not quiet:
        for k, v in metrics.items():
            if k == 'engines':
                for name, em in v.items():
                    print(f'  engine {name}: {em}')
            else:
                print(f'  {k}: {v}')
    return metrics


def serve_http(*, arch: str = 'qwen3-0.6b',
               offline_archs: Sequence[str] = DEFAULT_OFFLINE_ARCHS,
               host: str = '127.0.0.1', port: int = 8080,
               seed: int = 0) -> None:
    """Run the async serving front-end over a live node: OpenAI-style
    ``POST /v1/completions`` (SSE streaming) + the ``/v1/batches`` offline
    batch-job API, one event loop owning the runtime (docs/API.md
    § Serving endpoints).

        PYTHONPATH=src python -m repro.launch.serve --http --port 8080
        curl -N localhost:8080/v1/completions -d \\
            '{"prompt": [5, 7, 11], "max_tokens": 8, "stream": true}'
    """
    import asyncio

    from repro.serving.frontend.app import FrontendApp
    from repro.serving.frontend.driver import AsyncNodeDriver
    from repro.serving.frontend.http import serve_asgi

    node = build_node(arch=arch, offline_archs=offline_archs, seed=seed)

    async def _main() -> None:
        async with AsyncNodeDriver(node) as driver:
            server = await serve_asgi(FrontendApp(driver), host, port)
            print(f'serving on http://{host}:{server.port}  '
                  f'(online {arch}, offline {", ".join(offline_archs)})')
            try:
                await server.serve_forever()
            except asyncio.CancelledError:
                pass
            finally:
                await server.stop()

    try:
        asyncio.run(_main())
    except KeyboardInterrupt:
        print('shutting down')


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='qwen3-0.6b',
                    help='online engine architecture')
    ap.add_argument('--offline-arch', action='append', default=None,
                    help='offline engine architecture (repeatable; default: '
                         f'{" + ".join(DEFAULT_OFFLINE_ARCHS)})')
    ap.add_argument('--steps', type=int, default=400)
    ap.add_argument('--seed', type=int, default=0)
    ap.add_argument('--http', action='store_true',
                    help='serve the HTTP front-end (SSE streaming + batch '
                         'jobs) instead of running the scripted demo')
    ap.add_argument('--host', default='127.0.0.1')
    ap.add_argument('--port', type=int, default=8080)
    args = ap.parse_args()
    offline_archs = tuple(args.offline_arch or DEFAULT_OFFLINE_ARCHS)
    if args.http:
        serve_http(arch=args.arch, offline_archs=offline_archs,
                   host=args.host, port=args.port, seed=args.seed)
    else:
        serve_demo(arch=args.arch, offline_archs=offline_archs,
                   steps=args.steps, seed=args.seed)


if __name__ == '__main__':
    main()
