"""Post-optimization HLO text analysis for roofline accounting.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, so a scan-over-
layers program under-reports FLOPs/bytes/collectives by ~n_layers×.  This
module parses the optimized HLO text instead:

- pass 1 splits the module into computations, records every op (kind, result
  type, operand names) plus a symbol table so operand shapes resolve;
- pass 2 computes per-computation costs: dot FLOPs (2 × |result| ×
  contraction), collective payload bytes by kind (+ ring wire factors from
  replica_groups), and materialized-buffer traffic.  Traffic is
  **slice-aware**: dynamic-slice/gather charge the region, dynamic-update-
  slice charges 2× the update, and *fusions* charge each operand by how the
  fused computation consumes the matching parameter (a parameter only read
  through dynamic-slice charges the slice — this is what keeps a scan body
  that slices stacked (L, …) params from counting the full stack every
  iteration);
- execution multipliers propagate through the call graph: while bodies
  multiply by ``known_trip_count``, fusion-called computations are inlined.

Elementwise FLOPs are ignored (standard MFU accounting).  All counts are
per-device — the compiled module is the per-device SPMD program.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    'f64': 8, 'f32': 4, 'f16': 2, 'bf16': 2, 'f8e4m3fn': 1, 'f8e5m2': 1,
    's64': 8, 's32': 4, 's16': 2, 's8': 1, 'u64': 8, 'u32': 4, 'u16': 2,
    'u8': 1, 'pred': 1, 'c64': 8, 'c128': 16, 's4': 1, 'u4': 1,
}

COLLECTIVE_KINDS = ('all-reduce', 'all-gather', 'reduce-scatter',
                    'all-to-all', 'collective-permute')

_SHAPE_RE = re.compile(r'(\w+)\[([\d,]*)\]')
_HEADER_RE = re.compile(
    r'^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->\s*(.+?)\s*{\s*$')
# tuple result types may contain /*index=N*/ comments ('=' inside) but never
# a ')' — match to the first closing paren
_OP_RE = re.compile(r'^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*'
                    r'((?:\([^)]*\)|[\w\[\]{},]+))\s+([\w\-]+)\((.*)$')
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')
_GROUPS_LIST_RE = re.compile(r'replica_groups=\{\{([\d,]+)\}')
_GROUPS_IOTA_RE = re.compile(r'replica_groups=\[(\d+),(\d+)\]')
_CALLS_RE = re.compile(r'calls=%?([\w.\-]+)')
_BODY_RE = re.compile(r'body=%?([\w.\-]+)')
_COND_RE = re.compile(r'condition=%?([\w.\-]+)')
_APPLY_RE = re.compile(r'to_apply=%?([\w.\-]+)')
_BRANCH_RE = re.compile(r'branch_computations=\{([^}]*)\}')
_OPERAND_RE = re.compile(r'%([\w.\-]+)')
_CONTRACT_RE = re.compile(r'lhs_contracting_dims=\{([\d,]*)\}')
_PARAM_IDX_RE = re.compile(r'parameter\((\d+)\)')


def shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(',') if d]))
    return out


def type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(','))
    return 2


def wire_factor(kind: str, n: int) -> float:
    """Ring-algorithm wire bytes per payload byte per device."""
    if n <= 1:
        return 0.0
    if kind == 'all-reduce':
        return 2.0 * (n - 1) / n
    if kind in ('all-gather', 'reduce-scatter', 'all-to-all'):
        return (n - 1) / n
    return 1.0  # collective-permute


_FREE_OPS = {'get-tuple-element', 'tuple', 'parameter', 'bitcast',
             'constant', 'after-all', 'iota', 'partition-id', 'replica-id',
             # control flow: carries are aliased in place; body ops are
             # already counted via the call graph
             'while', 'conditional', 'call'}
_SLICE_READS = {'dynamic-slice', 'slice', 'gather'}


@dataclass
class Op:
    kind: str
    rtype: str
    operands: List[str]
    line: str
    is_root: bool = False
    is_async_start: bool = False


@dataclass
class Computation:
    name: str
    param_names: List[str] = field(default_factory=list)
    param_types: List[str] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)
    ops: List[Op] = field(default_factory=list)
    # (callee, multiplier, via_fusion)
    calls: List[Tuple[str, float, bool]] = field(default_factory=list)


def _parse_computation(name: str, param_types_str: str, body: List[str]
                       ) -> Computation:
    comp = Computation(name)
    for pm in re.finditer(r'([\w.\-]+):\s*(\([^)]*\)|[\w\[\]{},]+)',
                          param_types_str):
        comp.symbols[pm.group(1)] = pm.group(2)

    for line in body:
        m = _OP_RE.match(line)
        if not m:
            # parameter ops have no '(' payload in some printers; catch them
            pm = re.match(r'^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*'
                          r'((?:\([^)]*\)|[\w\[\]{},]+))\s+parameter\((\d+)\)',
                          line)
            if pm:
                _, opname, rtype, idx = pm.groups()
                comp.symbols[opname] = rtype
                i = int(idx)
                while len(comp.param_names) <= i:
                    comp.param_names.append('')
                    comp.param_types.append('')
                comp.param_names[i] = opname
                comp.param_types[i] = rtype
            continue
        root, opname, rtype, kind, rest = m.groups()
        comp.symbols[opname] = rtype
        if kind == 'parameter':
            pm2 = _PARAM_IDX_RE.search(line)
            if pm2:
                i = int(pm2.group(1))
                while len(comp.param_names) <= i:
                    comp.param_names.append('')
                    comp.param_types.append('')
                comp.param_names[i] = opname
                comp.param_types[i] = rtype
            continue
        is_start = kind.endswith('-start')
        base = kind[:-len('-start')] if is_start else kind
        if base.endswith('-done'):
            continue
        operands = _OPERAND_RE.findall(rest.split(')', 1)[0])
        comp.ops.append(Op(base, rtype, operands, line,
                           is_root=bool(root), is_async_start=is_start))

        # call graph
        cm = _CALLS_RE.search(line)
        if base == 'fusion' and cm:
            comp.calls.append((cm.group(1), 1.0, True))
        bm = _BODY_RE.search(line)
        if base == 'while' and bm:
            tm = _TRIP_RE.search(line)
            trip = float(tm.group(1)) if tm else 1.0
            comp.calls.append((bm.group(1), trip, False))
            cnd = _COND_RE.search(line)
            if cnd:
                comp.calls.append((cnd.group(1), trip, False))
        am = _APPLY_RE.search(line)
        if am and base not in COLLECTIVE_KINDS:
            comp.calls.append((am.group(1), 1.0, True))
        brm = _BRANCH_RE.search(line)
        if base == 'conditional' and brm:
            for b in _OPERAND_RE.findall(brm.group(1)):
                comp.calls.append((b, 1.0, False))
        if base == 'call' and cm:
            comp.calls.append((cm.group(1), 1.0, False))
    return comp


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    lines = hlo_text.splitlines()
    i = 0
    entry: Optional[str] = None
    while i < len(lines):
        m = _HEADER_RE.match(lines[i])
        if not m:
            i += 1
            continue
        is_entry, name, params, _ret = m.groups()
        body = []
        i += 1
        while i < len(lines) and not lines[i].startswith('}'):
            body.append(lines[i])
            i += 1
        comp = _parse_computation(name, params, body)
        comps[comp.name] = comp
        if is_entry:
            entry = name
    return comps, entry


# ---------------------------------------------------------------------------
# Cost pass
# ---------------------------------------------------------------------------

def _dot_flops(op: Op, comp: Computation) -> float:
    cdm = _CONTRACT_RE.search(op.line)
    lhs_t = comp.symbols.get(op.operands[0]) if op.operands else None
    contract = 1
    if cdm and lhs_t:
        lhs_dims = shape_dims(lhs_t)
        if lhs_dims:
            dims = lhs_dims[0][1]
            for di in cdm.group(1).split(','):
                if di and int(di) < len(dims):
                    contract *= dims[int(di)]
    n_out = 1
    for _, dims in shape_dims(op.rtype):
        for d in dims:
            n_out *= d
    return 2.0 * n_out * contract


def _fusion_param_charges(fused: Computation) -> Tuple[List[Optional[float]],
                                                       float]:
    """Per-parameter read charge for a fused computation.

    Returns (charges, extra_write): charges[i] is bytes to charge for
    operand i (None → full operand bytes); extra_write adjusts the result
    charge (DUS root writes only the update region).
    """
    uses: Dict[str, List[Op]] = defaultdict(list)
    for op in fused.ops:
        for o in op.operands:
            uses[o].append(op)
    charges: List[Optional[float]] = []
    root = next((op for op in fused.ops if op.is_root),
                fused.ops[-1] if fused.ops else None)
    for pname, ptype in zip(fused.param_names, fused.param_types):
        if not pname:
            charges.append(None)
            continue
        consumers = uses.get(pname, [])
        if not consumers:
            charges.append(0.0)
            continue
        full = float(type_bytes(ptype))
        charge = 0.0
        sliced = True
        for c in consumers:
            if c.kind in _SLICE_READS:
                charge += type_bytes(c.rtype)
            elif (c.kind == 'dynamic-update-slice' and c.operands
                  and c.operands[0] == pname):
                continue  # aliased in-place destination: no read
            else:
                sliced = False
                break
        charges.append(min(charge, full) if sliced else None)
    extra_write = 0.0
    if root is not None and root.kind == 'dynamic-update-slice':
        upd_t = (fused.symbols.get(root.operands[1])
                 if len(root.operands) > 1 else None)
        if upd_t:
            # charge update region instead of the full result buffer
            extra_write = float(type_bytes(upd_t)) - float(type_bytes(root.rtype))
    return charges, extra_write


def _op_traffic(op: Op, comp: Computation,
                comps: Dict[str, Computation]) -> float:
    res = float(type_bytes(op.rtype))
    if op.kind in _SLICE_READS:
        return 2.0 * res
    if op.kind in ('dynamic-update-slice', 'scatter'):
        upd = comp.symbols.get(op.operands[1]) if len(op.operands) > 1 else None
        return 2.0 * (type_bytes(upd) if upd else res)
    if op.kind == 'fusion':
        cm = _CALLS_RE.search(op.line)
        fused = comps.get(cm.group(1)) if cm else None
        if fused is not None and fused.param_names:
            charges, extra_write = _fusion_param_charges(fused)
            b = max(res + extra_write, 0.0)
            for i, oname in enumerate(op.operands):
                t = comp.symbols.get(oname)
                full = float(type_bytes(t)) if t else 0.0
                if i < len(charges) and charges[i] is not None:
                    b += min(charges[i], full)
                else:
                    b += full
            return b
    b = res
    for oname in op.operands:
        t = comp.symbols.get(oname)
        if t:
            b += type_bytes(t)
    return b


@dataclass
class ModuleCosts:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    coll_payload: Dict[str, float] = field(default_factory=dict)
    coll_wire: float = 0.0
    coll_count: float = 0.0


def analyze(hlo_text: str) -> ModuleCosts:
    comps, entry = parse_module(hlo_text)
    out = ModuleCosts()
    if entry is None:
        return out

    mult: Dict[str, float] = defaultdict(float)
    fusion_ctx: Dict[str, bool] = defaultdict(lambda: False)

    def visit(name: str, m: float, via_fusion: bool):
        comp = comps.get(name)
        if comp is None:
            return
        mult[name] += m
        if via_fusion:
            fusion_ctx[name] = True
        for callee, k, fus in comp.calls:
            visit(callee, m * k, via_fusion or fus)

    visit(entry, 1.0, False)

    for name, m in mult.items():
        comp = comps[name]
        if fusion_ctx[name]:
            # inlined into a fusion: dots inside fusions still execute
            for op in comp.ops:
                if op.kind == 'dot':
                    out.flops += m * _dot_flops(op, comp)
            continue
        for op in comp.ops:
            if op.kind == 'dot':
                out.flops += m * _dot_flops(op, comp)
            elif op.kind == 'convolution':
                out.flops += m * 2.0 * type_bytes(op.rtype)
            if op.kind in COLLECTIVE_KINDS:
                payload = type_bytes(op.rtype)
                if op.is_async_start and op.rtype.startswith('('):
                    payload //= 2
                n = _group_size(op.line)
                out.coll_payload[op.kind] = (
                    out.coll_payload.get(op.kind, 0.0) + m * payload)
                out.coll_wire += m * payload * wire_factor(op.kind, n)
                out.coll_count += m
            if op.kind not in _FREE_OPS:
                out.traffic_bytes += m * _op_traffic(op, comp, comps)
    return out
