"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline terms, benchmarks, napkin math)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper (elastic re-mesh, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
