"""Node orchestrator — one node's engines behind one ValveRuntime.

Valve's deployment unit is a *node*: one latency-critical ONLINE engine plus
N throughput OFFLINE engines — possibly of **different models** — sharing
one GPU's compute (dispatch gates) and KV memory (one :class:`KVPool`)
through one :class:`ValveRuntime`.  ``launch/serve.py`` used to hand-roll a
two-engine alternation loop; this module owns that loop and drives it from
*gate state*:

- the online engine dispatches whenever it has work (its lifecycle
  notifications close the gates, preempting offline compute);
- offline engines backfill whenever the gates are open (woken by the
  runtime after ``T_cool`` of continuous online idle), round-robin across
  engines so heterogeneous offline models share the harvested capacity;
- ``runtime.tick()`` runs every step (MIAD reservation + wake-up checks).

Each engine holds a class-scoped :class:`~repro.core.api.ValveSession`;
invalidations route to the owning session by allocation ownership, so N
engines each keep their own < 20-LOC patch surface — no shared callback
plumbing (and no per-request ``bind_invalidation`` table) in drivers.
The orchestrator observes the runtime through the typed event stream
(``runtime.subscribe``) and the unified telemetry registry
(``runtime.telemetry``) — it never reaches into per-plane stat objects.

**Multi-pool nodes** (cross-pool KV rescue): :meth:`add_pool` registers
auxiliary :class:`KVPool` instances — one per device group — whose memory
planes become migration targets of each other and of the runtime pool.
When online pressure reclaims offline handles, the plane first tries to
*migrate* each victim's lease to the least-loaded other pool
(``KVPool.transfer_pages`` cross-pool) instead of truncating it.  The
orchestrator subscribes to the resulting :class:`PageMigration` events and
completes the rescue at both planes:

- **data plane** — the KV cache rows behind the moved pages are copied
  from the source engine's cache into the destination engine's cache,
  synchronously at publish time (before the freed source pages can be
  reallocated and overwritten);
- **control plane** — the ``Request`` object is handed off from the source
  engine to an engine serving the destination pool and resubmitted; its
  live lease already sits in the destination plane, so admission extends
  it and prefill resumes at ``lease.resume_tokens`` — zero tokens of
  recompute are charged anywhere on this path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.events import (
    PageMigration, PreemptionEvent, ReclamationEvent, RuntimeEvent,
    WakeupEvent)
from repro.core.memory import MemoryPlane
from repro.core.runtime import ValveRuntime
from repro.models.api import build_model
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kvpool import KVPool
from repro.serving.scheduler import ReqState


@dataclass
class NodeStats:
    steps: int = 0
    online_dispatches: int = 0
    offline_dispatches: int = 0
    gated_skips: int = 0            # offline had work but gates were closed
    idle_steps: int = 0             # nothing dispatched this step
    # event-stream observations (subscribed, not scraped from stat fields)
    preemptions_seen: int = 0
    wakeups_seen: int = 0
    invalidation_bursts_seen: int = 0
    migrations_seen: int = 0        # cross-pool PageMigration events
    requests_rescued: int = 0       # handoffs completed (request moved)


class NodeOrchestrator:
    """Registers engines over one shared runtime and drives the node loop."""

    def __init__(self, runtime: ValveRuntime, *, idle_advance: float = 1e-3,
                 disaggregated: bool = False):
        self.runtime = runtime
        self.clock = runtime.clock
        self.pool = runtime.pool
        # True marks this node as one half of a disaggregated topology
        # (repro.serving.disagg.DisaggPlane): cross-pool PageMigration
        # completion is delegated to the plane's subscriber — exactly one
        # completer per migration — instead of the node's own handoff
        self.disaggregated = disaggregated
        self.online: Optional[Engine] = None
        self.offline: List[Engine] = []
        self.names: Dict[str, Engine] = {}
        self.stats = NodeStats()
        # on steps where nothing dispatched, sleep this long so continuous
        # idle can accumulate to T_cool and wake offline (a busy-spinning
        # drive loop would otherwise re-check the gates microseconds apart
        # and starve offline forever — and a VirtualClock would never
        # advance at all, livelocking drain()); works for both clock kinds
        self.idle_advance = idle_advance
        self._rr = 0                # round-robin cursor over offline engines
        # auxiliary pools (one per device group) and completed rescues
        self.pools: List[KVPool] = []
        self.rescues: List[Tuple[str, str, str]] = []  # (rid, src, dst)
        runtime.subscribe(self._on_runtime_event)

    def _on_runtime_event(self, ev: RuntimeEvent) -> None:
        """The orchestrator's view of runtime activity IS the event stream
        (same ordered facts the sim and the cluster harness consume)."""
        if isinstance(ev, PreemptionEvent):
            self.stats.preemptions_seen += 1
        elif isinstance(ev, WakeupEvent):
            self.stats.wakeups_seen += 1
        elif isinstance(ev, ReclamationEvent):
            self.stats.invalidation_bursts_seen += 1
        elif isinstance(ev, PageMigration) and ev.cross_pool:
            self.stats.migrations_seen += 1
            if not self.disaggregated:
                self._handoff_migration(ev)

    # ------------------------------------------------------------------
    # Registration
    # ------------------------------------------------------------------
    def register(self, engine: Engine, name: Optional[str] = None) -> Engine:
        """Register a pre-built engine.

        Runtime-backed engines must share this node's runtime; pool-backed
        engines (no runtime — a :class:`PoolSession` over an auxiliary
        pool) must be OFFLINE and serve a pool added via :meth:`add_pool`.
        """
        if engine.runtime is not None:
            assert engine.runtime is self.runtime, \
                'engine must be built on this node\'s runtime'
        else:
            assert engine.pool in self.pools, \
                'pool-backed engine must serve a pool from add_pool'
            assert engine.cfg.klass == 'offline', \
                'auxiliary-pool engines are offline only'
        assert engine.mcfg.page_size == self.pool.page_size, \
            (engine.mcfg.page_size, self.pool.page_size)
        if engine.cfg.klass == 'online':
            assert self.online is None, 'one online engine per node'
            self.online = engine
        else:
            self.offline.append(engine)
        name = name or f'{engine.cfg.klass}:{engine.mcfg.name}' \
                       f'#{len(self.names)}'
        assert name not in self.names, f'duplicate engine name {name!r}'
        self.names[name] = engine
        return engine

    def add_engine(self, model_cfg, engine_cfg: EngineConfig, *,
                   params=None, seed: int = 0, name: Optional[str] = None,
                   pool: Optional[KVPool] = None) -> Engine:
        """Build a model + engine on this node's runtime and register it.
        Heterogeneous colocation = calling this with different model configs
        (page_size must match the shared pool).  With ``pool`` set to an
        auxiliary pool (see :meth:`add_pool`), the engine is built over
        that pool's memory plane instead of the runtime — the migration
        destination for cross-pool rescues."""
        model = build_model(model_cfg)
        if params is None:
            params = model.init_params(jax.random.PRNGKey(seed))
        if pool is not None and pool is not self.pool:
            eng = Engine(model, params, pool, engine_cfg, clock=self.clock)
        else:
            eng = Engine(model, params, None, engine_cfg,
                         runtime=self.runtime, clock=self.clock)
        return self.register(eng, name)

    def add_pool(self, pool: KVPool) -> KVPool:
        """Register an auxiliary KV pool (one per device group).

        The pool joins the node's event stream (PageMigration publishes on
        the runtime bus) and every plane on the node — runtime pool plus
        all auxiliary pools — becomes a migration target of the others, so
        a reclamation victim on any pool can be rescued to the least
        loaded of the rest."""
        assert pool is not self.pool and pool not in self.pools, \
            'pool already registered'
        # names key migration_targets and PageMigration provenance
        # (src_pool/dst_pool): a duplicate would make rescue events
        # ambiguous and steer the data-plane copy to the wrong engine
        taken = {self.pool.name} | {p.name for p in self.pools}
        assert pool.name not in taken, \
            f'duplicate pool name {pool.name!r} (names key migration ' \
            f'targets and PageMigration provenance)'
        assert pool.page_size == self.pool.page_size, \
            (pool.page_size, self.pool.page_size)
        pool.bus = self.runtime.bus
        self.pools.append(pool)
        planes = [self.runtime.memory] + \
            [MemoryPlane.of(p) for p in self.pools]
        for pl in planes:
            pl.migration_targets = [q for q in planes if q is not pl]
        return pool

    @property
    def engines(self) -> List[Engine]:
        return ([self.online] if self.online is not None else []) + \
            list(self.offline)

    def engine_of(self, req_id: str) -> Optional[Engine]:
        """The engine currently holding ``req_id`` (None if unknown) —
        requests move between engines on this node (cross-pool rescue)
        and between nodes (disaggregated handoff), so front-end cancel /
        flush paths resolve the holder per call instead of assuming
        ``self.online``."""
        for eng in self.engines:
            if req_id in eng.requests:
                return eng
        return None

    # ------------------------------------------------------------------
    # Cross-pool rescue handoff (PageMigration subscriber)
    # ------------------------------------------------------------------
    def _engine_for_pool(self, pool_name: str,
                         holding: Optional[str] = None) -> Optional[Engine]:
        for eng in self.engines:
            if eng.pool.name != pool_name:
                continue
            if holding is None or holding in eng.requests:
                return eng
        return None

    def _handoff_migration(self, ev: PageMigration) -> None:
        """Complete a cross-pool rescue: copy the KV cache rows behind the
        moved pages and move the Request to an engine on the target pool.

        Runs synchronously inside the event publish — i.e. inside the
        reclamation that triggered the rescue, while the source engine is
        quiescent (reclamation only fires from online allocation pressure
        and the runtime tick, never mid-offline-dispatch) and before the
        freed source pages can be reallocated and overwritten."""
        src = self._engine_for_pool(ev.src_pool, holding=ev.owner)
        dst = self._engine_for_pool(ev.dst_pool)
        if src is None or dst is None or src is dst:
            return                  # not a serving-engine lease — no handoff
        # data plane: same-architecture engines move the physical KV rows
        # (page axis 1 of the engine pool layout); heterogeneous pairs keep
        # the bookkeeping-level rescue only
        if ev.src_pages and src.mcfg.name == dst.mcfg.name:
            s = np.asarray(ev.src_pages)
            d = np.asarray(ev.dst_pages)
            dst.cache = jax.tree_util.tree_map(
                lambda dc, sc: dc.at[:, d].set(sc[:, s]),
                dst.cache, src.cache)
        # control plane: hand the request off.  Pending fused-path tokens
        # reference src.requests by id — resolve them before the pop.
        src.flush_tokens()
        req = src.requests.pop(ev.owner)
        if ev.owner in src.queue:
            src.queue.remove(ev.owner)
        if ev.owner in src.running:
            src.running.remove(ev.owner)
        req.state = ReqState.WAITING
        req.pages, req.blocked_admits = [], 0
        dst.requests[ev.owner] = req
        dst.sched.submit(ev.owner)
        # admission on dst finds the migrated live lease in its plane and
        # resumes prefill at lease.resume_tokens — nothing recomputes
        self.stats.requests_rescued += 1
        self.rescues.append((ev.owner, ev.src_pool, ev.dst_pool))

    # ------------------------------------------------------------------
    # Drive loop
    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return any(e.queue or e.running for e in self.engines)

    def step(self) -> bool:
        """One node tick: online first, offline backfill iff gates open."""
        self.stats.steps += 1
        progressed = False
        if self.online is not None and (self.online.queue
                                        or self.online.running):
            if self.online.step():
                progressed = True
                self.stats.online_dispatches += 1
        if any(e.queue or e.running for e in self.offline):
            if self.runtime.offline_may_dispatch():
                # round-robin: try each offline engine once, dispatch the
                # first that makes progress (a memory-blocked engine does
                # not starve its siblings)
                n = len(self.offline)
                for _ in range(n):
                    eng = self.offline[self._rr % n]
                    self._rr += 1
                    if not (eng.queue or eng.running):
                        continue
                    if eng.step():
                        progressed = True
                        self.stats.offline_dispatches += 1
                        break
            else:
                self.stats.gated_skips += 1
        self.runtime.tick()
        if not progressed:
            self.stats.idle_steps += 1
            if self.idle_advance > 0:
                self.clock.sleep(self.idle_advance)
        return progressed

    def run(self, steps: int) -> None:
        for _ in range(steps):
            self.step()

    def drain(self, max_steps: int = 100_000) -> None:
        """Run until every engine's queue and batch are empty."""
        for _ in range(max_steps):
            if not self.has_work():
                return
            self.step()
        raise RuntimeError('drain exceeded max_steps')

    # ------------------------------------------------------------------
    # Metrics (the paper's Fig. 10 axes + serving-plane counters)
    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, object]:
        on_fin = self.online.finished if self.online is not None else []
        ttfts = [r.ttft for r in on_fin if r.ttft is not None]
        tpots = [r.tpot for r in on_fin if r.tpot and r.tpot > 0]
        off_tokens = sum(e.stats.tokens_generated for e in self.offline)
        off_recomp = sum(e.stats.tokens_recomputed for e in self.offline)
        # runtime counters come from the unified telemetry registry (the
        # event-stream fold), not from per-plane stat objects
        tel = self.runtime.telemetry.snapshot()
        return {
            'online_finished': len(on_fin),
            'offline_finished': sum(len(e.finished) for e in self.offline),
            'online_ttft_p50': float(np.median(ttfts)) if ttfts else None,
            'online_tpot_p50': float(np.median(tpots)) if tpots else None,
            'offline_tokens': off_tokens,
            'offline_recomputed_tokens': off_recomp,
            'online_dispatches': self.stats.online_dispatches,
            'offline_dispatches': self.stats.offline_dispatches,
            'gated_skips': self.stats.gated_skips,
            'cancellations': sum(e.stats.cancellations for e in self.engines),
            'compute_preemptions': tel['compute_preemptions'],
            'offline_wakeups': tel['offline_wakeups'],
            'reclamations': tel['reclamations'],
            'max_preemptions_per_request':
                tel['max_preemptions_per_request'],
            'preemption_latency': tel['preemption_latency'],
            # live requests are LEASES now (raw pool owner ids include the
            # memory plane's internal shared-prefix blocks)
            'live_online_requests':
                len(self.runtime.memory.live_leases('online')),
            'live_offline_requests':
                len(self.runtime.memory.live_leases('offline')),
            'engines': {
                name: {
                    'arch': eng.mcfg.name,
                    'klass': eng.cfg.klass,
                    'finished': len(eng.finished),
                    'tokens': eng.stats.tokens_generated,
                    'dispatches': eng.stats.dispatches,
                    'mixed_dispatches': eng.stats.mixed_dispatches,
                    'cancelled': eng.stats.cancellations,
                    # leased pages incl. attached shared-prefix pages
                    # (pool ownership alone would miss attachments)
                    'live_pages': sum(
                        len(r.lease) for r in eng.requests.values()
                        if r.lease is not None and not r.lease.released),
                } for name, eng in self.names.items()
            },
        }
