"""Node orchestrator — one node's engines behind one ValveRuntime.

Valve's deployment unit is a *node*: one latency-critical ONLINE engine plus
N throughput OFFLINE engines — possibly of **different models** — sharing
one GPU's compute (dispatch gates) and KV memory (one :class:`KVPool`)
through one :class:`ValveRuntime`.  ``launch/serve.py`` used to hand-roll a
two-engine alternation loop; this module owns that loop and drives it from
*gate state*:

- the online engine dispatches whenever it has work (its lifecycle
  notifications close the gates, preempting offline compute);
- offline engines backfill whenever the gates are open (woken by the
  runtime after ``T_cool`` of continuous online idle), round-robin across
  engines so heterogeneous offline models share the harvested capacity;
- ``runtime.tick()`` runs every step (MIAD reservation + wake-up checks).

Each engine holds a class-scoped :class:`~repro.core.api.ValveSession`;
invalidations route to the owning session by allocation ownership, so N
engines each keep their own < 20-LOC patch surface — no shared callback
plumbing (and no per-request ``bind_invalidation`` table) in drivers.
The orchestrator observes the runtime through the typed event stream
(``runtime.subscribe``) and the unified telemetry registry
(``runtime.telemetry``) — it never reaches into per-plane stat objects.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.events import (
    PreemptionEvent, ReclamationEvent, RuntimeEvent, WakeupEvent)
from repro.core.runtime import ValveRuntime
from repro.models.api import build_model
from repro.serving.engine import Engine, EngineConfig


@dataclass
class NodeStats:
    steps: int = 0
    online_dispatches: int = 0
    offline_dispatches: int = 0
    gated_skips: int = 0            # offline had work but gates were closed
    idle_steps: int = 0             # nothing dispatched this step
    # event-stream observations (subscribed, not scraped from stat fields)
    preemptions_seen: int = 0
    wakeups_seen: int = 0
    invalidation_bursts_seen: int = 0


class NodeOrchestrator:
    """Registers engines over one shared runtime and drives the node loop."""

    def __init__(self, runtime: ValveRuntime, *, idle_advance: float = 1e-3):
        self.runtime = runtime
        self.clock = runtime.clock
        self.pool = runtime.pool
        self.online: Optional[Engine] = None
        self.offline: List[Engine] = []
        self.names: Dict[str, Engine] = {}
        self.stats = NodeStats()
        # on steps where nothing dispatched, sleep this long so continuous
        # idle can accumulate to T_cool and wake offline (a busy-spinning
        # drive loop would otherwise re-check the gates microseconds apart
        # and starve offline forever — and a VirtualClock would never
        # advance at all, livelocking drain()); works for both clock kinds
        self.idle_advance = idle_advance
        self._rr = 0                # round-robin cursor over offline engines
        runtime.subscribe(self._on_runtime_event)

    def _on_runtime_event(self, ev: RuntimeEvent) -> None:
        """The orchestrator's view of runtime activity IS the event stream
        (same ordered facts the sim and the cluster harness consume)."""
        if isinstance(ev, PreemptionEvent):
            self.stats.preemptions_seen += 1
        elif isinstance(ev, WakeupEvent):
            self.stats.wakeups_seen += 1
        elif isinstance(ev, ReclamationEvent):
            self.stats.invalidation_bursts_seen += 1

    # ------------------------------------------------------------------
    # Registration
    # ------------------------------------------------------------------
    def register(self, engine: Engine, name: Optional[str] = None) -> Engine:
        """Register a pre-built engine (must share this node's runtime)."""
        assert engine.runtime is self.runtime, \
            'engine must be built on this node\'s runtime'
        assert engine.mcfg.page_size == self.pool.page_size, \
            (engine.mcfg.page_size, self.pool.page_size)
        if engine.cfg.klass == 'online':
            assert self.online is None, 'one online engine per node'
            self.online = engine
        else:
            self.offline.append(engine)
        name = name or f'{engine.cfg.klass}:{engine.mcfg.name}' \
                       f'#{len(self.names)}'
        assert name not in self.names, f'duplicate engine name {name!r}'
        self.names[name] = engine
        return engine

    def add_engine(self, model_cfg, engine_cfg: EngineConfig, *,
                   params=None, seed: int = 0,
                   name: Optional[str] = None) -> Engine:
        """Build a model + engine on this node's runtime and register it.
        Heterogeneous colocation = calling this with different model configs
        (page_size must match the shared pool)."""
        model = build_model(model_cfg)
        if params is None:
            params = model.init_params(jax.random.PRNGKey(seed))
        eng = Engine(model, params, None, engine_cfg,
                     runtime=self.runtime, clock=self.clock)
        return self.register(eng, name)

    @property
    def engines(self) -> List[Engine]:
        return ([self.online] if self.online is not None else []) + \
            list(self.offline)

    # ------------------------------------------------------------------
    # Drive loop
    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return any(e.queue or e.running for e in self.engines)

    def step(self) -> bool:
        """One node tick: online first, offline backfill iff gates open."""
        self.stats.steps += 1
        progressed = False
        if self.online is not None and (self.online.queue
                                        or self.online.running):
            if self.online.step():
                progressed = True
                self.stats.online_dispatches += 1
        if any(e.queue or e.running for e in self.offline):
            if self.runtime.offline_may_dispatch():
                # round-robin: try each offline engine once, dispatch the
                # first that makes progress (a memory-blocked engine does
                # not starve its siblings)
                n = len(self.offline)
                for _ in range(n):
                    eng = self.offline[self._rr % n]
                    self._rr += 1
                    if not (eng.queue or eng.running):
                        continue
                    if eng.step():
                        progressed = True
                        self.stats.offline_dispatches += 1
                        break
            else:
                self.stats.gated_skips += 1
        self.runtime.tick()
        if not progressed:
            self.stats.idle_steps += 1
            if self.idle_advance > 0:
                self.clock.sleep(self.idle_advance)
        return progressed

    def run(self, steps: int) -> None:
        for _ in range(steps):
            self.step()

    def drain(self, max_steps: int = 100_000) -> None:
        """Run until every engine's queue and batch are empty."""
        for _ in range(max_steps):
            if not self.has_work():
                return
            self.step()
        raise RuntimeError('drain exceeded max_steps')

    # ------------------------------------------------------------------
    # Metrics (the paper's Fig. 10 axes + serving-plane counters)
    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, object]:
        on_fin = self.online.finished if self.online is not None else []
        ttfts = [r.ttft for r in on_fin if r.ttft is not None]
        tpots = [r.tpot for r in on_fin if r.tpot and r.tpot > 0]
        off_tokens = sum(e.stats.tokens_generated for e in self.offline)
        off_recomp = sum(e.stats.tokens_recomputed for e in self.offline)
        # runtime counters come from the unified telemetry registry (the
        # event-stream fold), not from per-plane stat objects
        tel = self.runtime.telemetry.snapshot()
        return {
            'online_finished': len(on_fin),
            'offline_finished': sum(len(e.finished) for e in self.offline),
            'online_ttft_p50': float(np.median(ttfts)) if ttfts else None,
            'online_tpot_p50': float(np.median(tpots)) if tpots else None,
            'offline_tokens': off_tokens,
            'offline_recomputed_tokens': off_recomp,
            'online_dispatches': self.stats.online_dispatches,
            'offline_dispatches': self.stats.offline_dispatches,
            'gated_skips': self.stats.gated_skips,
            'cancellations': sum(e.stats.cancellations for e in self.engines),
            'compute_preemptions': tel['compute_preemptions'],
            'offline_wakeups': tel['offline_wakeups'],
            'reclamations': tel['reclamations'],
            'max_preemptions_per_request':
                tel['max_preemptions_per_request'],
            'preemption_latency': tel['preemption_latency'],
            # live requests are LEASES now (raw pool owner ids include the
            # memory plane's internal shared-prefix blocks)
            'live_online_requests':
                len(self.runtime.memory.live_leases('online')),
            'live_offline_requests':
                len(self.runtime.memory.live_leases('offline')),
            'engines': {
                name: {
                    'arch': eng.mcfg.name,
                    'klass': eng.cfg.klass,
                    'finished': len(eng.finished),
                    'tokens': eng.stats.tokens_generated,
                    'dispatches': eng.stats.dispatches,
                    'mixed_dispatches': eng.stats.mixed_dispatches,
                    'cancelled': eng.stats.cancellations,
                    # leased pages incl. attached shared-prefix pages
                    # (pool ownership alone would miss attachments)
                    'live_pages': sum(
                        len(r.lease) for r in eng.requests.values()
                        if r.lease is not None and not r.lease.released),
                } for name, eng in self.names.items()
            },
        }
