"""RWKV6 "Finch" — attention-free LM with data-dependent per-channel decay
[arXiv:2404.05892].

Time-mix recurrence per head (K = V = head_dim):
    y_t = r_t · (S_{t-1} + (u ∘ k_t) ⊗ v_t)
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
with w_t = exp(-exp(w_base + tanh(x_w @ A) @ B)) — the data-dependent decay
(the Finch signature).  Token-shift lerp coefficients are static (v5-style);
the per-channel dynamic mix LoRAs of the full release are omitted (recorded in
DESIGN.md) — they do not interact with Valve.

Sequence paths use the *chunked* form (matmul-heavy, MXU-friendly); decode is
the exact single-step recurrence.  The Pallas kernel in kernels/rwkv6 mirrors
the chunked form; this module is its jnp oracle.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common as cm
from repro.models.common import PSpec

LORA_DIM = 32


def template(cfg: ModelConfig) -> Dict[str, Any]:
    L, d, f, v = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.ssm_head_dim
    h = d // hd
    return {
        'embed': PSpec((v, d), ('vocab', 'embed'), scale=d ** -0.5),  # tied-unembed-safe: logits ~O(1)
        'final_norm': PSpec((d,), ('embed',), 'ones'),
        'unembed': PSpec((d, v), ('embed', 'vocab')),
        'layers': {
            'ln1': PSpec((L, d), ('layers', 'embed'), 'ones'),
            'ln2': PSpec((L, d), ('layers', 'embed'), 'ones'),
            # time-mix
            'mu': PSpec((L, 5, d), ('layers', None, 'embed'), 'zeros'),  # r,k,v,w,g
            'w_base': PSpec((L, d), ('layers', 'embed'), 'zeros'),
            'w_A': PSpec((L, d, LORA_DIM), ('layers', 'embed', None)),
            'w_B': PSpec((L, LORA_DIM, d), ('layers', None, 'embed'),
                         scale=0.1),
            'Wr': PSpec((L, d, d), ('layers', 'embed', 'qkv')),
            'Wk': PSpec((L, d, d), ('layers', 'embed', 'qkv')),
            'Wv': PSpec((L, d, d), ('layers', 'embed', 'qkv')),
            'Wg': PSpec((L, d, d), ('layers', 'embed', 'qkv')),
            'Wo': PSpec((L, d, d), ('layers', 'qkv', 'embed')),
            'u': PSpec((L, h, hd), ('layers', 'heads', 'head_dim'), 'zeros'),
            'ln_x': PSpec((L, d), ('layers', 'embed'), 'ones'),
            # channel-mix
            'mu_cm': PSpec((L, 2, d), ('layers', None, 'embed'), 'zeros'),
            'Wk_cm': PSpec((L, d, f), ('layers', 'embed', 'ffn')),
            'Wv_cm': PSpec((L, f, d), ('layers', 'ffn', 'embed')),
            'Wr_cm': PSpec((L, d, d), ('layers', 'embed', 'qkv')),
        },
    }


# ---------------------------------------------------------------------------
# WKV6 core
# ---------------------------------------------------------------------------

def wkv6_step(r, k, v, w, u, state):
    """One recurrence step.  r/k/v/w: (B, H, K); state: (B, H, K, V)."""
    outer = k[..., :, None] * v[..., None, :]              # (B, H, K, V)
    y = jnp.einsum('bhk,bhkv->bhv', r, state + u[..., :, None] * outer)
    new_state = w[..., :, None] * state + outer
    return y, new_state


def wkv6_ref(r, k, v, w, u, state):
    """Naive sequential oracle.  r/k/v/w: (B, T, H, K) f32; state (B, H, K, V)."""
    def body(s, xs):
        rt, kt, vt, wt = xs
        y, s = wkv6_step(rt, kt, vt, wt, u, s)
        return s, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, w))
    state, ys = jax.lax.scan(body, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def wkv6_chunked(r, k, v, w, u, state, *, chunk: int = 32):
    """Chunked-parallel WKV6 (f32).  Matches wkv6_ref.

    Within a chunk (A_t = Π_{τ≤t} w_τ, A_0 = 1):
      y_t = (r_t∘A_{t-1}) · S_in  +  Σ_{i<t} [(r_t∘A_{t-1}/A_i)·k_i] v_i
            + (r_t·(u∘k_t)) v_t
      S_out = A_T ∘ S_in + Σ_i (A_T/A_i) k_i ⊗ v_i
    """
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    if t % chunk:
        pad = chunk - t % chunk
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n = r.shape[1] // chunk
    resh = lambda x: x.reshape(b, n, chunk, h, x.shape[-1]).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)   # (n, B, H, c, K)

    logw = jnp.log(jnp.maximum(wc, 1e-30))
    logA = jnp.cumsum(logw, axis=-2)                      # inclusive (n,B,H,c,K)
    A = jnp.exp(logA)
    A_prev = jnp.exp(logA - logw)                         # A_{t-1}
    A_end = A[..., -1:, :]                                # (n,B,H,1,K)

    r_dec = rc * A_prev                                   # r_t ∘ A_{t-1}
    k_end = kc * jnp.exp(logA[..., -1:, :] - logA)        # (A_T/A_i) k_i
    # midpoint-normalized factors for the intra-chunk scores: the raw
    # factored form overflows f32 once the in-chunk decay range exceeds
    # ~85 nats (see kernels/rwkv6/kernel.py) — normalize both sides by
    # A_{mid} so each factor is bounded by exp(range/2)
    mid = logA[..., chunk // 2 : chunk // 2 + 1, :]
    r_dec_m = rc * jnp.exp(logA - logw - mid)
    k_inc_m = kc * jnp.exp(mid - logA)

    # strictly-causal intra-chunk scores
    scores = jnp.einsum('nbhtk,nbhsk->nbhts', r_dec_m, k_inc_m)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    scores = jnp.where(mask, scores, 0.0)
    y_intra = jnp.einsum('nbhts,nbhsv->nbhtv', scores, vc)
    y_diag = jnp.einsum('nbhtk,nbhtv->nbhtv',
                        rc * (u[None, None, :, None, :] * kc), vc)
    chunk_states = jnp.einsum('nbhsk,nbhsv->nbhkv', k_end, vc)

    def body(s, xs):
        rd, a_end, cs = xs
        y_in = jnp.einsum('bhtk,bhkv->bhtv', rd, s)
        s = a_end[..., 0, :, None] * s + cs
        return s, y_in

    state, y_inter = jax.lax.scan(body, state, (r_dec, A_end, chunk_states))
    y = y_intra + y_diag + y_inter                        # (n,B,H,c,V)
    y = y.transpose(1, 0, 3, 2, 4).reshape(b, n * chunk, h, dv)
    return y[:, :t], state


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _shift(x, last):
    """Token shift: x_{t-1}, with ``last`` filling t=0.  x: (B, T, D)."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def time_mix(cfg: ModelConfig, lp, x, shift_state, wkv_state, *, use_kernel=False):
    b, t, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    xs = _shift(x, shift_state)
    mu = lp['mu']
    mix = lambda i: x + (xs - x) * mu[i]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ lp['Wr']).reshape(b, t, h, hd)
    k = (xk @ lp['Wk']).reshape(b, t, h, hd)
    v = (xv @ lp['Wv']).reshape(b, t, h, hd)
    g = xg @ lp['Wg']
    w_raw = (lp['w_base'].astype(jnp.float32)
             + jnp.tanh(xw.astype(jnp.float32) @ lp['w_A'].astype(jnp.float32))
             @ lp['w_B'].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_raw)).reshape(b, t, h, hd)     # (0,1), data-dependent

    f32 = lambda a: a.astype(jnp.float32)
    if t == 1:
        y, wkv_state = wkv6_step(f32(r[:, 0]), f32(k[:, 0]), f32(v[:, 0]),
                                 w[:, 0], f32(lp['u']), wkv_state)
        y = y[:, None]
    elif use_kernel:
        # call the kernel directly, not the jitted ops wrapper: a nested
        # jit inside a scan body trips jax's closed_call lowering cache
        from repro.kernels.rwkv6.kernel import wkv6_bthk
        y, wkv_state = wkv6_bthk(
            f32(r), f32(k), f32(v), w, f32(lp['u']), wkv_state,
            interpret=jax.default_backend() == 'cpu')
    else:
        y, wkv_state = wkv6_chunked(f32(r), f32(k), f32(v), w,
                                    f32(lp['u']), wkv_state)
    # per-head group norm, then gate
    y = cm.rms_norm(y, jnp.ones((hd,), y.dtype), 64e-5)
    y = y.reshape(b, t, d).astype(x.dtype) * lp['ln_x']
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = y @ lp['Wo']
    return out, x[:, -1, :], wkv_state


def channel_mix(cfg: ModelConfig, lp, x, shift_state):
    xs = _shift(x, shift_state)
    mu = lp['mu_cm']
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ lp['Wk_cm']))
    k = constrain(k, ('batch', 'seq', 'ffn'))
    out = jax.nn.sigmoid((xr @ lp['Wr_cm']).astype(jnp.float32)).astype(x.dtype) \
        * (k @ lp['Wv_cm'])
    return out, x[:, -1, :]


def layer_apply(cfg: ModelConfig, lp, h, cache_l, *, use_kernel=False):
    x = cm.rms_norm(h, lp['ln1'], cfg.norm_eps)
    tm_out, new_shift_tm, new_wkv = time_mix(
        cfg, lp, x, cache_l['shift_tm'], cache_l['wkv'], use_kernel=use_kernel)
    h = h + tm_out
    h = constrain(h, ('batch', 'seq', 'embed'))
    x = cm.rms_norm(h, lp['ln2'], cfg.norm_eps)
    cm_out, new_shift_cm = channel_mix(cfg, lp, x, cache_l['shift_cm'])
    h = h + cm_out
    h = constrain(h, ('batch', 'seq', 'embed'))
    return h, {'wkv': new_wkv, 'shift_tm': new_shift_tm,
               'shift_cm': new_shift_cm}


def scan_layers(cfg: ModelConfig, layers, h, cache, *, remat=True,
                use_kernel=False):
    def body(carry, xs):
        lp, cache_l = xs
        out, new_cache_l = layer_apply(cfg, lp, carry, cache_l,
                                       use_kernel=use_kernel)
        return out, new_cache_l

    if remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, h, (layers, cache))


def init_state(cfg: ModelConfig, batch_size: int):
    d, hd = cfg.d_model, cfg.ssm_head_dim
    h = d // hd
    L = cfg.n_layers
    return {
        'wkv': jnp.zeros((L, batch_size, h, hd, hd), jnp.float32),
        'shift_tm': jnp.zeros((L, batch_size, d), cm.DEFAULT_DTYPE),
        'shift_cm': jnp.zeros((L, batch_size, d), cm.DEFAULT_DTYPE),
    }


def cache_template(cfg: ModelConfig, batch_size: int) -> Dict[str, PSpec]:
    d, hd = cfg.d_model, cfg.ssm_head_dim
    h = d // hd
    L = cfg.n_layers
    return {
        'wkv': PSpec((L, batch_size, h, hd, hd),
                     ('layers', 'batch', 'heads', None, None), 'zeros',
                     dtype=jnp.float32),
        'shift_tm': PSpec((L, batch_size, d), ('layers', 'batch', 'embed'),
                          'zeros'),
        'shift_cm': PSpec((L, batch_size, d), ('layers', 'batch', 'embed'),
                          'zeros'),
    }


def forward_train(cfg: ModelConfig, params, batch, *, remat=True,
                  use_kernel=False):
    tokens = batch['tokens']
    b, s = tokens.shape
    h = params['embed'][tokens]
    h = constrain(h, ('batch', 'seq', 'embed'))
    cache = init_state(cfg, b)
    h, _ = scan_layers(cfg, params['layers'], h, cache, remat=remat,
                       use_kernel=use_kernel)
    nll, cnt = cm.chunked_ce_loss(
        h, params['final_norm'], params['unembed'], batch['labels'],
        mask=batch.get('loss_mask'), eps=cfg.norm_eps)
    return nll / jnp.maximum(cnt, 1.0), {'tokens': cnt}


def prefill(cfg: ModelConfig, params, cache, batch):
    tokens = batch['tokens']
    h = params['embed'][tokens]
    h = constrain(h, ('batch', 'seq', 'embed'))
    h, cache = scan_layers(cfg, params['layers'], h, cache, remat=False)
    last = cm.rms_norm(h[:, -1], params['final_norm'], cfg.norm_eps)
    logits = last @ params['unembed']
    return cache, constrain(logits, ('batch', 'vocab'))


def decode_step(cfg: ModelConfig, params, cache, batch):
    tokens = batch['tokens']
    h = params['embed'][tokens][:, None, :]
    h = constrain(h, ('batch', 'seq', 'embed'))
    h, cache = scan_layers(cfg, params['layers'], h, cache, remat=False)
    last = cm.rms_norm(h[:, 0], params['final_norm'], cfg.norm_eps)
    logits = last @ params['unembed']
    return cache, constrain(logits, ('batch', 'vocab'))
