"""Decoder-only dense transformer (internlm2, command-r, qwen3-14b/0.6b,
llava-next backbone, valve-7b).

Three execution paths share one layer definition:
- ``forward_train``: full causal self-attention, scan-over-layers + remat,
  chunked CE loss (logits never materialize at (B, S, V)).
- ``prefill``: causal self-attention over the prompt, K/V written into the
  paged pool through the page table.
- ``decode_step``: one token per request, paged-attention read path (the
  tensors Valve's reclamation remaps live here).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common as cm
from repro.models.common import PSpec


# ---------------------------------------------------------------------------
# Template
# ---------------------------------------------------------------------------

def attn_template(cfg: ModelConfig, L: int, d_in: Optional[int] = None,
                  heads: Optional[int] = None, head_dim: Optional[int] = None,
                  kv_heads: Optional[int] = None) -> Dict[str, PSpec]:
    d = d_in if d_in is not None else cfg.d_model
    h = heads if heads is not None else cfg.n_heads
    hkv = kv_heads if kv_heads is not None else cfg.n_kv_heads
    hd = head_dim if head_dim is not None else cfg.hd
    t = {
        'wq': PSpec((L, d, h * hd), ('layers', 'embed', 'qkv')),
        'wk': PSpec((L, d, hkv * hd), ('layers', 'embed', 'qkv')),
        'wv': PSpec((L, d, hkv * hd), ('layers', 'embed', 'qkv')),
        'wo': PSpec((L, h * hd, cfg.d_model), ('layers', 'qkv', 'embed')),
    }
    if cfg.attn_bias:
        t['bq'] = PSpec((L, h * hd), ('layers', 'qkv'), 'zeros')
        t['bk'] = PSpec((L, hkv * hd), ('layers', 'qkv'), 'zeros')
        t['bv'] = PSpec((L, hkv * hd), ('layers', 'qkv'), 'zeros')
    if cfg.qk_norm:
        t['q_norm'] = PSpec((L, hd), ('layers', 'head_dim'), 'ones')
        t['k_norm'] = PSpec((L, hd), ('layers', 'head_dim'), 'ones')
    return t


def mlp_template(cfg: ModelConfig, L: int) -> Dict[str, PSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        'wg': PSpec((L, d, f), ('layers', 'embed', 'ffn')),
        'wu': PSpec((L, d, f), ('layers', 'embed', 'ffn')),
        'wd': PSpec((L, f, d), ('layers', 'ffn', 'embed')),
    }


def template(cfg: ModelConfig) -> Dict[str, Any]:
    L, d, v = cfg.n_layers, cfg.d_model, cfg.vocab_size
    t: Dict[str, Any] = {
        'embed': PSpec((v, d), ('vocab', 'embed'), scale=d ** -0.5),  # tied-unembed-safe: logits ~O(1)
        'final_norm': PSpec((d,), ('embed',), 'ones'),
        'layers': {
            'ln1': PSpec((L, d), ('layers', 'embed'), 'ones'),
            'ln2': PSpec((L, d), ('layers', 'embed'), 'ones'),
            **attn_template(cfg, L),
            **mlp_template(cfg, L),
        },
    }
    if not cfg.tie_embeddings:
        t['unembed'] = PSpec((d, v), ('embed', 'vocab'))
    return t


def unembed_of(cfg: ModelConfig, params):
    return params['embed'].T if cfg.tie_embeddings else params['unembed']


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def qkv_proj(cfg: ModelConfig, lp, x, positions, *, heads=None, head_dim=None,
             kv_heads=None, rope_theta=None, use_rope=True):
    b, s, _ = x.shape
    h = heads if heads is not None else cfg.n_heads
    hd = head_dim if head_dim is not None else cfg.hd
    hkv = kv_heads if kv_heads is not None else cfg.n_kv_heads
    q = x @ lp['wq']
    k = x @ lp['wk']
    v = x @ lp['wv']
    if cfg.attn_bias and 'bq' in lp:
        q, k, v = q + lp['bq'], k + lp['bk'], v + lp['bv']
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = constrain(q, ('batch', 'seq', 'heads', 'head_dim'))
    k = constrain(k, ('batch', 'seq', 'kv_heads', 'head_dim'))
    v = constrain(v, ('batch', 'seq', 'kv_heads', 'head_dim'))
    if cfg.qk_norm and 'q_norm' in lp:
        q = cm.rms_norm(q, lp['q_norm'], cfg.norm_eps)
        k = cm.rms_norm(k, lp['k_norm'], cfg.norm_eps)
    if use_rope:
        theta = rope_theta if rope_theta is not None else cfg.rope_theta
        q = cm.rope(q, positions, theta)
        k = cm.rope(k, positions, theta)
    return q, k, v


def self_attn_train(cfg: ModelConfig, lp, x, positions):
    q, k, v = qkv_proj(cfg, lp, x, positions)
    out = cm.chunked_attention(q, k, v, q_positions=positions,
                               kv_positions=positions, causal=True)
    b, s, _, _ = out.shape
    out = out.reshape(b, s, -1)
    out = constrain(out, ('batch', 'seq', 'qkv'))
    return out @ lp['wo']


def self_attn_prefill(cfg: ModelConfig, lp, x, positions, pool_k, pool_v,
                      page_table, *, use_pallas: bool = False):
    q, k, v = qkv_proj(cfg, lp, x, positions)
    pool_k = cm.kv_write_prefill(pool_k, page_table, k)
    pool_v = cm.kv_write_prefill(pool_v, page_table, v)
    if use_pallas:
        # serving hot spot: flash kernel keeps scores in VMEM (no grad
        # needed on the prefill path); interpret=None auto-falls back to
        # the Pallas interpreter off-TPU (kernels.common.resolve_interpret)
        from repro.kernels.common import pick_block
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=True,
                              block_q=pick_block(q.shape[1], 128),
                              block_k=pick_block(k.shape[1], 128))
    else:
        out = cm.chunked_attention(q, k, v, q_positions=positions,
                                   kv_positions=positions, causal=True)
    b, s, _, _ = out.shape
    out = out.reshape(b, s, -1)
    out = constrain(out, ('batch', 'seq', 'qkv'))
    return out @ lp['wo'], pool_k, pool_v


def self_attn_decode(cfg: ModelConfig, lp, x, positions, pool_k, pool_v,
                     page_table, *, use_pallas: bool = False, shared=None):
    """x: (B, 1, D); positions: (B,) index of the new token.

    ``shared`` (optional) is the deduplicated shared-prefix run structure
    from ``kernels.paged_attention.prefix.build_shared_runs``: when the
    engine's decode batch holds copy-on-write shared prefixes, attention
    reads each shared physical page once per batch instead of once per
    request (the original per-request ``page_table`` is still what the KV
    *write* above indexes — only the read path is deduplicated).
    """
    b = x.shape[0]
    pg = pool_k.shape[-3]   # page size (layout-agnostic: global 4-D / region 5-D)
    q, k, v = qkv_proj(cfg, lp, x, positions[:, None])
    page_idx = jnp.take_along_axis(
        page_table, (positions // pg)[:, None], axis=1)[:, 0]
    offs = positions % pg
    pool_k = cm.kv_write_token(pool_k, page_idx, offs, k[:, 0])
    pool_v = cm.kv_write_token(pool_v, page_idx, offs, v[:, 0])
    if shared is not None:
        from repro.kernels.paged_attention.ops import (
            paged_attention_prefix_shared)
        out = paged_attention_prefix_shared(
            q[:, 0], pool_k, pool_v, shared['pages'], shared['pos'],
            shared['mask'], shared['tail_pt'], shared['start'],
            positions + 1)
    elif use_pallas:
        # decode hot path: pages stream HBM→VMEM through the page table
        # instead of gathering the full (B, maxp·pg, Hkv, Dh) KV (the
        # oracle path below); falls back to the ref for the region layout
        from repro.kernels.paged_attention.ops import paged_attention_decode
        out = paged_attention_decode(q[:, 0], pool_k, pool_v, page_table,
                                     positions + 1)
    else:
        out = cm.paged_attention_ref(q[:, 0], pool_k, pool_v, page_table,
                                     positions + 1)
    out = out.reshape(b, 1, -1)
    out = constrain(out, ('batch', 'seq', 'qkv'))
    return out @ lp['wo'], pool_k, pool_v


def layer_apply(cfg: ModelConfig, lp, h, positions, mode: str,
                cache_l: Optional[Dict[str, jax.Array]] = None,
                page_table=None, use_pallas: bool = False, shared=None):
    x = cm.rms_norm(h, lp['ln1'], cfg.norm_eps)
    new_cache_l = cache_l
    if mode == 'train':
        attn_out = self_attn_train(cfg, lp, x, positions)
    elif mode == 'prefill':
        attn_out, pk, pv = self_attn_prefill(
            cfg, lp, x, positions, cache_l['k'], cache_l['v'], page_table,
            use_pallas=use_pallas)
        new_cache_l = {'k': pk, 'v': pv}
    elif mode == 'decode':
        attn_out, pk, pv = self_attn_decode(
            cfg, lp, x, positions, cache_l['k'], cache_l['v'], page_table,
            use_pallas=use_pallas, shared=shared)
        new_cache_l = {'k': pk, 'v': pv}
    else:
        raise ValueError(mode)
    h = h + attn_out
    h = constrain(h, ('batch', 'seq', 'embed'))
    x = cm.rms_norm(h, lp['ln2'], cfg.norm_eps)
    h = h + cm.swiglu(x, lp['wg'], lp['wu'], lp['wd'])
    h = constrain(h, ('batch', 'seq', 'embed'))
    return h, new_cache_l


def scan_layers(cfg: ModelConfig, layers, h, positions, mode: str,
                cache=None, page_table=None, remat: bool = True,
                use_pallas: bool = False, shared=None):
    def body(carry, xs):
        lp, cache_l = xs
        out, new_cache_l = layer_apply(cfg, lp, carry, positions, mode,
                                       cache_l, page_table,
                                       use_pallas=use_pallas, shared=shared)
        return out, new_cache_l

    if remat and mode == 'train':
        body = jax.checkpoint(body)
    h, new_cache = jax.lax.scan(body, h, (layers, cache))
    return h, new_cache


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, tokens, prefix_embeds=None):
    h = params['embed'][tokens]
    if prefix_embeds is not None:
        p = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h[:, p:]], axis=1)
    return constrain(h, ('batch', 'seq', 'embed'))


def forward_train(cfg: ModelConfig, params, batch, *, remat: bool = True):
    tokens = batch['tokens']
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = embed_inputs(cfg, params, tokens, batch.get('prefix_embeds'))
    h, _ = scan_layers(cfg, params['layers'], h, positions, 'train',
                       cache=None, remat=remat)
    nll, cnt = cm.chunked_ce_loss(
        h, params['final_norm'], unembed_of(cfg, params),
        batch['labels'], mask=batch.get('loss_mask'), eps=cfg.norm_eps)
    return nll / jnp.maximum(cnt, 1.0), {'tokens': cnt}


def prefill(cfg: ModelConfig, params, cache, batch, *,
            use_pallas: bool = False):
    tokens = batch['tokens']
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = embed_inputs(cfg, params, tokens, batch.get('prefix_embeds'))
    h, cache = scan_layers(cfg, params['layers'], h, positions, 'prefill',
                           cache=cache, page_table=batch['page_table'],
                           remat=False, use_pallas=use_pallas)
    last = cm.rms_norm(h[:, -1], params['final_norm'], cfg.norm_eps)
    logits = last @ unembed_of(cfg, params)
    return cache, constrain(logits, ('batch', 'vocab'))


def self_attn_prefill_chunk(cfg: ModelConfig, lp, x, positions, pool_k, pool_v,
                            page_table, page_ids, offsets, kv_len):
    """One prefill *chunk* with past-KV readback.

    x: (B, C, D) chunk hidden; positions: (B, C) absolute positions
    (padding repeats the last real position); page_ids/offsets: (B, C)
    per-token write targets (padding → quarantine page 0); kv_len: (B,)
    total valid tokens after this chunk.
    """
    q, k, v = qkv_proj(cfg, lp, x, positions)
    pool_k = cm.kv_write_tokens(pool_k, page_ids, offsets, k)
    pool_v = cm.kv_write_tokens(pool_v, page_ids, offsets, v)
    kg = cm.kv_gather(pool_k, page_table)    # (B, maxp*pg, Hkv, Dh)
    vg = cm.kv_gather(pool_v, page_table)
    b, skv = kg.shape[:2]
    kv_pos = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (b, skv))
    valid = kv_pos < kv_len[:, None]
    out = cm.attention(q, kg, vg, q_positions=positions, kv_positions=kv_pos,
                       kv_valid=valid, causal=True)
    c = x.shape[1]
    out = out.reshape(b, c, -1)
    out = constrain(out, ('batch', 'seq', 'qkv'))
    return out @ lp['wo'], pool_k, pool_v


def prefill_chunk(cfg: ModelConfig, params, cache, batch):
    """Chunked prefill step (the offline engine's preemptible dispatch unit).

    batch: tokens (B, C), positions (B, C), page_table (B, maxp),
    page_ids/offsets (B, C), kv_len (B,), last_idx (B,) index of the last
    real token inside the chunk.  Returns (cache, logits at last_idx).
    """
    tokens = batch['tokens']
    positions = batch['positions']
    h = embed_inputs(cfg, params, tokens, batch.get('prefix_embeds'))

    def body(carry, xs):
        lp, cache_l = xs
        x = cm.rms_norm(carry, lp['ln1'], cfg.norm_eps)
        attn_out, pk, pv = self_attn_prefill_chunk(
            cfg, lp, x, positions, cache_l['k'], cache_l['v'],
            batch['page_table'], batch['page_ids'], batch['offsets'],
            batch['kv_len'])
        hh = carry + attn_out
        x = cm.rms_norm(hh, lp['ln2'], cfg.norm_eps)
        hh = hh + cm.swiglu(x, lp['wg'], lp['wu'], lp['wd'])
        return hh, {'k': pk, 'v': pv}

    h, cache = jax.lax.scan(body, h, (params['layers'], cache))
    last = jnp.take_along_axis(h, batch['last_idx'][:, None, None], axis=1)[:, 0]
    last = cm.rms_norm(last, params['final_norm'], cfg.norm_eps)
    logits = last @ unembed_of(cfg, params)
    return cache, constrain(logits, ('batch', 'vocab'))


def decode_step(cfg: ModelConfig, params, cache, batch, *,
                use_pallas: bool = False):
    tokens = batch['tokens']            # (B,)
    positions = batch['positions']      # (B,) index of the new token
    h = params['embed'][tokens][:, None, :]
    h = constrain(h, ('batch', 'seq', 'embed'))
    h, cache = scan_layers(cfg, params['layers'], h, positions, 'decode',
                           cache=cache, page_table=batch['page_table'],
                           remat=False, use_pallas=use_pallas,
                           shared=batch.get('shared'))
    last = cm.rms_norm(h[:, 0], params['final_norm'], cfg.norm_eps)
    logits = last @ unembed_of(cfg, params)
    return cache, constrain(logits, ('batch', 'vocab'))


def decode_step_sample(cfg: ModelConfig, params, cache, batch, *,
                       use_pallas: bool = False, temperature: float = 0.0):
    """``decode_step`` with the sampling tail fused into the unembed.

    Instead of returning (B, V) logits for a separate sampling dispatch,
    the final-norm hidden goes straight into the fused unembed+argmax
    reduction (``kernels.sampling``) and (cache, (B,) int32 tokens) comes
    back — logits never materialize in HBM and the engine can keep the
    sampled token on device for the next iteration.  Greedy output is
    bit-identical to ``argmax`` over ``decode_step``'s logits; temperature
    sampling uses counter-hash Gumbel noise seeded by ``batch['seed']``.
    """
    tokens = batch['tokens']            # (B,)
    positions = batch['positions']      # (B,) index of the new token
    h = params['embed'][tokens][:, None, :]
    h = constrain(h, ('batch', 'seq', 'embed'))
    h, cache = scan_layers(cfg, params['layers'], h, positions, 'decode',
                           cache=cache, page_table=batch['page_table'],
                           remat=False, use_pallas=use_pallas,
                           shared=batch.get('shared'))
    last = cm.rms_norm(h[:, 0], params['final_norm'], cfg.norm_eps)
    from repro.kernels.sampling.ops import fused_unembed_sample
    toks = fused_unembed_sample(last, unembed_of(cfg, params),
                                batch.get('seed', 0),
                                temperature=temperature)
    return cache, toks


# ---------------------------------------------------------------------------
# Cache template
# ---------------------------------------------------------------------------

def cache_template(cfg: ModelConfig, n_pages: int,
                   batch: Optional[int] = None) -> Dict[str, PSpec]:
    """Paged KV pool.

    ``batch=None`` → global pool (P, pg, Hkv, Dh) per layer: the engine layout
    Valve's handles/quarantine operate on (page 0 = quarantine).
    ``batch=B`` → per-request region layout (B, R, pg, Hkv, Dh): the
    SPMD-clean distributed layout (region slot 0 = quarantine).
    """
    if batch is None:
        shape = (cfg.n_layers, n_pages, cfg.page_size, cfg.n_kv_heads, cfg.hd)
        axes = ('layers', 'pages', None, 'kv_heads', 'head_dim')
    else:
        shape = (cfg.n_layers, batch, n_pages, cfg.page_size,
                 cfg.n_kv_heads, cfg.hd)
        axes = ('layers', 'batch', 'pages', None, 'kv_heads', 'head_dim')
    return {'k': PSpec(shape, axes, 'zeros'), 'v': PSpec(shape, axes, 'zeros')}
