"""Mixture-of-Experts decoder (phi3.5-moe 16e top-2, llama4-scout 16e top-1 +
shared expert).

Routing is capacity-based (Switch-style): tokens are ranked within their
assigned expert by a cumulative-sum position, dispatched into dense (E, C, D)
buffers (expert dim sharded over the model axis → expert parallelism), and
combined back with router weights.  Overflow tokens are dropped (standard
capacity-factor semantics); the load-balancing auxiliary loss keeps the router
near-uniform so drops stay rare.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common as cm
from repro.models import dense
from repro.models.common import PSpec


def template(cfg: ModelConfig) -> Dict[str, Any]:
    L, d, f, e = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    t = dense.template(cfg)
    layers = t['layers']
    for k in ('wg', 'wu', 'wd'):
        del layers[k]
    layers['router'] = PSpec((L, d, e), ('layers', 'embed', 'expert'),
                             scale=d ** -0.5)
    layers['we_gate'] = PSpec((L, e, d, f), ('layers', 'expert', 'embed', 'ffn'))
    layers['we_up'] = PSpec((L, e, d, f), ('layers', 'expert', 'embed', 'ffn'))
    layers['we_down'] = PSpec((L, e, f, d), ('layers', 'expert', 'ffn', 'embed'))
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        layers['ws_gate'] = PSpec((L, d, fs), ('layers', 'embed', 'ffn'))
        layers['ws_up'] = PSpec((L, d, fs), ('layers', 'embed', 'ffn'))
        layers['ws_down'] = PSpec((L, fs, d), ('layers', 'ffn', 'embed'))
    return t


def moe_mlp(cfg: ModelConfig, lp, x, *, capacity_factor: float = 1.25):
    """x: (B, S, D) → (B, S, D), aux load-balance loss (f32 scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    n = b * s
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ lp['router'].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)           # (N, E)
    top_w, top_i = jax.lax.top_k(probs, k)            # (N, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(-1)                        # (N*k,) token-major
    flat_w = top_w.reshape(-1)
    tok_ids = jnp.arange(n * k, dtype=jnp.int32) // k

    cap = int(math.ceil(k * n / e * capacity_factor))
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1   # rank in expert
    keep = pos < cap
    dest_c = jnp.where(keep, pos, cap)                # cap → dropped (oob)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, dest_c].set(xf[tok_ids], mode='drop')
    buf = constrain(buf, ('expert', None, 'embed'))

    g = jnp.einsum('ecd,edf->ecf', buf, lp['we_gate'])
    u = jnp.einsum('ecd,edf->ecf', buf, lp['we_up'])
    g = constrain(g, ('expert', None, 'ffn'))
    u = constrain(u, ('expert', None, 'ffn'))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = jnp.einsum('ecf,efd->ecd', h, lp['we_down'])
    out = constrain(out, ('expert', None, 'embed'))

    gathered = out[flat_e, jnp.minimum(dest_c, cap - 1)]      # (N*k, D)
    contrib = jnp.where(keep[:, None], gathered * flat_w[:, None].astype(x.dtype),
                        jnp.zeros_like(gathered))
    y = jnp.zeros((n, d), x.dtype).at[tok_ids].add(contrib)

    if cfg.n_shared_experts:
        y = y + cm.swiglu(xf, lp['ws_gate'], lp['ws_up'], lp['ws_down'])

    # Load-balance aux loss (Switch eq. 4): E * Σ_e f_e · P_e
    frac = jnp.mean(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=(0, 1))
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)
    return y.reshape(b, s, d), aux


def layer_apply(cfg: ModelConfig, lp, h, positions, mode: str,
                cache_l=None, page_table=None, capacity_factor: float = 1.25,
                use_pallas: bool = False):
    x = cm.rms_norm(h, lp['ln1'], cfg.norm_eps)
    new_cache_l = cache_l
    if mode == 'train':
        attn_out = dense.self_attn_train(cfg, lp, x, positions)
    elif mode == 'prefill':
        attn_out, pk, pv = dense.self_attn_prefill(
            cfg, lp, x, positions, cache_l['k'], cache_l['v'], page_table)
        new_cache_l = {'k': pk, 'v': pv}
    else:
        attn_out, pk, pv = dense.self_attn_decode(
            cfg, lp, x, positions, cache_l['k'], cache_l['v'], page_table,
            use_pallas=use_pallas)
        new_cache_l = {'k': pk, 'v': pv}
    h = h + attn_out
    h = constrain(h, ('batch', 'seq', 'embed'))
    x = cm.rms_norm(h, lp['ln2'], cfg.norm_eps)
    mlp_out, aux = moe_mlp(cfg, lp, x, capacity_factor=capacity_factor)
    h = h + mlp_out
    h = constrain(h, ('batch', 'seq', 'embed'))
    return h, new_cache_l, aux


def scan_layers(cfg: ModelConfig, layers, h, positions, mode: str,
                cache=None, page_table=None, remat: bool = True,
                capacity_factor: float = 1.25, use_pallas: bool = False):
    def body(carry, xs):
        hh, aux_sum = carry
        lp, cache_l = xs
        out, new_cache_l, aux = layer_apply(
            cfg, lp, hh, positions, mode, cache_l, page_table,
            capacity_factor=capacity_factor, use_pallas=use_pallas)
        return (out, aux_sum + aux), new_cache_l

    if remat and mode == 'train':
        body = jax.checkpoint(body)
    (h, aux), new_cache = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), (layers, cache))
    return h, new_cache, aux / cfg.n_layers


def forward_train(cfg: ModelConfig, params, batch, *, remat: bool = True,
                  aux_weight: float = 0.01):
    tokens = batch['tokens']
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = dense.embed_inputs(cfg, params, tokens, batch.get('prefix_embeds'))
    h, _, aux = scan_layers(cfg, params['layers'], h, positions, 'train',
                            remat=remat)
    nll, cnt = cm.chunked_ce_loss(
        h, params['final_norm'], dense.unembed_of(cfg, params),
        batch['labels'], mask=batch.get('loss_mask'), eps=cfg.norm_eps)
    loss = nll / jnp.maximum(cnt, 1.0) + aux_weight * aux
    return loss, {'tokens': cnt, 'aux_loss': aux}


def prefill(cfg: ModelConfig, params, cache, batch):
    tokens = batch['tokens']
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = dense.embed_inputs(cfg, params, tokens, batch.get('prefix_embeds'))
    h, cache, _ = scan_layers(cfg, params['layers'], h, positions, 'prefill',
                              cache=cache, page_table=batch['page_table'],
                              remat=False)
    last = cm.rms_norm(h[:, -1], params['final_norm'], cfg.norm_eps)
    logits = last @ dense.unembed_of(cfg, params)
    return cache, constrain(logits, ('batch', 'vocab'))


def prefill_chunk(cfg: ModelConfig, params, cache, batch):
    """Chunked prefill with past-KV readback (see dense.prefill_chunk)."""
    tokens = batch['tokens']
    positions = batch['positions']
    h = dense.embed_inputs(cfg, params, tokens, batch.get('prefix_embeds'))

    def body(carry, xs):
        lp, cache_l = xs
        x = cm.rms_norm(carry, lp['ln1'], cfg.norm_eps)
        attn_out, pk, pv = dense.self_attn_prefill_chunk(
            cfg, lp, x, positions, cache_l['k'], cache_l['v'],
            batch['page_table'], batch['page_ids'], batch['offsets'],
            batch['kv_len'])
        hh = carry + attn_out
        x = cm.rms_norm(hh, lp['ln2'], cfg.norm_eps)
        mlp_out, _ = moe_mlp(cfg, lp, x, capacity_factor=2.0)
        return hh + mlp_out, {'k': pk, 'v': pv}

    h, cache = jax.lax.scan(body, h, (params['layers'], cache))
    last = jnp.take_along_axis(h, batch['last_idx'][:, None, None], axis=1)[:, 0]
    last = cm.rms_norm(last, params['final_norm'], cfg.norm_eps)
    logits = last @ dense.unembed_of(cfg, params)
    return cache, constrain(logits, ('batch', 'vocab'))


def decode_step(cfg: ModelConfig, params, cache, batch, *,
                use_pallas: bool = False):
    tokens = batch['tokens']
    positions = batch['positions']
    h = params['embed'][tokens][:, None, :]
    h = constrain(h, ('batch', 'seq', 'embed'))
    h, cache, _ = scan_layers(cfg, params['layers'], h, positions, 'decode',
                              cache=cache, page_table=batch['page_table'],
                              remat=False, capacity_factor=2.0,
                              use_pallas=use_pallas)
    last = cm.rms_norm(h[:, 0], params['final_norm'], cfg.norm_eps)
    logits = last @ dense.unembed_of(cfg, params)
    return cache, constrain(logits, ('batch', 'vocab'))


cache_template = dense.cache_template
