"""Zamba2 — hybrid Mamba2 backbone with a single SHARED attention+MLP block
applied every ``hybrid_attn_every`` layers [arXiv:2411.15242].

Mamba2 sequence paths use the chunked SSD form (intra-chunk "attention-like"
matmuls + an inter-chunk state scan) — sub-quadratic and MXU-friendly; decode
is the exact single-step recurrence.  The shared block attends over
concat(hidden, initial_embedding) (width 2·d_model) with one parameter set
reused at every application; its KV caches (one per application) are paged —
they are the tensors Valve reclaims for this architecture.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common as cm
from repro.models.common import PSpec

SSD_CHUNK = 128


def n_attn_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid_attn_every


def _dims(cfg: ModelConfig):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim
    h = d_in // hd
    n = cfg.ssm_state
    return d, d_in, hd, h, n


def template(cfg: ModelConfig) -> Dict[str, Any]:
    d, d_in, hd, h, n = _dims(cfg)
    L, v = cfg.n_layers, cfg.vocab_size
    conv_ch = d_in + 2 * n
    d2 = 2 * d
    ah = cfg.hybrid_attn_heads
    ahd = d2 // ah
    t: Dict[str, Any] = {
        'embed': PSpec((v, d), ('vocab', 'embed'), scale=d ** -0.5),  # tied-unembed-safe: logits ~O(1)
        'final_norm': PSpec((d,), ('embed',), 'ones'),
        'layers': {
            'ln': PSpec((L, d), ('layers', 'embed'), 'ones'),
            'in_proj': PSpec((L, d, 2 * d_in + 2 * n + h),
                             ('layers', 'embed', 'qkv')),
            'conv_w': PSpec((L, cfg.conv_kernel, conv_ch),
                            ('layers', None, 'qkv'), scale=0.5),
            'conv_b': PSpec((L, conv_ch), ('layers', 'qkv'), 'zeros'),
            'A_log': PSpec((L, h), ('layers', 'heads'), 'zeros'),
            'dt_bias': PSpec((L, h), ('layers', 'heads'), 'zeros'),
            'D': PSpec((L, h), ('layers', 'heads'), 'ones'),
            'norm': PSpec((L, d_in), ('layers', 'qkv'), 'ones'),
            'out_proj': PSpec((L, d_in, d), ('layers', 'qkv', 'embed')),
        },
        # ONE shared attention+MLP block (paper: reused with fresh KV per app)
        'shared': {
            'ln1': PSpec((d2,), ('embed',), 'ones'),
            'wq': PSpec((d2, ah * ahd), ('embed', 'qkv')),
            'wk': PSpec((d2, ah * ahd), ('embed', 'qkv')),
            'wv': PSpec((d2, ah * ahd), ('embed', 'qkv')),
            'wo': PSpec((ah * ahd, d), ('qkv', 'embed')),
            'ln2': PSpec((d2,), ('embed',), 'ones'),
            'wg': PSpec((d2, cfg.hybrid_attn_d_ff), ('embed', 'ffn')),
            'wu': PSpec((d2, cfg.hybrid_attn_d_ff), ('embed', 'ffn')),
            'wd': PSpec((cfg.hybrid_attn_d_ff, d), ('ffn', 'embed')),
        },
    }
    if not cfg.tie_embeddings:
        t['unembed'] = PSpec((d, v), ('embed', 'vocab'))
    return t


def unembed_of(cfg, params):
    return params['embed'].T if cfg.tie_embeddings else params['unembed']


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

def ssd_step(x, b_t, c_t, a_t, state):
    """Exact decode recurrence.  x: (B,H,P); b_t/c_t: (B,N); a_t: (B,H);
    state: (B,H,P,N)."""
    state = a_t[..., None, None] * state \
        + x[..., :, None] * b_t[:, None, None, :]
    y = jnp.einsum('bhpn,bn->bhp', state, c_t)
    return y, state


def ssd_ref(x, b, c, a, state):
    """Naive sequential oracle.  x: (B,T,H,P); b/c: (B,T,N); a: (B,T,H)."""
    def body(s, xs):
        xt, bt, ct, at = xs
        y, s = ssd_step(xt, bt, ct, at, s)
        return s, y
    xs = (x.transpose(1, 0, 2, 3), b.transpose(1, 0, 2),
          c.transpose(1, 0, 2), a.transpose(1, 0, 2))
    state, ys = jax.lax.scan(body, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def ssd_chunked(x, b, c, a, state, *, chunk: int = SSD_CHUNK):
    """Chunked SSD (Dao & Gu 2024 block decomposition).  Matches ssd_ref.

    x: (B,T,H,P) f32; b,c: (B,T,N); a: (B,T,H) in (0,1); state: (B,H,P,N).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    if t % chunk:
        pad = chunk - t % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    nc = x.shape[1] // chunk
    xc = x.reshape(bsz, nc, chunk, h, p).transpose(1, 0, 3, 2, 4)  # (nc,B,H,c,P)
    bc = b.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)        # (nc,B,c,N)
    cc = c.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    ac = a.reshape(bsz, nc, chunk, h).transpose(1, 0, 3, 2)        # (nc,B,H,c)

    loga = jnp.log(jnp.maximum(ac, 1e-30))
    L = jnp.cumsum(loga, axis=-1)                                  # inclusive
    # intra-chunk: coeff_{t,i} = exp(L_t - L_i) * a_i ... note h_t includes a_t
    # h_t = Σ_{i≤t} (Π_{τ=i+1..t} a_τ) x_i b_i  → exp(L_t - L_i)
    M = jnp.exp(L[..., :, None] - L[..., None, :])                 # (nc,B,H,c,c)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(causal, M, 0.0)
    cb = jnp.einsum('nbtk,nbsk->nbts', cc, bc)                     # (nc,B,c,c)
    y_intra = jnp.einsum('nbts,nbhts,nbhsp->nbhtp', cb, M, xc)

    decay_to_end = jnp.exp(L[..., -1:] - L)                        # (nc,B,H,c)
    chunk_state = jnp.einsum('gbhs,gbhsp,gbsn->gbhpn',
                             decay_to_end, xc, bc)
    a_tot = jnp.exp(L[..., -1])                                    # (nc,B,H)
    decay_in = jnp.exp(L)                                          # Π_{1..t}

    def body(s, xs):
        cci, di, at, cs = xs
        y_in = jnp.einsum('btn,bhpn,bht->bhtp', cci, s, di)
        s = at[..., None, None] * s + cs
        return s, y_in

    state, y_inter = jax.lax.scan(body, state,
                                  (cc, decay_in, a_tot, chunk_state))
    y = (y_intra + y_inter).transpose(1, 0, 3, 2, 4).reshape(bsz, nc * chunk, h, p)
    return y[:, :t], state


def _causal_conv(xbc, conv_w, conv_b, conv_state):
    """Depthwise causal conv.  xbc: (B,T,C); conv_w: (K,C); conv_state:
    (B,K-1,C) — the last K-1 pre-conv inputs from the previous segment."""
    k = conv_w.shape[0]
    full = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    new_state = full[:, -(k - 1):] if k > 1 else conv_state
    return jax.nn.silu((out + conv_b).astype(jnp.float32)).astype(xbc.dtype), \
        new_state


def mamba_block(cfg: ModelConfig, lp, h, cache_l):
    """One Mamba2 layer.  h: (B,T,D)."""
    d, d_in, hd, nh, n = _dims(cfg)
    bsz, t, _ = h.shape
    x = cm.rms_norm(h, lp['ln'], cfg.norm_eps)
    zxbcdt = x @ lp['in_proj']
    zxbcdt = constrain(zxbcdt, ('batch', 'seq', 'qkv'))
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n], axis=-1)
    xbc, new_conv = _causal_conv(xbc, lp['conv_w'], lp['conv_b'],
                                 cache_l['conv'])
    xs, b, c = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    xs = xs.reshape(bsz, t, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp['dt_bias'])   # (B,T,H)
    a = jnp.exp(-jnp.exp(lp['A_log'].astype(jnp.float32)) * dt)    # (0,1)
    xdt = xs * dt[..., None]
    f32 = lambda v_: v_.astype(jnp.float32)
    if t == 1:
        y, new_ssm = ssd_step(xdt[:, 0], f32(b[:, 0]), f32(c[:, 0]),
                              a[:, 0], cache_l['ssm'])
        y = y[:, None]
    else:
        y, new_ssm = ssd_chunked(xdt, f32(b), f32(c), a, cache_l['ssm'])
    y = y + lp['D'][:, None] * xs                                   # skip
    y = y.reshape(bsz, t, d_in)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
                    .astype(jnp.float32), lp['norm'], cfg.norm_eps)
    out = y.astype(h.dtype) @ lp['out_proj']
    return h + out, {'conv': new_conv, 'ssm': new_ssm}


# ---------------------------------------------------------------------------
# Shared attention block (paged KV per application)
# ---------------------------------------------------------------------------

def shared_attn(cfg: ModelConfig, sp, h, e0, positions, mode,
                pool_k=None, pool_v=None, page_table=None):
    """h, e0: (B,T,D).  Returns (h', new_pool_k, new_pool_v)."""
    b, t, d = h.shape
    ah = cfg.hybrid_attn_heads
    ahd = 2 * d // ah
    cat = jnp.concatenate([h, e0], axis=-1)
    x = cm.rms_norm(cat, sp['ln1'], cfg.norm_eps)
    q = (x @ sp['wq']).reshape(b, t, ah, ahd)
    k = (x @ sp['wk']).reshape(b, t, ah, ahd)
    v = (x @ sp['wv']).reshape(b, t, ah, ahd)
    q = constrain(q, ('batch', 'seq', 'heads', 'head_dim'))
    k = constrain(k, ('batch', 'seq', 'heads', 'head_dim'))
    v = constrain(v, ('batch', 'seq', 'heads', 'head_dim'))
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    if mode == 'train':
        out = cm.chunked_attention(q, k, v, q_positions=positions,
                                   kv_positions=positions, causal=True)
    elif mode == 'prefill':
        pool_k = cm.kv_write_prefill(pool_k, page_table, k)
        pool_v = cm.kv_write_prefill(pool_v, page_table, v)
        out = cm.chunked_attention(q, k, v, q_positions=positions,
                                   kv_positions=positions, causal=True)
    elif mode == 'decode_dense':
        # long-context decode: contiguous KV (B, S, AH, AHD), S sharded over
        # (pod, data) — sequence-parallel attention, no page indirection.
        pos = positions[:, 0]
        bidx = jnp.arange(b, dtype=jnp.int32)
        pool_k = pool_k.at[bidx, pos].set(k[:, 0])
        pool_v = pool_v.at[bidx, pos].set(v[:, 0])
        s_max = pool_k.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(s_max, dtype=jnp.int32),
                                  (b, s_max))
        valid = kv_pos <= pos[:, None]
        out = cm.attention(q, pool_k, pool_v, q_positions=pos[:, None],
                           kv_positions=kv_pos, kv_valid=valid, causal=False)
    else:  # decode: positions (B, 1) == (B,) broadcast of new-token index
        pos = positions[:, 0]
        pg = pool_k.shape[-3]
        page_idx = jnp.take_along_axis(page_table, (pos // pg)[:, None],
                                       axis=1)[:, 0]
        pool_k = cm.kv_write_token(pool_k, page_idx, pos % pg, k[:, 0])
        pool_v = cm.kv_write_token(pool_v, page_idx, pos % pg, v[:, 0])
        out = cm.paged_attention_ref(q[:, 0], pool_k, pool_v, page_table,
                                     pos + 1)[:, None]
    out = out.reshape(b, t, ah * ahd)
    out = constrain(out, ('batch', 'seq', 'qkv'))
    h = h + out @ sp['wo']
    cat = jnp.concatenate([h, e0], axis=-1)
    x = cm.rms_norm(cat, sp['ln2'], cfg.norm_eps)
    h = h + cm.swiglu(x, sp['wg'], sp['wu'], sp['wd'])
    return constrain(h, ('batch', 'seq', 'embed')), pool_k, pool_v


# ---------------------------------------------------------------------------
# Full stack
# ---------------------------------------------------------------------------

def scan_layers(cfg: ModelConfig, params, h, e0, positions, mode,
                mamba_cache, attn_cache, page_table=None, remat=True):
    """mamba_cache: {'conv': (L,B,K-1,C), 'ssm': (L,B,H,P,N)};
    attn_cache: {'k','v': (n_apps, P, pg, AH, AHD)} or None (train)."""
    every = cfg.hybrid_attn_every
    sp = params['shared']

    def body(carry, xs):
        hh, ak, av = carry
        idx, lp, mcache_l = xs
        hh, new_mcache = mamba_block(cfg, lp, hh, mcache_l)

        def with_attn(args):
            hh, ak, av = args
            app = idx // every
            if ak is None:
                h2, _, _ = shared_attn(cfg, sp, hh, e0, positions, mode)
                return h2, ak, av
            pk = ak[app] if mode != 'train' else None
            pv = av[app] if mode != 'train' else None
            h2, pk, pv = shared_attn(cfg, sp, hh, e0, positions, mode,
                                     pk, pv, page_table)
            ak2 = jax.lax.dynamic_update_index_in_dim(ak, pk, app, 0)
            av2 = jax.lax.dynamic_update_index_in_dim(av, pv, app, 0)
            return h2, ak2, av2

        is_attn = (idx + 1) % every == 0
        if attn_cache is None:
            hh, ak, av = jax.lax.cond(is_attn, with_attn,
                                      lambda args: args, (hh, ak, av))
        else:
            hh, ak, av = jax.lax.cond(is_attn, with_attn,
                                      lambda args: args, (hh, ak, av))
        return (hh, ak, av), new_mcache

    if remat and mode == 'train':
        body = jax.checkpoint(body)
    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    if attn_cache is None:
        carry = (h, None, None)
    else:
        carry = (h, attn_cache['k'], attn_cache['v'])
    (h, ak, av), new_mamba = jax.lax.scan(
        body, carry, (idxs, params['layers'], mamba_cache))
    new_attn = None if ak is None else {'k': ak, 'v': av}
    return h, new_mamba, new_attn


def mamba_cache_template(cfg: ModelConfig, batch_size: int):
    d, d_in, hd, h, n = _dims(cfg)
    L = cfg.n_layers
    conv_ch = d_in + 2 * n
    return {
        'conv': PSpec((L, batch_size, cfg.conv_kernel - 1, conv_ch),
                      ('layers', 'batch', None, 'qkv'), 'zeros'),
        'ssm': PSpec((L, batch_size, h, hd, n),
                     ('layers', 'batch', 'heads', None, 'state'), 'zeros',
                     dtype=jnp.float32),
    }


def attn_cache_template(cfg: ModelConfig, n_pages: int,
                        batch: Optional[int] = None):
    """Paged shared-attn KV.  ``batch=None`` → global pool (engine);
    otherwise per-request region layout (distributed)."""
    ah = cfg.hybrid_attn_heads
    ahd = 2 * cfg.d_model // ah
    if batch is None:
        shape = (n_attn_apps(cfg), n_pages, cfg.page_size, ah, ahd)
        axes = ('layers', 'pages', None, 'heads', 'head_dim')
    else:
        shape = (n_attn_apps(cfg), batch, n_pages, cfg.page_size, ah, ahd)
        axes = ('layers', 'batch', 'pages', None, 'heads', 'head_dim')
    return {'k': PSpec(shape, axes, 'zeros'), 'v': PSpec(shape, axes, 'zeros')}


def attn_cache_template_dense(cfg: ModelConfig, batch: int, max_seq: int):
    """Contiguous long-context KV (S sharded over data): long_500k decode."""
    ah = cfg.hybrid_attn_heads
    ahd = 2 * cfg.d_model // ah
    shape = (n_attn_apps(cfg), batch, max_seq, ah, ahd)
    axes = ('layers', 'batch', 'kv_seq', 'heads', 'head_dim')
    return {'k': PSpec(shape, axes, 'zeros'), 'v': PSpec(shape, axes, 'zeros')}


def _positions_train(b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


def forward_train(cfg: ModelConfig, params, batch, *, remat=True):
    tokens = batch['tokens']
    b, s = tokens.shape
    h = params['embed'][tokens]
    h = constrain(h, ('batch', 'seq', 'embed'))
    e0 = h
    mc = cm.init_from_template(mamba_cache_template(cfg, b),
                               jax.random.PRNGKey(0))
    h, _, _ = scan_layers(cfg, params, h, e0, _positions_train(b, s), 'train',
                          mc, None, remat=remat)
    nll, cnt = cm.chunked_ce_loss(h, params['final_norm'],
                                  unembed_of(cfg, params), batch['labels'],
                                  mask=batch.get('loss_mask'), eps=cfg.norm_eps)
    return nll / jnp.maximum(cnt, 1.0), {'tokens': cnt}


def prefill(cfg: ModelConfig, params, cache, batch):
    tokens = batch['tokens']
    b, s = tokens.shape
    h = params['embed'][tokens]
    h = constrain(h, ('batch', 'seq', 'embed'))
    pos = _positions_train(b, s)
    h, mc, ac = scan_layers(cfg, params, h, h, pos, 'prefill',
                            cache['mamba'], cache['attn'],
                            page_table=batch['page_table'], remat=False)
    last = cm.rms_norm(h[:, -1], params['final_norm'], cfg.norm_eps)
    logits = last @ unembed_of(cfg, params)
    return {'mamba': mc, 'attn': ac}, constrain(logits, ('batch', 'vocab'))


def decode_step(cfg: ModelConfig, params, cache, batch, *,
                long_context: bool = False):
    tokens = batch['tokens']
    positions = batch['positions']           # (B,)
    h = params['embed'][tokens][:, None, :]
    h = constrain(h, ('batch', 'seq', 'embed'))
    mode = 'decode_dense' if long_context else 'decode'
    h, mc, ac = scan_layers(cfg, params, h, h, positions[:, None], mode,
                            cache['mamba'], cache['attn'],
                            page_table=batch.get('page_table'), remat=False)
    last = cm.rms_norm(h[:, 0], params['final_norm'], cfg.norm_eps)
    logits = last @ unembed_of(cfg, params)
    return {'mamba': mc, 'attn': ac}, constrain(logits, ('batch', 'vocab'))
