"""Unified model API.

``build_model(cfg)`` returns a :class:`Model` that dispatches to the family
implementation and exposes everything the launcher / dry-run / engine / tests
need: param templates (for no-allocation lowering), loss / prefill / decode
entry points, cache templates per execution shape, and ShapeDtypeStruct input
specs for every assigned (arch × shape) cell.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, cell_supported
from repro.models import common as cm
from repro.models import dense, encdec, moe, rwkv6, zamba2

_FAMILY = {
    'dense': dense,
    'vlm': dense,
    'moe': moe,
    'ssm': rwkv6,
    'encdec': encdec,
    'hybrid': zamba2,
}

I32 = jnp.int32
BF16 = cm.DEFAULT_DTYPE


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclass
class Model:
    cfg: ModelConfig

    @property
    def mod(self):
        return _FAMILY[self.cfg.family]

    # ------------------------------------------------------------- params
    def template(self):
        return self.mod.template(self.cfg)

    def init_params(self, rng):
        return cm.init_from_template(self.template(), rng)

    def param_shapes(self):
        return cm.shapes_from_template(self.template())

    def param_axes(self):
        return cm.axes_from_template(self.template())

    # -------------------------------------------------------- step fns
    def loss_fn(self, params, batch, **kw):
        return self.mod.forward_train(self.cfg, params, batch, **kw)

    def prefill_fn(self, params, cache, batch):
        return self.mod.prefill(self.cfg, params, cache, batch)

    def decode_fn(self, params, cache, batch, *, long_context=False,
                  use_pallas=False):
        if self.cfg.family == 'hybrid':
            return self.mod.decode_step(self.cfg, params, cache, batch,
                                        long_context=long_context)
        if self.cfg.family in ('dense', 'vlm', 'moe'):
            # paged-KV decoder families route decode attention through the
            # Pallas paged kernel when asked (the engine's hot path)
            return self.mod.decode_step(self.cfg, params, cache, batch,
                                        use_pallas=use_pallas)
        return self.mod.decode_step(self.cfg, params, cache, batch)

    def decode_sample_fn(self, params, cache, batch, *, use_pallas=False,
                         temperature=0.0):
        """Fused decode+sampling step: (cache, (B,) int32 tokens).

        The engine's ``fused_sampling`` fast path — logits never leave the
        device (see ``models.dense.decode_step_sample``).  Dense-family
        models only; other families keep the logits-returning
        :meth:`decode_fn` + sampler composition.
        """
        assert self.cfg.family in ('dense', 'vlm'), \
            f'fused sampling not implemented for family {self.cfg.family!r}'
        return dense.decode_step_sample(self.cfg, params, cache, batch,
                                        use_pallas=use_pallas,
                                        temperature=temperature)

    # -------------------------------------------------------- caches
    def cache_template(self, shape: ShapeConfig, *, engine_pages: Optional[int] = None):
        """Cache PSpec tree for an execution shape.

        ``engine_pages`` switches to the single-device global-pool layout
        used by the serving engine (Valve's handle space).
        """
        cfg = self.cfg
        pg = cfg.page_size
        if shape is not None:
            b = shape.global_batch
            maxp = shape.seq_len // pg
            # slot 0 = quarantine; rounded up so the region dim stays
            # shardable over the 16-way model axis (padding slots unused)
            region = -(-(maxp + 1) // 16) * 16
        else:
            assert engine_pages is not None, 'need a shape or engine_pages'
            b = region = None
        fam = cfg.family

        if fam in ('dense', 'vlm', 'moe'):
            if engine_pages is not None:
                return dense.cache_template(cfg, engine_pages)
            return dense.cache_template(cfg, region, batch=b)
        if shape is None:
            raise NotImplementedError(
                f'engine pool layout only for paged-KV families, not {fam}')
        if fam == 'ssm':
            return rwkv6.cache_template(cfg, b)
        if fam == 'hybrid':
            t = {'mamba': zamba2.mamba_cache_template(cfg, b)}
            if shape.name == 'long_500k':
                t['attn'] = zamba2.attn_cache_template_dense(cfg, b, shape.seq_len)
            elif engine_pages is not None:
                t['attn'] = zamba2.attn_cache_template(cfg, engine_pages)
            else:
                t['attn'] = zamba2.attn_cache_template(cfg, region, batch=b)
            return t
        if fam == 'encdec':
            enc_len = self.enc_len(shape)
            if engine_pages is not None:
                raise NotImplementedError('engine serves decoder-only models')
            return encdec.cache_template(cfg, region, b, enc_len)
        raise ValueError(fam)

    def cache_shapes(self, shape: ShapeConfig, **kw):
        return cm.shapes_from_template(self.cache_template(shape, **kw))

    def cache_axes(self, shape: ShapeConfig, **kw):
        return cm.axes_from_template(self.cache_template(shape, **kw))

    def init_cache(self, shape: ShapeConfig, **kw):
        return cm.init_from_template(self.cache_template(shape, **kw),
                                     jax.random.PRNGKey(0))

    def enc_len(self, shape: ShapeConfig) -> int:
        """Encoder context for enc-dec shapes (see DESIGN.md)."""
        if shape.kind == 'prefill':
            return shape.seq_len
        return min(shape.seq_len, 4096)

    # -------------------------------------------------------- input specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the step function's ``batch``."""
        ok, why = cell_supported(self.cfg, shape)
        if not ok:
            raise ValueError(f'{self.cfg.name} × {shape.name}: {why}')
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        pg = cfg.page_size
        d = cfg.d_model

        if shape.kind == 'train':
            specs = {'tokens': _sds((b, s), I32), 'labels': _sds((b, s), I32)}
            if cfg.family == 'encdec':
                specs['frames'] = _sds((b, s, d), BF16)
            elif cfg.frontend is not None:
                specs['prefix_embeds'] = _sds((b, cfg.frontend_tokens, d), BF16)
            return specs

        if shape.kind == 'prefill':
            if cfg.family == 'encdec':
                s_dec = s // encdec.DEC_PREFIX_FRACTION
                return {
                    'frames': _sds((b, s, d), BF16),
                    'tokens': _sds((b, s_dec), I32),
                    'page_table': _sds((b, s_dec // pg), I32),
                }
            specs = {'tokens': _sds((b, s), I32),
                     'page_table': _sds((b, s // pg), I32)}
            if cfg.family == 'ssm':
                del specs['page_table']
            if cfg.frontend is not None:
                specs['prefix_embeds'] = _sds((b, cfg.frontend_tokens, d), BF16)
            return specs

        # decode: one new token with a KV cache of seq_len
        specs = {'tokens': _sds((b,), I32), 'positions': _sds((b,), I32)}
        if cfg.family == 'ssm' or shape.name == 'long_500k':
            return specs
        specs['page_table'] = _sds((b, s // pg), I32)
        return specs

    def input_axes(self, shape: ShapeConfig) -> Dict[str, tuple]:
        """Logical axes for every input (resolved via the active rule set)."""
        cfg = self.cfg
        axes = {}
        for name, spec in self.input_specs(shape).items():
            if name in ('tokens', 'labels', 'loss_mask'):
                axes[name] = ('batch', 'seq')[: len(spec.shape)] \
                    if len(spec.shape) > 1 else ('batch',)
            elif name == 'frames':
                axes[name] = ('batch', 'seq', 'embed')
            elif name == 'prefix_embeds':
                axes[name] = ('batch', None, 'embed')
            elif name == 'page_table':
                axes[name] = ('batch', None)
            elif name == 'positions':
                axes[name] = ('batch',)
            else:
                raise KeyError(name)
        return axes

    # -------------------------------------------------------- smoke inputs
    def make_inputs(self, shape_kind: str, b: int, s: int,
                    rng: Optional[np.random.Generator] = None) -> Dict[str, Any]:
        """Small *concrete* inputs for CPU smoke tests."""
        cfg = self.cfg
        rng = rng or np.random.default_rng(0)
        pg = cfg.page_size
        d = cfg.d_model
        tok = lambda shp: jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=shp), I32)

        if shape_kind == 'train':
            batch = {'tokens': tok((b, s)), 'labels': tok((b, s))}
            if cfg.family == 'encdec':
                batch['frames'] = jnp.asarray(
                    rng.normal(size=(b, s, d)) * 0.02, BF16)
            elif cfg.frontend is not None:
                p = min(cfg.frontend_tokens, s)
                batch['prefix_embeds'] = jnp.asarray(
                    rng.normal(size=(b, p, d)) * 0.02, BF16)
            return batch

        if shape_kind == 'prefill':
            maxp = s // pg
            # region-local ids; slot 0 is quarantine → pages 1..maxp
            pt = jnp.broadcast_to(jnp.arange(1, maxp + 1, dtype=I32), (b, maxp))
            if cfg.family == 'encdec':
                return {
                    'frames': jnp.asarray(rng.normal(size=(b, s, d)) * .02, BF16),
                    'tokens': tok((b, s)),
                    'page_table': pt,
                }
            batch = {'tokens': tok((b, s)), 'page_table': pt}
            if cfg.family == 'ssm':
                del batch['page_table']
            if cfg.frontend is not None:
                p = min(cfg.frontend_tokens, s)
                batch['prefix_embeds'] = jnp.asarray(
                    rng.normal(size=(b, p, d)) * .02, BF16)
            return batch

        if shape_kind == 'decode':
            maxp = s // pg
            pt = jnp.broadcast_to(jnp.arange(1, maxp + 1, dtype=I32), (b, maxp))
            return {
                'tokens': tok((b,)),
                'positions': jnp.full((b,), s - 1, I32),
                'page_table': pt,
            }
        raise ValueError(shape_kind)


@functools.lru_cache(maxsize=None)
def _build_cached(cfg: ModelConfig) -> Model:
    return Model(cfg)


def build_model(cfg: ModelConfig) -> Model:
    return _build_cached(cfg)
