"""Shared model building blocks.

Params are plain nested dicts of arrays.  Each family builds a *template* —
the same nested structure with :class:`PSpec` leaves carrying shape, logical
sharding axes, and init law — from which we derive:

- real params (``init_from_template``) for smoke tests / small runs,
- ``jax.ShapeDtypeStruct`` stand-ins (``shapes_from_template``) so the dry-run
  lowers full-size models without allocating a byte,
- logical-axes trees (``axes_from_template``) → PartitionSpecs for pjit.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

DEFAULT_DTYPE = jnp.bfloat16


@dataclass(frozen=True)
class PSpec:
    """Param template leaf: shape + logical axes + init law."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = 'normal'       # 'normal' | 'zeros' | 'ones'
    scale: Optional[float] = None  # None → 1/sqrt(fan_in) for 'normal'
    dtype: Any = DEFAULT_DTYPE

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_pspec(x):
    return isinstance(x, PSpec)


def init_from_template(tmpl, rng: jax.Array):
    leaves, treedef = jax.tree.flatten(tmpl, is_leaf=_is_pspec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for leaf, key in zip(leaves, keys):
        if leaf.init == 'zeros':
            arr = jnp.zeros(leaf.shape, leaf.dtype)
        elif leaf.init == 'ones':
            arr = jnp.ones(leaf.shape, leaf.dtype)
        else:
            fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
            scale = leaf.scale if leaf.scale is not None else fan_in ** -0.5
            arr = (jax.random.normal(key, leaf.shape, jnp.float32) * scale
                   ).astype(leaf.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def shapes_from_template(tmpl):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tmpl, is_leaf=_is_pspec)


def axes_from_template(tmpl):
    return jax.tree.map(lambda l: l.axes, tmpl, is_leaf=_is_pspec)


def param_bytes(tmpl) -> int:
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tmpl, is_leaf=_is_pspec))


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotate-half RoPE.  x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions: (B, S) → angles (B, S, 1, half)
    angles = positions.astype(jnp.float32)[..., None, None] * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    xr2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


def swiglu(x, wg, wu, wd, bg=None, bu=None, bd=None):
    g = x @ wg
    u = x @ wu
    if bg is not None:
        g = g + bg
        u = u + bu
    axes = ('batch', 'seq', 'ffn') if g.ndim == 3 else ('batch', 'ffn')
    g = constrain(g, axes)
    u = constrain(u, axes)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out = h @ wd
    if bd is not None:
        out = out + bd
    return out


def repeat_kv(kv, groups: int):
    """(..., S, Hkv, Dh) → (..., S, Hkv*groups, Dh)."""
    if groups == 1:
        return kv
    return jnp.repeat(kv, groups, axis=-2)


def attention(q, k, v, *, q_positions, kv_positions, kv_valid=None,
              causal: bool = True, scale: Optional[float] = None):
    """Reference GQA attention (jnp oracle path).

    q: (B, Sq, Hq, Dh); k/v: (B, Skv, Hkv, Dh).  f32 softmax.
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    groups = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    qg = q.reshape(b, sq, hkv, groups, dh)
    # f32 ACCUMULATION without upcasting operands: upcasting k/v first
    # materializes f32 copies of the (gathered) KV — 2× the HBM traffic
    # and temp footprint on every attention (§Perf H-mem3)
    scores = jnp.einsum('bqhgd,bkhd->bhgqk', qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((b, 1, 1, sq, k.shape[1]), bool)
    if causal:
        mask &= (q_positions[:, None, None, :, None]
                 >= kv_positions[:, None, None, None, :])
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum('bhgqk,bkhd->bqhgd', probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def chunked_attention(q, k, v, *, q_positions, kv_positions, kv_valid=None,
                      causal: bool = True, scale: Optional[float] = None,
                      q_chunk: int = 512, remat_chunks: bool = True):
    """Blockwise attention: scan over Q chunks so scores never materialize at
    (Sq × Skv).  Same math as :func:`attention` (oracle-equivalent).

    ``remat_chunks`` checkpoints each chunk body: without it the backward
    pass keeps EVERY chunk's f32 scores/probs live simultaneously
    (≈ n_chunks × B·H·q_chunk·Skv f32 — the dominant HBM temp the dry-run
    found on big train cells); with it the live set is one chunk,
    recomputed during backprop (§Perf H-mem2).
    """
    b, sq, hq, dh = q.shape
    if sq <= q_chunk:
        return attention(q, k, v, q_positions=q_positions,
                         kv_positions=kv_positions, kv_valid=kv_valid,
                         causal=causal, scale=scale)
    n = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qs = q.reshape(b, n, q_chunk, hq, dh).transpose(1, 0, 2, 3, 4)
    qp = q_positions.reshape(b, n, q_chunk).transpose(1, 0, 2)

    def body(_, xs):
        qc, qpc = xs
        out = attention(qc, k, v, q_positions=qpc, kv_positions=kv_positions,
                        kv_valid=kv_valid, causal=causal, scale=scale)
        return None, out

    if remat_chunks:
        body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (qs, qp))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, hq, dh)


def chunked_ce_loss(h, norm_w, unembed, labels, *, mask=None, eps: float = 1e-5,
                    seq_chunk: int = 512, logit_axes=('batch', 'seq', 'vocab')):
    """Final-norm → unembed → cross-entropy, scanned over sequence chunks so
    the (B, S, V) logits tensor never materializes.

    Returns (sum_nll, sum_count) so callers can combine across microbatches.
    """
    b, s, d = h.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    n = max(s // seq_chunk, 1)
    seq_chunk = s // n
    assert s % n == 0
    hs = h.reshape(b, n, seq_chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n, seq_chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, n, seq_chunk).transpose(1, 0, 2)

    def body(carry, xs):
        nll_sum, cnt = carry
        hc, lc, mc = xs
        hc = rms_norm(hc, norm_w, eps)
        logits = hc @ unembed
        logits = constrain(logits, logit_axes)
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        mcf = mc.astype(jnp.float32)
        nll_sum = nll_sum + jnp.sum((lse - gold) * mcf)
        cnt = cnt + jnp.sum(mcf)
        return (nll_sum, cnt), None

    # checkpoint: otherwise every chunk's (B, chunk, V) f32 logits stay
    # live for the backward pass simultaneously (§Perf H-mem2)
    body = jax.checkpoint(body)
    (nll, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hs, ls, ms))
    return nll, cnt


def softmax_cross_entropy(logits, labels, mask=None):
    """logits (B, S, V) [any dtype], labels (B, S) int32 → mean NLL (f32)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Paged KV-cache primitives (the substrate Valve's reclamation operates on).
# Pool layout: (P, page, Hkv, Dh); page 0 is the QUARANTINE page.  Page tables
# hold *physical* page ids; remapping a victim handle = rewriting its entries
# to 0, which is always mapped, so no access can ever fault (paper §5).
# ---------------------------------------------------------------------------

QUARANTINE_PAGE = 0


def paged_gather(pool, page_table):
    """pool (P, pg, Hkv, Dh), page_table (B, maxp) → (B, maxp*pg, Hkv, Dh)."""
    b, maxp = page_table.shape
    pg = pool.shape[1]
    gathered = pool[page_table]              # (B, maxp, pg, Hkv, Dh)
    return gathered.reshape(b, maxp * pg, *pool.shape[2:])


def paged_write_prefill(pool, page_table, kv):
    """Write a full prefill's K or V into the pool.

    kv: (B, S, Hkv, Dh) with S % page == 0; page_table (B, S//page) physical ids.
    """
    b, s, hkv, dh = kv.shape
    pg = pool.shape[1]
    chunks = kv.reshape(b * (s // pg), pg, hkv, dh)
    idx = page_table[:, : s // pg].reshape(-1)
    return pool.at[idx].set(chunks, mode='drop')


def paged_write_token(pool, page_ids, offsets, kv):
    """Write one new token per request.  kv: (B, Hkv, Dh)."""
    return pool.at[page_ids, offsets].set(kv, mode='drop')


def region_gather(pool, page_table):
    """Region-paged gather (SPMD-clean: batch-aligned take_along_axis).

    pool (B, R, pg, Hkv, Dh), page_table (B, maxp) with region-local ids
    → (B, maxp*pg, Hkv, Dh)."""
    b, maxp = page_table.shape
    idx = page_table[:, :, None, None, None]
    gathered = jnp.take_along_axis(pool, idx, axis=1)   # (B, maxp, pg, H, D)
    return gathered.reshape(b, maxp * pool.shape[2], *pool.shape[3:])


def kv_gather(pool, page_table):
    """Dispatch on layout: 4-D = global pool, 5-D = per-request regions."""
    return (paged_gather if pool.ndim == 4 else region_gather)(pool, page_table)


def kv_write_prefill(pool, page_table, kv):
    """Layout-dispatching prefill write.  kv: (B, S, Hkv, Dh)."""
    if pool.ndim == 4:
        return paged_write_prefill(pool, page_table, kv)
    b, s, hkv, dh = kv.shape
    pg = pool.shape[2]
    np_ = s // pg
    chunks = kv.reshape(b, np_, pg, hkv, dh)
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
    return pool.at[bidx, page_table[:, :np_]].set(chunks, mode='drop')


def kv_write_token(pool, page_ids, offsets, kv):
    """Layout-dispatching single-token write.  kv: (B, Hkv, Dh)."""
    if pool.ndim == 4:
        return paged_write_token(pool, page_ids, offsets, kv)
    bidx = jnp.arange(pool.shape[0], dtype=jnp.int32)
    return pool.at[bidx, page_ids, offsets].set(kv, mode='drop')


def kv_write_tokens(pool, page_ids, offsets, kv):
    """Token-granular chunk write (no page-alignment requirement).

    page_ids/offsets: (B, C) per-token physical page + in-page offset;
    kv: (B, C, Hkv, Dh).  Padding tokens should point at the quarantine page
    (id 0) — overwriting quarantine is harmless by design.
    """
    if pool.ndim == 4:
        b, c = page_ids.shape
        return pool.at[page_ids.reshape(-1), offsets.reshape(-1)].set(
            kv.reshape(b * c, *kv.shape[2:]), mode='drop')
    bidx = jnp.arange(pool.shape[0], dtype=jnp.int32)[:, None]
    return pool.at[bidx, page_ids, offsets].set(kv, mode='drop')


def paged_attention_ref(q, pool_k, pool_v, page_table, lengths, *,
                        scale: Optional[float] = None):
    """Decode attention through the page table (pure-jnp oracle).

    q: (B, Hq, Dh) — one new token per request at position ``lengths``.
    Pool layout may be global (P, pg, H, D) or region (B, R, pg, H, D).
    """
    b, hq, dh = q.shape
    pg = pool_k.shape[-3]
    maxp = page_table.shape[1]
    k = kv_gather(pool_k, page_table)   # (B, S_max, Hkv, Dh)
    v = kv_gather(pool_v, page_table)
    kv_pos = jnp.broadcast_to(jnp.arange(maxp * pg, dtype=jnp.int32), (b, maxp * pg))
    valid = kv_pos < lengths[:, None]
    out = attention(q[:, None], k, v,
                    q_positions=lengths[:, None].astype(jnp.int32),
                    kv_positions=kv_pos, kv_valid=valid,
                    causal=False, scale=scale)
    return out[:, 0]
