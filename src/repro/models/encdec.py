"""seamless-m4t-medium — speech-encoder → text-decoder transformer.

[audio] frontend is a STUB by instruction: inputs are precomputed speech frame
embeddings (B, S_enc, d_model).  The decoder is a standard causal transformer
with cross-attention; decoder self-attn KV is paged (Valve-reclaimable), the
cross-attention K/V (computed once from encoder output at prefill) is a dense
per-request cache.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common as cm
from repro.models import dense
from repro.models.common import PSpec

# Encoder context for decode shapes; prefill_32k = 32k encoder frames +
# seq/8 decoder prefix (documented in DESIGN.md — the shape grid is LM-centric).
DEC_PREFIX_FRACTION = 8


def template(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab_size
    Le, Ld = cfg.enc_layers, cfg.dec_layers
    t: Dict[str, Any] = {
        'embed': PSpec((v, d), ('vocab', 'embed'), scale=d ** -0.5),  # tied-unembed-safe: logits ~O(1)
        'unembed': PSpec((d, v), ('embed', 'vocab')),
        'frontend_proj': PSpec((d, d), ('embed', 'embed')),  # audio-stub adapter
        'enc_final_norm': PSpec((d,), ('embed',), 'ones'),
        'final_norm': PSpec((d,), ('embed',), 'ones'),
        'enc_layers': {
            'ln1': PSpec((Le, d), ('layers', 'embed'), 'ones'),
            'ln2': PSpec((Le, d), ('layers', 'embed'), 'ones'),
            **dense.attn_template(cfg, Le),
            **dense.mlp_template(cfg, Le),
        },
        'dec_layers': {
            'ln1': PSpec((Ld, d), ('layers', 'embed'), 'ones'),
            'ln2': PSpec((Ld, d), ('layers', 'embed'), 'ones'),
            'ln_cross': PSpec((Ld, d), ('layers', 'embed'), 'ones'),
            **dense.attn_template(cfg, Ld),
            **{f'x{k}': s for k, s in dense.attn_template(cfg, Ld).items()},
            **dense.mlp_template(cfg, Ld),
        },
    }
    return t


def _xlp(lp):
    """Cross-attention param view (keys prefixed with 'x')."""
    return {k[1:]: v for k, v in lp.items() if k.startswith('x')}


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, S_enc, D) stub embeddings → encoder output (B, S_enc, D)."""
    b, s, _ = frames.shape
    h = frames.astype(cm.DEFAULT_DTYPE) @ params['frontend_proj']
    h = constrain(h, ('batch', 'seq', 'embed'))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(hh, lp):
        x = cm.rms_norm(hh, lp['ln1'], cfg.norm_eps)
        q, k, v = dense.qkv_proj(cfg, lp, x, positions)
        out = cm.chunked_attention(q, k, v, q_positions=positions,
                                   kv_positions=positions, causal=False)
        out = out.reshape(b, s, -1)
        out = constrain(out, ('batch', 'seq', 'qkv'))
        hh = hh + out @ lp['wo']
        hh = constrain(hh, ('batch', 'seq', 'embed'))
        x = cm.rms_norm(hh, lp['ln2'], cfg.norm_eps)
        hh = hh + cm.swiglu(x, lp['wg'], lp['wu'], lp['wd'])
        return constrain(hh, ('batch', 'seq', 'embed')), None

    h, _ = jax.lax.scan(body, h, params['enc_layers'])
    return cm.rms_norm(h, params['enc_final_norm'], cfg.norm_eps)


def cross_kv(cfg: ModelConfig, params, enc_out):
    """Precompute cross-attention K/V for every decoder layer.

    → k, v: (Ld, B, S_enc, Hkv, Dh)."""
    b, s, _ = enc_out.shape

    def body(_, lp):
        xlp = _xlp(lp)
        k = (enc_out @ xlp['wk'])
        v = (enc_out @ xlp['wv'])
        if cfg.attn_bias and 'bk' in xlp:
            k, v = k + xlp['bk'], v + xlp['bv']
        k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
        return None, (k, v)

    _, (ks, vs) = jax.lax.scan(body, None, params['dec_layers'])
    return ks, vs


def _cross_attn(cfg, lp, x, positions, xk, xv):
    b, t, _ = x.shape
    xlp = _xlp(lp)
    q = x @ xlp['wq']
    if cfg.attn_bias and 'bq' in xlp:
        q = q + xlp['bq']
    q = q.reshape(b, t, cfg.n_heads, cfg.hd)
    q = constrain(q, ('batch', 'seq', 'heads', 'head_dim'))
    enc_pos = jnp.broadcast_to(jnp.arange(xk.shape[1], dtype=jnp.int32),
                               (b, xk.shape[1]))
    out = cm.chunked_attention(q, xk, xv, q_positions=positions,
                               kv_positions=enc_pos, causal=False)
    out = out.reshape(b, t, -1)
    out = constrain(out, ('batch', 'seq', 'qkv'))
    return out @ xlp['wo']


def dec_layer(cfg: ModelConfig, lp, h, positions, mode, cache_l, page_table,
              xk, xv):
    x = cm.rms_norm(h, lp['ln1'], cfg.norm_eps)
    new_cache_l = cache_l
    if mode == 'train':
        attn = dense.self_attn_train(cfg, lp, x, positions)
    elif mode == 'prefill':
        attn, pk, pv = dense.self_attn_prefill(
            cfg, lp, x, positions, cache_l['k'], cache_l['v'], page_table)
        new_cache_l = {'k': pk, 'v': pv}
    else:
        attn, pk, pv = dense.self_attn_decode(
            cfg, lp, x, positions, cache_l['k'], cache_l['v'], page_table)
        new_cache_l = {'k': pk, 'v': pv}
    h = h + attn
    h = constrain(h, ('batch', 'seq', 'embed'))
    x = cm.rms_norm(h, lp['ln_cross'], cfg.norm_eps)
    pos2d = positions if positions.ndim == 2 else positions[:, None]
    h = h + _cross_attn(cfg, lp, x, pos2d, xk, xv)
    h = constrain(h, ('batch', 'seq', 'embed'))
    x = cm.rms_norm(h, lp['ln2'], cfg.norm_eps)
    h = h + cm.swiglu(x, lp['wg'], lp['wu'], lp['wd'])
    return constrain(h, ('batch', 'seq', 'embed')), new_cache_l


def scan_dec(cfg, params, h, positions, mode, cache, page_table, xks, xvs,
             remat=True):
    def body(hh, xs):
        lp, cache_l, xk, xv = xs
        out, new_cache_l = dec_layer(cfg, lp, hh, positions, mode, cache_l,
                                     page_table, xk, xv)
        return out, new_cache_l

    if remat and mode == 'train':
        body = jax.checkpoint(body)
    return jax.lax.scan(body, h, (params['dec_layers'], cache, xks, xvs))


def forward_train(cfg: ModelConfig, params, batch, *, remat=True):
    frames = batch['frames']                  # (B, S_enc, D) stub
    tokens = batch['tokens']                  # (B, S_dec)
    b, s = tokens.shape
    enc_out = encode(cfg, params, frames)
    xks, xvs = cross_kv(cfg, params, enc_out)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = params['embed'][tokens]
    h = constrain(h, ('batch', 'seq', 'embed'))
    h, _ = scan_dec(cfg, params, h, positions, 'train', None, None, xks, xvs,
                    remat=remat)
    nll, cnt = cm.chunked_ce_loss(h, params['final_norm'], params['unembed'],
                                  batch['labels'], mask=batch.get('loss_mask'),
                                  eps=cfg.norm_eps)
    return nll / jnp.maximum(cnt, 1.0), {'tokens': cnt}


def prefill(cfg: ModelConfig, params, cache, batch):
    """Encode frames, compute cross-KV, prefill decoder prefix."""
    frames = batch['frames']
    tokens = batch['tokens']
    b, s = tokens.shape
    enc_out = encode(cfg, params, frames)
    xks, xvs = cross_kv(cfg, params, enc_out)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = params['embed'][tokens]
    h = constrain(h, ('batch', 'seq', 'embed'))
    h, kv = scan_dec(cfg, params, h, positions, 'prefill',
                     {'k': cache['k'], 'v': cache['v']},
                     batch['page_table'], xks, xvs, remat=False)
    last = cm.rms_norm(h[:, -1], params['final_norm'], cfg.norm_eps)
    logits = last @ params['unembed']
    new_cache = {'k': kv['k'], 'v': kv['v'], 'cross_k': xks, 'cross_v': xvs}
    return new_cache, constrain(logits, ('batch', 'vocab'))


def decode_step(cfg: ModelConfig, params, cache, batch):
    tokens = batch['tokens']
    positions = batch['positions']
    h = params['embed'][tokens][:, None, :]
    h = constrain(h, ('batch', 'seq', 'embed'))
    h, kv = scan_dec(cfg, params, h, positions, 'decode',
                     {'k': cache['k'], 'v': cache['v']},
                     batch['page_table'], cache['cross_k'], cache['cross_v'],
                     remat=False)
    last = cm.rms_norm(h[:, 0], params['final_norm'], cfg.norm_eps)
    logits = last @ params['unembed']
    new_cache = {'k': kv['k'], 'v': kv['v'],
                 'cross_k': cache['cross_k'], 'cross_v': cache['cross_v']}
    return new_cache, constrain(logits, ('batch', 'vocab'))


def cache_template(cfg: ModelConfig, n_pages: int, batch: int, enc_len: int):
    Ld = cfg.dec_layers
    kv_shape = (Ld, n_pages, cfg.page_size, cfg.n_kv_heads, cfg.hd)
    kv_axes = ('layers', 'pages', None, 'kv_heads', 'head_dim')
    x_shape = (Ld, batch, enc_len, cfg.n_kv_heads, cfg.hd)
    x_axes = ('layers', 'batch', None, 'kv_heads', 'head_dim')
    return {
        'k': PSpec(kv_shape, kv_axes, 'zeros'),
        'v': PSpec(kv_shape, kv_axes, 'zeros'),
        'cross_k': PSpec(x_shape, x_axes, 'zeros'),
        'cross_v': PSpec(x_shape, x_axes, 'zeros'),
    }
