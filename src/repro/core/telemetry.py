"""Unified telemetry — counters *derived from the event stream*.

Before the control-plane API, every plane hand-synchronized its own
counters (``RuntimeStats`` mutated inline in the runtime hot path,
``NodeStats``/``SimResult`` scraped by callers) and ``check_invariants``
compared fields that were only correct if every mutation site remembered to
update all of them.  Here a single :class:`TelemetryRegistry` subscribes to
the :class:`~repro.core.events.EventBus` and derives the counters — the
event log is the source of truth, the registry is a fold over it, and the
invariants (≤ 1 preemption per online request, wake-ups == gate enables,
§5 ordering) are checked against what was actually published.

:class:`LatencySummary` replaces the unbounded
``RuntimeStats.preemption_latencies`` list: exact count/mean/max plus a
bounded deterministic reservoir for quantiles, so week-long sim/harness
runs hold O(1) memory.  The retained samples stay list-like (iteration,
len, indexing) and ``raw`` is the escape hatch tests use.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.events import (
    EventBus, MemoryPressureEvent, PageMigration, PreemptionEvent,
    PrefillHandoff, ReclamationEvent, ReservationChangeEvent, RuntimeEvent,
    WakeupEvent, check_event_ordering)

__all__ = ['LatencySummary', 'TelemetryRegistry']


class LatencySummary:
    """Streaming latency record: exact count/mean/max, bounded reservoir
    for quantiles (Vitter's Algorithm R with a seeded RNG — deterministic
    given the sample sequence).

    Below ``cap`` samples the reservoir IS the full raw sequence in arrival
    order, so existing ``list(...)``-style test assertions keep working;
    past ``cap`` the quantiles become estimates while count/mean/max stay
    exact.  ``raw`` is the retained-samples escape hatch.
    """

    def __init__(self, cap: int = 512, seed: int = 0):
        assert cap >= 1
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self._samples: List[float] = []
        self._rng = random.Random(seed)

    # -- recording ---------------------------------------------------------
    def record(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        if x > self.max:
            self.max = x
        if len(self._samples) < self.cap:
            self._samples.append(x)
        else:
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self._samples[j] = x

    append = record                      # list-compat alias

    # -- list compatibility (exact while count ≤ cap) ----------------------
    def __len__(self) -> int:
        return self.count

    def __iter__(self):
        return iter(self._samples)

    def __getitem__(self, i):
        return self._samples[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, LatencySummary):
            return self._samples == other._samples and \
                self.count == other.count
        return self._samples == other     # compare against plain lists

    def __repr__(self) -> str:
        return (f'LatencySummary(count={self.count}, mean={self.mean:.6g}, '
                f'p50={self.p50:.6g}, p99={self.p99:.6g}, '
                f'max={self.max:.6g})')

    @property
    def raw(self) -> List[float]:
        """Retained samples (the full sequence while count ≤ cap)."""
        return list(self._samples)

    @property
    def exact(self) -> bool:
        return self.count <= self.cap

    # -- statistics --------------------------------------------------------
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        idx = min(int(q * (len(s) - 1) + 0.5), len(s) - 1)
        return s[idx]

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    def summary(self) -> Dict[str, float]:
        return {'count': self.count, 'mean': self.mean, 'p50': self.p50,
                'p99': self.p99, 'max': self.max}


@dataclass
class _Counters:
    preemptions: int = 0
    wakeups: int = 0
    reclamations: int = 0
    handles_reclaimed: int = 0
    pages_invalidated: int = 0
    requests_invalidated: int = 0
    requests_killed: int = 0
    memory_pressure_events: int = 0
    reservation_changes: int = 0
    pages_migrated: int = 0              # cross-pool rescue pages
    requests_migrated: int = 0           # cross-pool rescued victims
    prefill_handoffs: int = 0            # disagg: prefill → decode moves
    handoff_pages: int = 0               # disagg: pages copied at handoff
    handoff_recompute_tokens: int = 0    # disagg: must stay 0
    per_request_preemptions: Dict[str, int] = field(default_factory=dict)


class TelemetryRegistry:
    """The one telemetry surface: a fold over the event bus.

    Plane-agnostic — the live :class:`~repro.core.runtime.ValveRuntime`,
    the §7.2 ``NodeSim``, and any test harness attach one to their bus and
    read identical counters.  Optional ``stats``/``lifecycle`` hooks keep
    the legacy ``RuntimeStats``/``LifecycleStats`` dataclasses populated
    (now *derived* from events instead of hand-synced), preserving every
    existing read site during the deprecation window.
    """

    def __init__(self, bus: EventBus, *, stats=None, lifecycle=None,
                 latency_cap: int = 512):
        self.bus = bus
        self.counters = _Counters()
        self.preemption_latencies = LatencySummary(cap=latency_cap)
        self.handoff_latencies = LatencySummary(cap=latency_cap)
        self._stats = stats              # legacy RuntimeStats mirror
        self._lifecycle = lifecycle      # legacy LifecycleStats mirror
        if stats is not None:
            # the summary object replaces the unbounded list in-place
            stats.preemption_latencies = self.preemption_latencies
        # hot path: one dict lookup + one handler call per event
        self._handlers = {
            PreemptionEvent: self._on_preemption,
            WakeupEvent: self._on_wakeup,
            ReclamationEvent: self._on_reclamation,
            MemoryPressureEvent: self._on_pressure,
            ReservationChangeEvent: self._on_reservation,
            PageMigration: self._on_migration,
            PrefillHandoff: self._on_handoff,
        }
        bus.set_fold(self._on_event)

    # ------------------------------------------------------------------
    def _on_event(self, ev: RuntimeEvent) -> None:
        h = self._handlers.get(ev.__class__)
        if h is not None:
            h(ev)

    def _on_preemption(self, ev: PreemptionEvent) -> None:
        c = self.counters
        c.preemptions += 1
        self.preemption_latencies.record(ev.latency_s)
        per = c.per_request_preemptions
        for rid in ev.requests:
            per[rid] = per.get(rid, 0) + 1
        if self._stats is not None:
            self._stats.compute_preemptions += 1
        if self._lifecycle is not None:
            ls = self._lifecycle.stats
            ls.preemptions += 1
            for rid in ev.requests:
                ls.preempted_requests[rid] = \
                    ls.preempted_requests.get(rid, 0) + 1

    def _on_wakeup(self, ev: WakeupEvent) -> None:
        self.counters.wakeups += 1
        if self._stats is not None:
            self._stats.offline_wakeups += 1
        if self._lifecycle is not None:
            self._lifecycle.stats.wakeups += 1

    def _on_reclamation(self, ev: ReclamationEvent) -> None:
        c = self.counters
        c.reclamations += 1
        c.handles_reclaimed += ev.n_handles
        c.pages_invalidated += ev.pages
        if ev.killed:
            c.requests_killed += len(ev.requests)
        else:
            c.requests_invalidated += len(ev.requests)

    def _on_pressure(self, ev: MemoryPressureEvent) -> None:
        self.counters.memory_pressure_events += 1
        if self._stats is not None:
            self._stats.memory_pressure_events += 1

    def _on_reservation(self, ev: ReservationChangeEvent) -> None:
        self.counters.reservation_changes += 1

    def _on_migration(self, ev: PageMigration) -> None:
        # intra-pool re-keys are bookkeeping, not rescues — count only
        # actual cross-pool page movement
        if ev.cross_pool:
            self.counters.pages_migrated += ev.n_pages
            self.counters.requests_migrated += 1

    def _on_handoff(self, ev: PrefillHandoff) -> None:
        c = self.counters
        c.prefill_handoffs += 1
        c.handoff_pages += ev.pages_copied
        c.handoff_recompute_tokens += ev.recompute_tokens
        self.handoff_latencies.record(ev.latency_s)

    # ------------------------------------------------------------------
    @property
    def max_preemptions_per_request(self) -> int:
        return max(self.counters.per_request_preemptions.values(), default=0)

    def snapshot(self) -> Dict[str, object]:
        """One flat dict — what orchestrator metrics / harness reports read
        instead of reaching into per-plane stat objects."""
        c = self.counters
        return {
            'compute_preemptions': c.preemptions,
            'offline_wakeups': c.wakeups,
            'reclamations': c.reclamations,
            'handles_reclaimed': c.handles_reclaimed,
            'pages_invalidated': c.pages_invalidated,
            'requests_invalidated': c.requests_invalidated,
            'requests_killed': c.requests_killed,
            'memory_pressure_events': c.memory_pressure_events,
            'reservation_changes': c.reservation_changes,
            'pages_migrated': c.pages_migrated,
            'requests_migrated': c.requests_migrated,
            'prefill_handoffs': c.prefill_handoffs,
            'handoff_pages': c.handoff_pages,
            'handoff_recompute_tokens': c.handoff_recompute_tokens,
            'handoff_latency': self.handoff_latencies.summary(),
            'max_preemptions_per_request': self.max_preemptions_per_request,
            'preemption_latency': self.preemption_latencies.summary(),
        }

    # ------------------------------------------------------------------
    def check_invariants(self, *, gates=None,
                         require_gate_closed: bool = True,
                         max_preempt_per_request: Optional[int] = 1) -> None:
        """Check the paper's §4–5 invariants against the event log.

        - event ordering (§5 compute-first, §4.2 T_cool wake rule);
        - wake-ups == gate enables when ``gates`` (a GateGroup) is given —
          a wake-up the log never saw, or a gate enable that bypassed the
          wake-up path, both fail here;
        - ≤ ``max_preempt_per_request`` preemptions per online request
          (None disables — baseline strategies violate it by design).
        """
        check_event_ordering(list(self.bus.log),
                             require_gate_closed=require_gate_closed)
        if gates is not None:
            for g in gates.gates:
                assert g.stats.enables == self.counters.wakeups, \
                    (g.device_id, g.stats.enables, self.counters.wakeups)
        if max_preempt_per_request is not None:
            for rid, n in self.counters.per_request_preemptions.items():
                assert n <= max_preempt_per_request, \
                    f'request {rid} preempted {n}× ' \
                    f'(> {max_preempt_per_request})'
