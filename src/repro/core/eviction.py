"""Selective handle reclamation — paper Algorithm 1 (+ FIFO baseline).

The KV cache is not allocated contiguously over memory handles (fragmentation),
so one handle may hold pages of several offline requests.  Valve greedily
selects the ``k`` handles with the lowest *marginal token cost*: the total
extra tokens of requests newly impacted by reclaiming that handle (requests
already impacted by an earlier pick are free).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Sequence, Set


def select_handles(
    k: int,
    handles: Sequence[int],
    reqs_of: Callable[[int], Set[str]],
    cost: Callable[[str], float],
) -> List[int]:
    """Paper Algorithm 1.

    k           — number of handles to reclaim;
    handles     — candidate handle ids (equal size);
    reqs_of(h)  — REQS(h): offline requests with ≥1 page in handle h;
    cost(r)     — COST(r): recompute cost of request r in tokens.
    """
    S: List[int] = []
    chosen: Set[int] = set()
    E: Set[str] = set()
    k = min(k, len(handles))
    for _ in range(k):
        best, best_cost = None, None
        for h in handles:
            if h in chosen:
                continue
            c = sum(cost(r) for r in reqs_of(h) if r not in E)
            if best_cost is None or c < best_cost:
                best, best_cost = h, c
        if best is None:
            break
        S.append(best)
        chosen.add(best)
        E |= reqs_of(best)
    return S


def select_handles_fifo(
    k: int,
    handles_by_age: Sequence[int],
    reqs_of: Callable[[int], Set[str]] = None,
    cost: Callable[[str], float] = None,
) -> List[int]:
    """FIFO baseline (paper §7.2, Fig. 11): evict oldest handles first."""
    return list(handles_by_age[: k])


def impacted_requests(selected: Iterable[int],
                      reqs_of: Callable[[int], Set[str]]) -> Set[str]:
    out: Set[str] = set()
    for h in selected:
        out |= reqs_of(h)
    return out
