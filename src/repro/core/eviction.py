"""Selective handle reclamation — paper Algorithm 1 (+ FIFO baseline).

The KV cache is not allocated contiguously over memory handles (fragmentation),
so one handle may hold pages of several offline requests.  Valve greedily
selects the ``k`` handles with the lowest *marginal token cost*: the total
extra tokens of requests newly impacted by reclaiming that handle (requests
already impacted by an earlier pick are free).

Two cost models:

- :func:`select_handles` — the classic COST(r) model: a request's whole
  recompute cost is paid the first time any of its pages is hit.
- :func:`select_handles_partial` — the memory-plane model (partial
  invalidation): hitting a page only costs the tokens between the request's
  *surviving prefix* and its current fill, so the marginal cost of a handle
  depends on the lowest logical position it would knock out given the picks
  so far (``repro.core.memory.MemoryPlane.recompute_cost``).

Both are **memoized**: per-handle costs are cached and only handles sharing
a request with the previous pick are re-scored (the naive loop re-scored
every handle every round — O(k·H·R)).  ``_select_handles_naive`` keeps the
textbook implementation as the property-test oracle; the memoized versions
are tie-break-identical to it.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Sequence, Set

_INF = 1 << 30


def _select_handles_naive(
    k: int,
    handles: Sequence[int],
    reqs_of: Callable[[int], Set[str]],
    cost: Callable[[str], float],
) -> List[int]:
    """Reference implementation (paper Algorithm 1, verbatim greedy) —
    the oracle the memoized version is property-tested against."""
    S: List[int] = []
    chosen: Set[int] = set()
    E: Set[str] = set()
    k = min(k, len(handles))
    for _ in range(k):
        best, best_cost = None, None
        for h in handles:
            if h in chosen:
                continue
            c = sum(cost(r) for r in reqs_of(h) if r not in E)
            if best_cost is None or c < best_cost:
                best, best_cost = h, c
        if best is None:
            break
        S.append(best)
        chosen.add(best)
        E |= reqs_of(best)
    return S


def select_handles(
    k: int,
    handles: Sequence[int],
    reqs_of: Callable[[int], Set[str]],
    cost: Callable[[str], float],
) -> List[int]:
    """Paper Algorithm 1 (memoized).

    k           — number of handles to reclaim;
    handles     — candidate handle ids (equal size);
    reqs_of(h)  — REQS(h): offline requests with ≥1 page in handle h;
    cost(r)     — COST(r): recompute cost of request r in tokens.

    Per-handle costs are computed once, then only handles intersecting the
    last pick's request set are re-scored — identical picks (including tie
    breaks: first-lowest in ``handles`` order) to the naive O(k·H·R) loop.
    """
    k = min(k, len(handles))
    if k <= 0:
        return []
    req_sets: Dict[int, Set[str]] = {h: set(reqs_of(h)) for h in handles}
    by_req: Dict[str, Set[int]] = {}
    for h in handles:
        for r in req_sets[h]:
            by_req.setdefault(r, set()).add(h)
    E: Set[str] = set()
    cached: Dict[int, float] = {
        h: sum(cost(r) for r in req_sets[h]) for h in handles}
    S: List[int] = []
    chosen: Set[int] = set()
    for _ in range(k):
        best, best_cost = None, None
        for h in handles:
            if h in chosen:
                continue
            c = cached[h]
            if best_cost is None or c < best_cost:
                best, best_cost = h, c
        if best is None:
            break
        S.append(best)
        chosen.add(best)
        newly = req_sets[best] - E
        E |= newly
        dirty: Set[int] = set()
        for r in newly:
            dirty |= by_req[r]
        for h in dirty:
            if h not in chosen:
                cached[h] = sum(cost(r) for r in req_sets[h] if r not in E)
    return S


def select_handles_partial(
    k: int,
    handles: Sequence[int],
    impact_of: Callable[[int], Dict[str, int]],
    loss_of: Callable[[str, int], float],
) -> List[int]:
    """Algorithm 1 under partial (surviving-prefix) invalidation.

    impact_of(h)     — {request id: lowest logical page index lost} if ``h``
                       were reclaimed;
    loss_of(r, idx)  — tokens request ``r`` must recompute if its surviving
                       prefix is cut at logical page ``idx`` (monotone
                       non-increasing in ``idx``; ``loss_of(r, ∞) == 0``).

    The marginal cost of a handle is the *additional* recompute its cut
    positions cause beyond the cuts already inflicted by earlier picks —
    memoized with the same dirty-set re-scoring as :func:`select_handles`.
    """
    k = min(k, len(handles))
    if k <= 0:
        return []
    impact: Dict[int, Dict[str, int]] = {h: dict(impact_of(h))
                                         for h in handles}
    by_req: Dict[str, Set[int]] = {}
    for h in handles:
        for r in impact[h]:
            by_req.setdefault(r, set()).add(h)
    cut: Dict[str, int] = {}           # rid → lowest idx cut by picks so far
    cut_loss: Dict[str, float] = {}    # rid → loss already paid at that cut

    def marginal(h: int) -> float:
        tot = 0.0
        for r, idx in impact[h].items():
            if idx < cut.get(r, _INF):
                tot += loss_of(r, idx) - cut_loss.get(r, 0.0)
        return tot

    cached: Dict[int, float] = {h: marginal(h) for h in handles}
    S: List[int] = []
    chosen: Set[int] = set()
    for _ in range(k):
        best, best_cost = None, None
        for h in handles:
            if h in chosen:
                continue
            c = cached[h]
            if best_cost is None or c < best_cost:
                best, best_cost = h, c
        if best is None:
            break
        S.append(best)
        chosen.add(best)
        dirty: Set[int] = set()
        for r, idx in impact[best].items():
            if idx < cut.get(r, _INF):
                cut[r] = idx
                cut_loss[r] = loss_of(r, idx)
            dirty |= by_req[r]
        for h in dirty:
            if h not in chosen:
                cached[h] = marginal(h)
    return S


def select_handles_fifo(
    k: int,
    handles_by_age: Sequence[int],
    reqs_of: Callable[[int], Set[str]] = None,
    cost: Callable[[str], float] = None,
) -> List[int]:
    """FIFO baseline (paper §7.2, Fig. 11): evict oldest handles first."""
    return list(handles_by_age[: k])


def impacted_requests(selected: Iterable[int],
                      reqs_of: Callable[[int], Set[str]]) -> Set[str]:
    out: Set[str] = set()
    for h in selected:
        out |= reqs_of(h)
    return out
