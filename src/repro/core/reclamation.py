"""Page-fault-free sub-layer memory reclamation (paper §5).

The reclamation path, in the paper's mandatory order:

1. **compute first** — offline gates are disabled so no in-flight program can
   touch pages being reclaimed (the runtime enforces the ordering and this
   module asserts it);
2. **select victims** — Algorithm 1 (or FIFO baseline) picks the handles with
   the lowest marginal token cost;
3. **remap to quarantine** — every mapped page of a victim handle is remapped
   to page 0, which is always mapped, so by construction no access can fault;
4. **surface invalidated IDs** — the per-request invalidation records are
   pushed through a single framework callback (the < 20-LOC patch surface);
   since Memory-plane API v1 each record is a
   :class:`~repro.core.memory.LeaseInvalidation` carrying the **surviving
   prefix** (``keep``/``resume``), so the framework resumes
   recompute *from the surviving prefix* instead of restarting at token 0.
   Requests allocated around the plane degrade to the legacy whole-request
   semantics (``keep == 0``).

Victim selection runs Algorithm 1 over the plane's *marginal
recompute-from-surviving-prefix* cost (``MemoryPlane.recompute_cost``) —
unfilled tails and zero-ref cached prefixes are free to take.

A :class:`ReclamationRateLimiter` tracks the reclamation-event rate that the
MIAD reservation is driving toward the user target.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

from repro.core import eviction
from repro.core.memory import MemoryPlane
from repro.serving.kvpool import KVPool

# type of the framework-side patch surface: called once per reclamation with
# {offline request id: LeaseInvalidation} — each value iterates as the
# legacy invalidated-page-id list, so un-migrated callbacks keep working
InvalidationCallback = Callable[[Dict[str, List[int]]], None]


@dataclass
class ReclamationStats:
    reclamations: int = 0
    handles_reclaimed: int = 0
    pages_invalidated: int = 0
    requests_impacted: int = 0
    tokens_lost: float = 0.0           # recompute cost surfaced to offline
    ordering_violations: int = 0       # must stay 0: compute-before-memory


class ReclamationRateLimiter:
    """Sliding-window reclamation-event rate (events/s)."""

    def __init__(self, window_s: float = 60.0):
        self.window_s = window_s
        self._events: Deque[float] = deque()
        self._t0: Optional[float] = None     # first observation time

    def note(self, now: float) -> None:
        if self._t0 is None:
            self._t0 = now
        self._events.append(now)
        self._trim(now)

    def _trim(self, now: float) -> None:
        w = self.window_s
        while self._events and self._events[0] < now - w:
            self._events.popleft()

    def rate(self, now: float) -> float:
        """Events per second over the *elapsed* horizon: before a full
        window has been observed, divide by the time actually observed —
        dividing by ``window_s`` would underestimate warm-up bursts (same
        bug class as ``MIADReservation._event_rate``)."""
        self._trim(now)
        if len(self._events) < 2:
            # one event over ~zero elapsed time is rate-indeterminate —
            # use the full window (see MIADReservation._event_rate)
            return len(self._events) / self.window_s
        start = self._t0 if self._t0 is not None else self._events[0]
        horizon = min(self.window_s, max(now - start, 1e-3))
        return len(self._events) / horizon


class ReclamationController:
    """Coordinates compute preemption with memory reclamation over one pool.

    ``gate_is_closed`` is a runtime-supplied predicate proving offline compute
    is already disabled — reclaiming while it returns False is the exact bug
    class (in-flight kernel touches an unmapped page) the paper's ordering
    rule exists to prevent, and is recorded as an ordering violation.
    """

    def __init__(self, pool: KVPool, *,
                 gate_is_closed: Callable[[], bool],
                 on_invalidate: Optional[InvalidationCallback] = None,
                 policy: str = 'valve',
                 cost_of: Optional[Callable[[str], float]] = None,
                 rate_window_s: float = 60.0,
                 bus=None):
        assert policy in ('valve', 'fifo'), policy
        self.pool = pool
        self.plane = MemoryPlane.of(pool)
        self.gate_is_closed = gate_is_closed
        self.on_invalidate = on_invalidate
        self.policy = policy
        # optional typed event stream (repro.core.events.EventBus): each
        # reclamation publishes one ReclamationEvent before the framework
        # callback fires, so subscribers see the fact before the reaction
        self.bus = bus
        # COST(r): by default the plane's marginal recompute-from-surviving-
        # prefix tokens; a custom ``cost_of`` opts back into the classic
        # whole-request cost model of paper Algorithm 1
        self.cost_of = cost_of
        self.rate = ReclamationRateLimiter(rate_window_s)
        self.stats = ReclamationStats()
        self._handle_age: Dict[int, float] = {}

    # ------------------------------------------------------------- victims
    def select_victims(self, k: int) -> List[int]:
        cand = self.pool.offline_handles()
        if self.policy == 'fifo':
            by_age = sorted(cand, key=lambda h: self._handle_age.get(h, 0.0))
            return eviction.select_handles_fifo(k, by_age)
        if self.cost_of is not None:
            return eviction.select_handles(
                k, cand, self.pool.reqs_of_handle, self.cost_of)
        return eviction.select_handles_partial(
            k, cand, self.plane.impact_of, self.plane.recompute_cost)

    def note_handle_use(self, h: int, now: float) -> None:
        """FIFO baseline bookkeeping: first-touch age per handle."""
        self._handle_age.setdefault(h, now)

    # ----------------------------------------------------------- reclaim
    def reclaim(self, n_handles: int, now: float) -> Dict[str, List[int]]:
        """Reclaim ``n_handles`` offline handles for online use.

        Returns the invalidation map {offline req: LeaseInvalidation} (also
        pushed through ``on_invalidate``).  Caller must hold the compute
        gate closed.
        """
        if not self.gate_is_closed():
            self.stats.ordering_violations += 1
            raise RuntimeError(
                'reclamation attempted with offline compute enabled '
                '(paper §5: disable offline compute first)')
        victims = self.select_victims(n_handles)
        invalidated = self.plane.reclaim_handles(victims, now)
        for h in victims:
            self._handle_age.pop(h, None)

        self.stats.reclamations += 1
        self.stats.handles_reclaimed += len(victims)
        # rescued (cross-pool migrated) victims lost nothing — their KV
        # moved intact, so they are not "invalidated" for stats or the
        # event; the pool already published PageMigration for each
        truncated = {rid: v for rid, v in invalidated.items()
                     if getattr(v, 'migrated_to', None) is None}
        # PHYSICAL pages: a shared prefix page appears in every using
        # lease's record — count each page id once
        n_pages = len({p for v in truncated.values() for p in v})
        self.stats.pages_invalidated += n_pages
        self.stats.requests_impacted += len(truncated)
        # recompute tax actually inflicted: fill lost beyond the surviving
        # prefix (legacy ids report their remapped pages, as before)
        self.stats.tokens_lost += sum(v.lost_tokens
                                      for v in truncated.values())
        self.rate.note(now)

        if self.bus is not None:
            from repro.core.events import ReclamationEvent
            # rescued victims are named so check_event_ordering can prove
            # each had its PageMigration (= data-plane copy) published
            # BEFORE this event frees the source pages for reallocation
            self.bus.publish(
                ReclamationEvent, n_handles=len(victims),
                requests=tuple(sorted(truncated)),
                pages=n_pages,
                gate_closed=True,
                rescued=tuple(sorted(set(invalidated) - set(truncated))))

        if self.on_invalidate is not None and invalidated:
            self.on_invalidate(invalidated)
        return invalidated
