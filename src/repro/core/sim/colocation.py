"""Single-node colocation simulator (paper §7.2).

One GPU resource, one latency-critical ONLINE engine, one throughput
OFFLINE engine, pluggable compute/memory policies (strategies.py).  The
simulation is sequential in time (single resource ⇒ no event heap needed):
the online engine always wins the GPU, paying the strategy's preemption
delay when offline holds it; offline backfills idle per the strategy's
wake rule and memory headroom.

Calibration (7B-class model, production-scale numbers the paper quotes):
prefill ≈ 50 µs/token (32 k prompt → 1.6 s — why layer-level preemption
stretches to "hundreds of ms"), decode iteration ≈ 30 ms with ≈ 2 ms
host-side gaps between iterations (paper Fig. 4).

Work conservation: Channel/GPreempt context-save the in-flight offline
dispatch (it resumes later); KernelPreempt drains it (online eats the full
residual, offline keeps the work).  Valve invalidations preserve generated
tokens and requeue a recompute prefill; UVM/StaticMem kills restart the
request and forfeit its generated tokens.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import (
    EventBus, MemoryPressureEvent, PreemptionEvent, ReclamationEvent,
    WakeupEvent)
from repro.core.sim.strategies import (
    AllocResult, Channel, ComputePolicy, GPreempt, KernelPreempt,
    MemoryPolicy, OurMem, Prism, StaticMem, UVM)
from repro.core.sim.workload import OnlineRequest, WorkloadPair
from repro.core.telemetry import TelemetryRegistry


@dataclass
class SimConfig:
    total_pages: int = 4096
    page_tokens: int = 16
    t_prefill_per_token: float = 50e-6
    t_decode_iter: float = 0.030
    t_decode_gap: float = 0.002
    online_max_batch: int = 32
    miad_tick: float = 0.25          # MIAD/lifecycle maintenance cadence
    # batched decode fast path: steady pure-decode stretches (online and
    # offline) execute without the per-request Python inner loop, replaying
    # the exact scalar float/rng/event sequence — SimResult telemetry is
    # bit-identical (gated in benchmarks/fleet_placement.py); the 100+-node
    # fleet harness needs this to stay inside CI budget
    vectorized: bool = False
    # -- watchdogs (long-horizon workloads tune these instead of tripping
    # the defaults) --
    watchdog_guard_steps: int = 50_000_000   # hard non-termination assert
    watchdog_stall_steps: int = 20_000       # zero-advance loops before forcing
    watchdog_force_step_s: float = 0.001     # forced clock step on a stall


@dataclass
class OnlineState:
    req: OnlineRequest
    pages: int = 0
    prefilled: bool = False
    tokens_done: int = 0
    t_first: Optional[float] = None
    t_last: Optional[float] = None
    stall: float = 0.0               # memory stall paid at admission


@dataclass
class OfflineReq:
    rid: str
    prefill_tokens: int              # tokens to (re)compute before decoding
    out_remaining: int
    pages: int
    generated: int = 0
    filled: int = 0                  # KV materialized (mirrors the lease)
    blocked: int = 0                 # consecutive failed re-allocations

    def __post_init__(self):
        self.prompt0 = self.prefill_tokens   # original prompt length
        self.pages0 = self.pages             # full page need (for realloc)


@dataclass
class SimResult:
    name: str
    ttft: Dict[str, float] = field(default_factory=dict)
    tpot: Dict[str, float] = field(default_factory=dict)
    offline_tokens: float = 0.0
    offline_tokens_wasted: float = 0.0
    recompute_tokens: float = 0.0
    horizon: float = 0.0
    compute_stats: object = None
    mem_stats: object = None
    max_preempt_per_request: int = 0
    # -- measured node telemetry (feeds the §6 cluster perf model) --
    # online-busy GPU spans (decode gaps coalesced) and the trace of memory
    # NOT held by online — exactly the inputs Eq. 1's P_compute / P_memory /
    # P_multi consume, so the cluster scheduler can run on simulated-measured
    # data instead of hand-written telemetry
    busy_intervals: List[Tuple[float, float]] = field(default_factory=list)
    mem_trace_t: List[float] = field(default_factory=list)
    mem_trace_free: List[float] = field(default_factory=list)
    # requests whose KV need exceeds the whole pool — rejected at admission
    # (the real engine returns a max-context error; admitting head-of-line
    # would block the queue forever)
    rejected: List[str] = field(default_factory=list)
    # -- the control-plane view (same typed stream the live runtime emits):
    # a TelemetryRegistry folding the sim's event bus — the cluster harness
    # reads these counters instead of scraping compute/mem stat objects
    telemetry: Optional[TelemetryRegistry] = None
    events: List[object] = field(default_factory=list)

    @property
    def offline_throughput(self) -> float:
        return self.offline_tokens / max(self.horizon, 1e-9)

    def online_busy_fraction(self) -> float:
        busy = sum(b - a for a, b in self.busy_intervals)
        return busy / max(self.horizon, 1e-9)


class NodeSim:
    def __init__(self, pair: WorkloadPair, compute: Optional[ComputePolicy],
                 memory: MemoryPolicy, cfg: Optional[SimConfig] = None,
                 *, offline_enabled: bool = True, events: bool = True):
        self.pair = pair
        self.cp = compute
        self.mp = memory
        self.cfg = cfg or SimConfig()
        self.offline_enabled = offline_enabled
        # typed event stream (identical shape to the live runtime's):
        # preemptions, reclamations (gate_closed=False for the baselines
        # that move pages under running compute — their §5 violation made
        # visible), wake-ups.  ``events=False`` is the overhead-measurement
        # baseline for benchmarks/api_overhead.py.
        self.bus = EventBus() if events else None
        self.telemetry = (TelemetryRegistry(self.bus)
                          if self.bus is not None else None)
        self._gated_since_wake = False

        self.now = 0.0
        self.arriv = list(pair.online.requests)
        self.next_arrival = 0
        self.waiting: List[OnlineState] = []
        self.active: List[OnlineState] = []
        self.result = SimResult(pair.name)

        # offline engine
        self._off_ids = itertools.count()
        self.off_pending: List[OfflineReq] = []   # needs (re)prefill
        self.off_running: List[OfflineReq] = []   # decoding
        # shared system prompt (HyGen-style): every offline request passes
        # the same synthetic token prefix to lease-capable memory policies,
        # which attach the published pages instead of re-prefilling them
        n_shared = pair.offline.shared_prefix_tokens
        self._prefix_base = list(range(n_shared)) if n_shared > 0 else None
        self.off_busy_until = 0.0
        self.off_inflight: Optional[Tuple[str, float, List[OfflineReq]]] = None
        # ('prefill'|'decode', started_at, targets)
        self._last_tick = 0.0

    # ------------------------------------------------------------------
    # Offline bookkeeping
    # ------------------------------------------------------------------
    def _off_sizes(self) -> Tuple[int, int]:
        """(prompt, output) for the next offline request (size mix aware)."""
        w = self.pair.offline
        if w.prompt_choices:
            if not hasattr(self, '_off_rng'):
                import numpy as np
                self._off_rng = np.random.default_rng(w.seed)
            p = int(self._off_rng.choice(w.prompt_choices))
            o = int(self._off_rng.choice(w.output_choices or
                                         (w.output_tokens,)))
            return p, o
        return w.prompt_tokens, w.output_tokens

    def _off_pages_needed(self, prompt: int, out: int) -> int:
        return -(-(prompt + out) // self.cfg.page_tokens)

    def _off_prefix(self, prompt: int) -> Optional[List[int]]:
        """The shared system prompt clamped below this request's prompt
        length (≥1 token always remains to prefill)."""
        if self._prefix_base is None:
            return None
        return self._prefix_base[: max(0, prompt - 1)]

    def _off_resync(self, r: OfflineReq) -> None:
        """Align a request's prefill need with its lease's valid-KV prefix
        (shared attach on admission, surviving prefix after re-extension)."""
        resume = self.mp.resume_tokens(r.rid)
        if resume > r.filled:
            r.prefill_tokens = max(1, (r.prompt0 + r.generated) - resume)
            r.filled = resume

    def _off_admit(self) -> None:
        """Top up in-flight offline requests while memory allows."""
        w = self.pair.offline
        while (len(self.off_running) + len(self.off_pending) < w.max_batch):
            rid = f'off-{next(self._off_ids)}'
            prompt, out = self._off_sizes()
            pages = self._off_pages_needed(prompt, out)
            if not self.mp.alloc_offline(rid, pages, self.now,
                                         self._off_prefix(prompt)):
                break
            r = OfflineReq(rid, prompt, out, pages)
            self._off_resync(r)      # shared prefix: skip its prefill
            self.off_pending.append(r)

    def _off_invalidate(self, res: AllocResult) -> None:
        """Apply a memory policy's invalidations/kills to the offline engine."""
        byid = {r.rid: r for r in self.off_pending + self.off_running}
        for rid in set(res.invalidated) | res.killed:
            r = byid.get(rid)
            if r is None:
                continue
            if r in self.off_pending:
                self.off_pending.remove(r)
            if r in self.off_running:
                self.off_running.remove(r)
            if rid in res.killed:
                # restart from zero: generated work forfeited
                self.result.offline_tokens -= r.generated
                self.result.offline_tokens_wasted += r.generated
                self.mp.free_offline(rid)
            else:
                # Valve: tokens kept; recompute only what was materialized
                # BEYOND the surviving prefix, then resume.  Whole-request
                # policies report no survivors → full restart as before.
                surv = res.surviving.get(rid, 0)
                self.result.recompute_tokens += max(0, r.filled - surv)
                r.prefill_tokens = max(
                    1, (r.prompt0 + r.generated) - surv)
                r.filled = min(r.filled, surv)
                # surviving pages stay leased; the lost tail re-extends
                # lazily at the next offline dispatch (an immediate re-grab
                # would steal the pages the online burst is reclaiming FOR
                # and thrash the reclaimer)
                r.pages = self.mp.held_pages(rid)
                if r.pages == 0:
                    self.mp.free_offline(rid)
                self.off_pending.insert(0, r)
        # drop in-flight dispatch targets that vanished
        if self.off_inflight is not None:
            kind, t0, targets = self.off_inflight
            targets = [t for t in targets
                       if t in self.off_running or t in self.off_pending]
            self.off_inflight = (kind, t0, targets)

    def _publish_wakeup(self) -> None:
        """First offline dispatch after a preemption = the wake-up; record
        the §4.2 wake-rule inputs (idle span vs T_cool) when the compute
        policy tracks them (Channel — the Valve path)."""
        if self.bus is None or not self._gated_since_wake:
            return
        lc = getattr(self.cp, 'lifecycle', None)
        self.bus.publish(
            WakeupEvent, t=self.now,
            idle_for_s=lc.idle_for(self.now) if lc is not None else 0.0,
            t_cool_s=lc.t_cool if lc is not None else 0.0)
        self._gated_since_wake = False

    def _off_start_dispatch(self) -> bool:
        """Start one offline dispatch at self.now if there is work."""
        if not self.offline_enabled:
            return False
        self._off_admit()
        # re-extend recompute victims to their full page need (surviving
        # leases keep their prefix; dead ones re-admit, possibly attaching
        # a shared prefix again)
        for r in self.off_pending:
            if r.pages >= r.pages0:
                continue
            if self.mp.alloc_offline(r.rid, r.pages0, self.now,
                                     self._off_prefix(r.prompt0)):
                r.pages, r.blocked = r.pages0, 0
                self._off_resync(r)
            else:
                # sustained pressure: surviving prefixes held by blocked
                # victims must not starve re-admission — spill our own
                # survivors and fall back to whole-request recompute
                r.blocked += 1
                if r.blocked >= 3 and r.pages > 0:
                    # the forfeited surviving prefix is recompute work too
                    self.result.recompute_tokens += r.filled
                    self.mp.free_offline(r.rid)
                    r.pages, r.filled, r.blocked = 0, 0, 0
                    r.prefill_tokens = r.prompt0 + r.generated
        ready_pending = [r for r in self.off_pending if r.pages >= r.pages0]
        if ready_pending:
            r = ready_pending[0]
            dur = r.prefill_tokens * self.cfg.t_prefill_per_token
            self.off_inflight = ('prefill', self.now, [r])
            self.off_busy_until = self.now + dur
            self._publish_wakeup()
            return True
        if self.off_running:
            self.off_inflight = ('decode', self.now, list(self.off_running))
            self.off_busy_until = self.now + self.cfg.t_decode_iter
            self._publish_wakeup()
            return True
        return False

    def _off_complete_dispatch(self) -> None:
        """Apply the effects of the offline dispatch ending at off_busy_until."""
        kind, t0, targets = self.off_inflight
        self.off_inflight = None
        if kind == 'prefill':
            if not targets:        # victim invalidated while in flight
                return
            r = targets[0]
            if r in self.off_pending:
                self.off_pending.remove(r)
                self.off_running.append(r)
                # the whole context is materialized now — the lease's fill
                # fact drives prefix publication and surviving prefixes
                r.filled = r.prompt0 + r.generated
                self.mp.note_filled(r.rid, r.filled)
        else:
            for r in targets:
                if r not in self.off_running:
                    continue
                r.generated += 1
                r.out_remaining -= 1
                r.filled = r.prompt0 + r.generated
                self.mp.note_filled(r.rid, r.filled)
                self.result.offline_tokens += 1
                if r.out_remaining <= 0:
                    self.off_running.remove(r)
                    self.mp.free_offline(r.rid)

    def _off_preempt(self, online_t: float) -> float:
        """Online needs the GPU at ``online_t`` while offline is in flight.
        Returns when online may start."""
        if self.off_inflight is None or self.off_busy_until <= online_t:
            if self.off_busy_until > 0 and self.off_inflight is not None \
                    and self.off_busy_until <= online_t:
                self._off_complete_dispatch()
            return online_t
        remaining = self.off_busy_until - online_t
        delay = self.cp.preempt_delay(remaining)
        # only ADMITTED requests experience the preemption (queued requests
        # aren't executing)
        active_ids = {s.req.req_id for s in self.active}
        self.cp.note_preemption(active_ids, delay)
        if self.bus is not None:
            self.bus.publish(PreemptionEvent, t=online_t, latency_s=delay,
                             requests=tuple(sorted(active_ids)),
                             trigger='lifecycle')
        self._gated_since_wake = True
        if isinstance(self.cp, KernelPreempt):
            # drain: the offline iteration completes
            self.off_busy_until = online_t + delay
            self._off_complete_dispatch()
        else:
            # context save: the dispatch's remaining work returns to queue
            kind, t0, targets = self.off_inflight
            self.off_inflight = None
            if kind == 'prefill' and targets:
                done_frac = max(0.0, (online_t - t0)
                                / max(self.off_busy_until - t0, 1e-12))
                r = targets[0]
                # round UP and clamp to ≥1: the dispatch did NOT complete
                # (we are strictly before off_busy_until), so truncating a
                # nearly-finished prefill to 0 remaining tokens would credit
                # offline with free work on resume
                remaining = r.prefill_tokens * (1.0 - done_frac)
                r.prefill_tokens = max(1, int(math.ceil(remaining - 1e-9)))
            # decode iteration: tokens not produced; requests stay running
            self.off_busy_until = online_t + delay
        return online_t + delay

    # ------------------------------------------------------------------
    # Measured telemetry (the cluster plane's view of this node)
    # ------------------------------------------------------------------
    def _note_busy(self, a: float, b: float) -> None:
        """Record an online-busy span; spans separated by ≤ 2 decode gaps
        coalesce (the inter-iteration gap is not harvestable idle — that is
        the whole point of T_cool)."""
        if b <= a:
            return
        iv = self.result.busy_intervals
        if iv and a <= iv[-1][1] + 2.0 * self.cfg.t_decode_gap + 1e-9:
            iv[-1] = (iv[-1][0], max(iv[-1][1], b))
        else:
            iv.append((a, b))

    def _sample_mem(self, now: float) -> None:
        """Sample pages NOT held by online — the memory a colocated offline
        job could occupy at this instant (Eq. 2's free-memory trace)."""
        free_for_offline = self.mp.total - sum(self.mp.online_pages.values())
        tr_t = self.result.mem_trace_t
        if tr_t and now <= tr_t[-1] + 1e-12:
            self.result.mem_trace_free[-1] = free_for_offline
            return
        tr_t.append(now)
        self.result.mem_trace_free.append(float(free_for_offline))

    # ------------------------------------------------------------------
    # Online engine
    # ------------------------------------------------------------------
    def _pages_for(self, req: OnlineRequest) -> int:
        return -(-(req.prompt_tokens + req.output_tokens)
                 // self.cfg.page_tokens)

    def _pump_arrivals(self) -> None:
        while (self.next_arrival < len(self.arriv)
               and self.arriv[self.next_arrival].t_arrive <= self.now):
            req = self.arriv[self.next_arrival]
            self.next_arrival += 1
            self.waiting.append(OnlineState(req))
            # lifecycle start fires at ADMISSION (like the real engine): a
            # queued-but-unadmitted request produces no GPU activity, and
            # gating offline on it deadlocks Prism (online waits for memory
            # offline holds; offline waits for online idle)

    def _admit_online(self) -> None:
        while self.waiting and len(self.active) < self.cfg.online_max_batch:
            st = self.waiting[0]
            if self._pages_for(st.req) > self.mp.total:
                # oversized: no admission order can ever satisfy it — reject
                # like the real engine's max-context error instead of
                # livelocking the head of the queue
                self.waiting.pop(0)
                self.result.rejected.append(st.req.req_id)
                continue
            res = self.mp.alloc_online(st.req.req_id,
                                       self._pages_for(st.req), self.now)
            if res.reclaimed and self.bus is not None:
                self.bus.publish(MemoryPressureEvent, t=self.now,
                                 req_id=st.req.req_id,
                                 deficit_pages=res.deficit_pages)
                # physical pages: with leases a shared prefix page appears
                # in every using lease's record — count each page id once.
                # Whole-request policies use SYMBOLIC per-request ids
                # (range(n) each), where a set union would undercount.
                if self.mp.supports_leases:
                    n_pages = len({p for v in res.invalidated.values()
                                   for p in v})
                else:
                    n_pages = sum(len(v) for v in res.invalidated.values())
                self.bus.publish(
                    ReclamationEvent, t=self.now,
                    n_handles=res.reclaimed_handles,
                    requests=tuple(sorted(set(res.invalidated) | res.killed)),
                    pages=n_pages,
                    gate_closed=res.gate_closed, killed=bool(res.killed))
            self._off_invalidate(res)
            if not res.ok:
                break                       # head-of-line blocks (Prism)
            self.now += res.delay           # reclamation/fault stall
            st.stall += res.delay
            st.pages = self._pages_for(st.req)
            self.waiting.pop(0)
            self.active.append(st)
            if self.cp:
                self.cp.on_online_request_start(st.req.req_id, self.now)

    def _finish_online(self, st: OnlineState) -> None:
        self.active.remove(st)
        self.mp.free_online(st.req.req_id)
        if self.cp:
            self.cp.on_online_request_end(st.req.req_id, self.now)
        r = st.req
        self.result.ttft[r.req_id] = st.t_first - r.t_arrive
        if r.output_tokens > 1:
            self.result.tpot[r.req_id] = ((st.t_last - st.t_first)
                                          / (r.output_tokens - 1))

    def _online_dispatch(self) -> bool:
        """Run one online dispatch; returns True if one ran."""
        self._pump_arrivals()
        self._admit_online()
        needs_prefill = [s for s in self.active if not s.prefilled]
        decoding = [s for s in self.active if s.prefilled]
        if not needs_prefill and not decoding:
            return False
        start = self._off_preempt(self.now)
        self.now = start
        if needs_prefill:
            st = needs_prefill[0]
            dur = st.req.prompt_tokens * self.cfg.t_prefill_per_token
            self.now += dur
            st.prefilled = True
            st.tokens_done = 1              # prefill emits the first token
            st.t_first = st.t_last = self.now
            self._note_busy(start, self.now)
            if self.cp:
                self.cp.on_online_iter(start, self.now)
            if st.req.output_tokens <= 1:
                self._finish_online(st)
            return True
        # decode iteration over the whole batch
        self.now += self.cfg.t_decode_iter
        self._note_busy(start, self.now)
        if self.cp:
            self.cp.on_online_iter(start, self.now)
        for st in list(decoding):
            st.tokens_done += 1
            st.t_last = self.now
            if st.tokens_done >= st.req.output_tokens:
                self._finish_online(st)
        # the inter-iteration gap (paper Fig. 4): immediate-wake policies
        # inject offline work here — and pay a preemption at the next
        # iteration; Channel's T_cool (> gap) never fires in a gap
        if (self.offline_enabled and self.off_inflight is None
                and self.active and self.cp is not None
                and self.cp.offline_may_start(self.now)):
            self._off_start_dispatch()
        self.now += self.cfg.t_decode_gap
        return True

    # ------------------------------------------------------------------
    # Batched decode fast path (cfg.vectorized)
    # ------------------------------------------------------------------
    def _burst_online_decode(self) -> bool:
        """Run K back-to-back online decode iterations without the
        per-request inner loop.

        Bit-identity with the scalar loop is the contract: the clock
        replays the exact scalar float sequence (``now += t_iter``;
        ``now += t_gap``), ticks/lifecycle/busy-span calls fire at the
        same instants, and the only deferred state — per-request
        ``tokens_done``/``t_last`` — is integer-counted and flushed once,
        which is exact in float64.  Ticks run inline at their scalar
        instants; the burst stops one iteration before any finish and
        breaks at arrivals and offline wake-ups, so the scalar loop
        handles every state transition.
        """
        active = self.active
        if not active or self.waiting or self.off_inflight is not None:
            return False
        for st in active:
            if not st.prefilled:
                return False
        # batch the per-request remaining-token bound over the whole batch
        k_max = int(np.fromiter(
            (st.req.output_tokens - st.tokens_done for st in active),
            dtype=np.int64, count=len(active)).min()) - 1
        if k_max < 1:
            return False
        cfg = self.cfg
        t_iter, t_gap = cfg.t_decode_iter, cfg.t_decode_gap
        tick_every = cfg.miad_tick
        arriv, n_arr = self.arriv, len(self.arriv)
        cp = self.cp
        now = self.now
        last_end = now
        executed = 0
        while executed < k_max:
            i = self.next_arrival
            if i < n_arr and arriv[i].t_arrive <= now:
                break            # scalar entry pumps + admits the arrival
            if executed and now - self._last_tick >= tick_every:
                self._last_tick = now     # iteration 0's tick ran in run()
                self.mp.tick(now)
                self._sample_mem(now)
            start = now
            now += t_iter
            self._note_busy(start, now)
            if cp is not None:
                cp.on_online_iter(start, now)
            last_end = now
            executed += 1
            started = False
            if (self.offline_enabled and cp is not None
                    and cp.offline_may_start(now)):
                self.now = now            # dispatch stamps self.now
                started = self._off_start_dispatch()
            now += t_gap
            if started:
                break            # next scalar entry pays the preemption
        if executed:
            for st in active:
                st.tokens_done += executed
                st.t_last = last_end
            self.now = now
        return executed > 0

    def _burst_offline_decode(self) -> bool:
        """Run K offline decode dispatches back to back, deferring the
        per-target completion bookkeeping to one flush.

        Safe to defer because during a pure-decode stretch the deferred
        facts are write-only: token counts are integers (exact under one
        batched add), lease fills only move forward and publish nothing
        (every running request materialized its shared prefix at prefill
        completion), and MIAD's tick reads handle *allocation*, not fill.
        The admission probe is replayed exactly per dispatch — same rid
        counter, rng draws, and alloc calls as the scalar loop — so a
        success ends the burst and the scalar path prefills it.
        """
        if (not self.offline_enabled or self.off_inflight is not None
                or self.active or self.waiting or self.off_pending
                or not self.off_running or self._gated_since_wake):
            return False
        if (self.next_arrival < len(self.arriv)
                and self.arriv[self.next_arrival].t_arrive <= self.now):
            return False         # scalar entry admits the arrival first
        k_max = int(np.fromiter(
            (r.out_remaining for r in self.off_running),
            dtype=np.int64, count=len(self.off_running)).min()) - 1
        if k_max < 1:
            return False
        cfg = self.cfg
        t_iter, tick_every = cfg.t_decode_iter, cfg.miad_tick
        horizon = self.pair.online.horizon_s
        arrivals_done = self.next_arrival >= len(self.arriv)
        next_arr = (horizon if arrivals_done
                    else self.arriv[self.next_arrival].t_arrive)
        w = self.pair.offline
        cp = self.cp
        now = self.now
        executed = 0
        while executed < k_max:
            if executed and now - self._last_tick >= tick_every:
                self._last_tick = now     # iteration 0's tick ran in run()
                self.mp.tick(now)
                self._sample_mem(now)
            if arrivals_done and now >= horizon:
                break            # run() ends the sim at this entry
            if cp is not None and not cp.offline_may_start(now):
                break
            if now + t_iter >= next_arr:
                # arrival/horizon lands inside the dispatch — defer the
                # WHOLE iteration (probe included: its rid/rng/alloc
                # sequence belongs to the dispatch the scalar path starts)
                break
            if len(self.off_running) + len(self.off_pending) < w.max_batch:
                # _off_admit's probe, replayed exactly
                rid = f'off-{next(self._off_ids)}'
                prompt, out = self._off_sizes()
                pages = self._off_pages_needed(prompt, out)
                if self.mp.alloc_offline(rid, pages, now,
                                         self._off_prefix(prompt)):
                    r = OfflineReq(rid, prompt, out, pages)
                    self._off_resync(r)
                    self.off_pending.append(r)
                    break        # scalar path prefills the admission
            now += t_iter
            self.off_busy_until = now
            executed += 1
        if executed:
            for r in self.off_running:
                r.generated += executed
                r.out_remaining -= executed
                r.filled = r.prompt0 + r.generated
                self.mp.note_filled(r.rid, r.filled)
            self.result.offline_tokens += executed * len(self.off_running)
            self.now = now
        return executed > 0

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        horizon = self.pair.online.horizon_s
        guard = 0
        stall = 0
        last_now = -1.0
        while True:
            guard += 1
            assert guard < self.cfg.watchdog_guard_steps, \
                'sim did not terminate'
            # watchdog: if the clock stops advancing (degenerate zero-length
            # dispatch loops), force a step rather than livelock
            if self.now <= last_now + 1e-12:
                stall += 1
                if stall > self.cfg.watchdog_stall_steps:
                    self.now = last_now + self.cfg.watchdog_force_step_s
                    stall = 0
            else:
                stall = 0
                last_now = self.now
            if self.now - self._last_tick >= self.cfg.miad_tick:
                self._last_tick = self.now
                self.mp.tick(self.now)
                self._sample_mem(self.now)
            if self.cfg.vectorized and (self._burst_online_decode()
                                        or self._burst_offline_decode()):
                continue
            ran = self._online_dispatch()
            if ran:
                continue
            done = (self.next_arrival >= len(self.arriv)
                    and not self.waiting and not self.active)
            if done and self.now >= horizon:
                break
            # idle: complete offline dispatch, backfill, or jump time
            if self.off_inflight is not None:
                if self.off_busy_until <= self.now:
                    self._off_complete_dispatch()
                    continue
            next_arr = (self.arriv[self.next_arrival].t_arrive
                        if self.next_arrival < len(self.arriv) else horizon)
            if self.offline_enabled and self.off_inflight is None \
                    and (self.cp is None or self.cp.offline_may_start(self.now)):
                if self._off_start_dispatch():
                    # run until the dispatch ends or online work appears
                    t_next = min(self.off_busy_until, next_arr)
                    self.now = max(self.now, t_next)
                    continue
            if self.off_inflight is not None:
                # wait for the dispatch to end — or for the next arrival if
                # it comes first.  An arrival already in the past must not
                # clamp the jump to ``now`` (that stalls the clock below
                # off_busy_until forever when online is memory-blocked),
                # and a dispatch that ended in the past must not rewind the
                # clock (it completes on the next loop entry).
                t_next = self.off_busy_until
                if next_arr > self.now:
                    t_next = min(t_next, next_arr)
                self.now = max(self.now, t_next)
                continue
            # truly idle: jump to next arrival or wake-check boundary
            t_jump = next_arr
            if (self.cp is not None and self.offline_enabled
                    and not self.cp.offline_may_start(self.now)):
                t_jump = min(t_jump, self.now + 0.001)  # poll wake boundary
            if t_jump <= self.now:
                t_jump = self.now + 0.001
            self.now = min(t_jump, max(horizon, self.now + 0.001)) \
                if done else t_jump
            if done and self.now >= horizon:
                break

        self.result.horizon = max(self.now, horizon)
        self._sample_mem(self.result.horizon)
        if not self.result.mem_trace_t or self.result.mem_trace_t[0] > 0.0:
            # anchor the trace at t=0 (full memory before any admission)
            self.result.mem_trace_t.insert(0, 0.0)
            self.result.mem_trace_free.insert(0, float(self.mp.total))
        self.result.compute_stats = self.cp.stats if self.cp else None
        self.result.mem_stats = self.mp.stats
        if self.cp:
            self.result.max_preempt_per_request = max(
                self.cp.stats.per_request.values(), default=0)
        # the control-plane view: the same ordered facts the live runtime
        # publishes, folded by the same registry the orchestrator reads
        self.result.telemetry = self.telemetry
        if self.bus is not None:
            self.result.events = list(self.bus.log)
        return self.result


# ---------------------------------------------------------------------------
# Runners
# ---------------------------------------------------------------------------

def run_strategy(pair: WorkloadPair, compute_name: str, memory_name: str,
                 cfg: Optional[SimConfig] = None,
                 eviction_policy: str = 'valve') -> SimResult:
    from repro.core.sim import strategies as S
    cfg = cfg or SimConfig()
    cp = S.COMPUTE_POLICIES[compute_name]()
    if memory_name == 'OurMem':
        mp = OurMem(cfg.total_pages, cfg.page_tokens, policy=eviction_policy)
    else:
        mp = S.MEMORY_POLICIES[memory_name](cfg.total_pages, cfg.page_tokens)
    res = NodeSim(pair, cp, mp, cfg).run()
    res.name = f'{pair.name}:{compute_name}+{memory_name}'
    return res


def run_online_standalone(pair: WorkloadPair,
                          cfg: Optional[SimConfig] = None) -> SimResult:
    """Online alone: full memory, no offline — the TTFT/TPOT baseline."""
    cfg = cfg or SimConfig()
    mp = Prism(cfg.total_pages, cfg.page_tokens)
    res = NodeSim(pair, None, mp, cfg, offline_enabled=False).run()
    res.name = f'{pair.name}:standalone'
    return res


def run_offline_standalone(pair: WorkloadPair,
                           cfg: Optional[SimConfig] = None) -> SimResult:
    """Offline monopolizing the GPU — Thrput_(w,max) for normalization."""
    cfg = cfg or SimConfig()
    empty_online = WorkloadPair(
        pair.name,
        type(pair.online)(pair.online.name, [], pair.online.horizon_s),
        pair.offline)
    mp = Prism(cfg.total_pages, cfg.page_tokens)
    res = NodeSim(empty_online, None, mp, cfg).run()
    res.name = f'{pair.name}:offline-max'
    return res
