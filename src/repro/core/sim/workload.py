"""Workload models for the colocation simulator (paper §7.2 methodology:
"sample 10 online/offline workload pairs from production and replay").

Online traces are bursty in compute and/or KV memory (paper Fig. 2–3): a
Poisson background with periodic burst windows; prompt/output lengths
lognormal.  The 10 pairs sweep burstiness (compute-CV and memory-CV) so the
strategy comparison reproduces the paper's spread — including the 4
memory-bursty workloads where Prism/StaticMem degrade.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class OnlineRequest:
    req_id: str
    t_arrive: float
    prompt_tokens: int
    output_tokens: int


@dataclass
class OnlineWorkload:
    name: str
    requests: List[OnlineRequest]
    horizon_s: float


@dataclass(frozen=True)
class OfflineWorkload:
    """A continuous batch-inference job (throughput SLA, no latency SLA).

    ``prompt_choices``/``output_choices``: per-request size mixes — varied
    sizes fragment the handle space (the condition Algorithm 1 exploits).

    ``shared_prefix_tokens``: every request's prompt starts with the same
    ``shared_prefix_tokens``-token system prompt (the HyGen-style dominant
    harvest workload).  Lease-capable memory policies attach the published
    prefix pages copy-on-write instead of re-prefilling them; whole-request
    policies just see the prompt length.
    """
    name: str
    prompt_tokens: int = 512        # per request (mean when mixed)
    output_tokens: int = 256
    max_batch: int = 48             # requests in flight if memory allows
    prompt_choices: tuple = ()
    output_choices: tuple = ()
    shared_prefix_tokens: int = 0
    seed: int = 0


@dataclass(frozen=True)
class WorkloadPair:
    name: str
    online: OnlineWorkload
    offline: OfflineWorkload
    # burstiness knobs recorded for the report
    compute_cv: float = 0.0
    memory_bursty: bool = False


def make_online_trace(*, name: str, horizon_s: float = 600.0,
                      base_rate: float = 0.5, burst_rate: float = 6.0,
                      burst_every_s: float = 120.0, burst_len_s: float = 10.0,
                      prompt_mean: int = 512, prompt_sigma: float = 0.8,
                      out_mean: int = 96, seed: int = 0,
                      ramp_at_s: float = None,
                      ramp_mult: float = 1.0) -> OnlineWorkload:
    """Bursty Poisson trace.  ``ramp_at_s``/``ramp_mult`` make the trace
    non-stationary: all rates multiply by ``ramp_mult`` from ``ramp_at_s``
    on — the "deceptive node" the cluster monitoring loop exists for (looks
    harvestable when scouted, then its online service heats up)."""
    rng = np.random.default_rng(seed)
    reqs: List[OnlineRequest] = []
    t = 0.0
    i = 0
    while t < horizon_s:
        in_burst = (t % burst_every_s) < burst_len_s
        rate = burst_rate if in_burst else base_rate
        ramped = ramp_at_s is not None and t >= ramp_at_s
        if ramped:
            rate *= ramp_mult
        gap = float(rng.exponential(1.0 / max(rate, 1e-9)))
        if ramp_at_s is not None and not ramped and t + gap > ramp_at_s:
            # a quiet-period gap that crosses the ramp boundary must not
            # skip the ramp: by memorylessness, restart the draw at the
            # boundary with the ramped rate
            t = ramp_at_s
            continue
        t += gap
        if t >= horizon_s:
            break
        prompt = int(np.clip(rng.lognormal(math.log(prompt_mean),
                                           prompt_sigma), 16, 32768))
        out = max(1, int(rng.geometric(1.0 / out_mean)))
        reqs.append(OnlineRequest(f'{name}-r{i}', t, prompt, out))
        i += 1
    return OnlineWorkload(name, reqs, horizon_s)


def slice_trace(w: OnlineWorkload, t0: float, t1: float) -> OnlineWorkload:
    """Epoch window [t0, t1) of a trace, rebased to epoch-local time —
    the cluster harness replays one epoch slice per scheduling round."""
    reqs = [OnlineRequest(r.req_id, r.t_arrive - t0,
                          r.prompt_tokens, r.output_tokens)
            for r in w.requests if t0 <= r.t_arrive < t1]
    return OnlineWorkload(f'{w.name}@{t0:g}', reqs, t1 - t0)


# ---------------------------------------------------------------------------
# Fleet generator (cluster plane, paper §6): heterogeneous online services
# across nodes, with per-node GPU alignment structure
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class NodeWorkload:
    """One node's online side: a trace per GPU.

    ``aligned`` nodes run one service replicated across GPUs (arrivals
    jittered by ≲0.2 s → busy intervals overlap, P_multi high); unaligned
    nodes run independent services per GPU (P_multi low — the 0.95
    admission gate must reject multi-GPU offline jobs there).
    """
    name: str
    gpu_traces: Tuple[OnlineWorkload, ...]
    aligned: bool


def make_fleet_workloads(n_nodes: int = 8, gpus_per_node: int = 2, *,
                         horizon_s: float = 240.0, seed: int = 0,
                         n_ramp_nodes: int = 1, ramp_at_s: float = None,
                         ramp_mult: float = 60.0,
                         aligned_frac: float = 0.68) -> List[NodeWorkload]:
    """Heterogeneous trace mix for a simulated fleet.

    The first ``n_ramp_nodes`` nodes are quiet until ``ramp_at_s`` (default:
    a quarter of the horizon) and then heat up by ``ramp_mult`` — jobs the
    scheduler places there from scout-epoch telemetry will start violating
    their SLA, driving the eviction/reschedule path.

    Seeding is isolated per node (``SeedSequence.spawn``): node *i*'s trace
    depends only on ``(seed, i)``, so a 100-node fleet is byte-reproducible
    and growing ``n_nodes`` never re-rolls the existing nodes.
    """
    children = np.random.SeedSequence(seed).spawn(n_nodes)
    if ramp_at_s is None:
        ramp_at_s = horizon_s / 4.0
    nodes: List[NodeWorkload] = []
    for i in range(n_nodes):
        rng = np.random.default_rng(children[i])
        ramping = i < n_ramp_nodes
        aligned = ramping or bool(rng.random() < aligned_frac)
        base = 0.03 + 0.02 * float(rng.random())
        kw = dict(
            horizon_s=horizon_s,
            base_rate=(0.015 if ramping else base),
            burst_rate=(0.2 if ramping else 2.0 + 2.0 * float(rng.random())),
            burst_every_s=45.0 + 10.0 * (i % 4),
            burst_len_s=5.0 + 1.0 * (i % 3),
            prompt_mean=int(rng.choice([256, 512, 2048])),
            prompt_sigma=0.6,
            out_mean=int(rng.choice([32, 48, 64])),
            ramp_at_s=(ramp_at_s if ramping else None),
            ramp_mult=(ramp_mult if ramping else 1.0))
        traces = []
        if aligned:
            # one service, replicated: same request stream, small per-GPU
            # arrival jitter (scatter-gather fan-out skew)
            base_trace = make_online_trace(
                name=f'n{i}', seed=int(rng.integers(0, 2**31)), **kw)
            for g in range(gpus_per_node):
                # jitter ≪ request service time, so measured busy-interval
                # alignment stays above the 0.95 admission gate
                jit = rng.normal(0.0, 0.015, size=len(base_trace.requests))
                reqs = [OnlineRequest(f'{r.req_id}-g{g}',
                                      min(max(r.t_arrive + float(j), 0.0),
                                          horizon_s - 1e-6),
                                      r.prompt_tokens, r.output_tokens)
                        for r, j in zip(base_trace.requests, jit)]
                reqs.sort(key=lambda r: r.t_arrive)
                traces.append(OnlineWorkload(f'n{i}g{g}', reqs, horizon_s))
        else:
            for g in range(gpus_per_node):
                traces.append(make_online_trace(
                    name=f'n{i}g{g}', seed=int(rng.integers(0, 2**31)), **kw))
        nodes.append(NodeWorkload(f'node{i}', tuple(traces), aligned))
    return nodes


def make_workload_pairs(n: int = 10, *, horizon_s: float = 600.0,
                        seed: int = 0) -> List[WorkloadPair]:
    """10 production-shaped pairs sweeping compute/memory burstiness."""
    pairs: List[WorkloadPair] = []
    rng = np.random.default_rng(seed)
    for i in range(n):
        mem_bursty = i % 2 == 0            # half the pairs memory-bursty
        burst_rate = 3.0 + 0.8 * i          # increasing compute burstiness
        prompt_mean = 2048 if mem_bursty else 256
        prompt_sigma = 1.1 if mem_bursty else 0.5
        # background duty ≈ rate × lifetime ≈ 0.05..0.14 × ~2 s → 10–30%:
        # utilization switches between idle and fully-busy (paper Fig. 3),
        # which is the idle capacity colocation exists to harvest
        online = make_online_trace(
            name=f'online{i}', horizon_s=horizon_s,
            base_rate=0.05 + 0.01 * i,
            burst_rate=burst_rate,
            burst_every_s=60.0 + 10.0 * i,
            burst_len_s=6.0 + 1.5 * i,
            prompt_mean=prompt_mean, prompt_sigma=prompt_sigma,
            out_mean=40 + 12 * (i % 3),
            seed=int(rng.integers(0, 2**31)))
        offline = OfflineWorkload(
            name=f'offline{i}',
            prompt_tokens=int(rng.choice([256, 512, 1024])),
            output_tokens=int(rng.choice([128, 256, 512])),
            max_batch=48)
        cv = burst_rate / (0.3 + 0.05 * i)
        pairs.append(WorkloadPair(f'pair{i}', online, offline,
                                  compute_cv=cv, memory_bursty=mem_bursty))
    return pairs
