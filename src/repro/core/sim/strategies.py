"""Colocation strategy policies (paper §7.2 baselines).

Compute preemption — how long online waits when offline holds the GPU, and
when offline may run:

- ``KernelPreempt`` (TGS): switch at kernel boundaries; with CUDA graphs the
  boundary is a whole *iteration*, so online waits the full in-flight
  offline iteration.
- ``GPreempt``: driver timeslice — preemption is immediate (~10 µs) but
  offline wakes in every inter-iteration gap, so every online decode
  iteration pays a wake-collision switch.
- ``Channel`` (Valve §4): channel disable ≈ 0.5 ms + one bounded sub-layer
  chunk residual; wake only after ``T_cool = 2 × max decode gap`` — at most
  one preemption per online request.  Uses the real
  ``OnlineLifecycleTracker``.

Memory — where online KV comes from when it bursts:

- ``UVM``: offline fills all spare memory; online allocations page-fault it
  back at ~µs/page on the critical path, and the faulted offline requests
  die (restart from scratch).
- ``Prism``: VMM sharing without reclamation — online waits for offline
  requests to *finish* when memory is exhausted.
- ``StaticMem``: offline capped at the trailing-hour min free memory;
  online bursts above the cap kill offline requests outright.
- ``OurMem`` (Valve §5): the real ``KVPool`` + ``MIADReservation`` +
  ``ReclamationController`` (Algorithm 1 or FIFO) — sub-layer reclamation
  latency, rate driven to target by MIAD, victims chosen to minimize
  recompute tokens.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.lifecycle import OnlineLifecycleTracker
from repro.core.memory import MemoryPlane
from repro.core.miad import MIADConfig, MIADReservation
from repro.core.reclamation import ReclamationController
from repro.serving.kvpool import KVPool


# ---------------------------------------------------------------------------
# Compute policies
# ---------------------------------------------------------------------------

@dataclass
class ComputeStats:
    preemptions: int = 0
    preempt_delay_total: float = 0.0
    per_request: Dict[str, int] = field(default_factory=dict)


class ComputePolicy:
    name = 'base'

    def __init__(self):
        self.stats = ComputeStats()

    def preempt_delay(self, inflight_remaining: float) -> float:
        """Delay online pays to evict a running offline dispatch."""
        raise NotImplementedError

    def offline_may_start(self, now: float) -> bool:
        raise NotImplementedError

    # notifications from the simulator
    def on_online_request_start(self, rid: str, now: float): ...
    def on_online_request_end(self, rid: str, now: float): ...
    def on_online_iter(self, now_start: float, now_end: float): ...
    def note_preemption(self, rid_set, delay: float):
        self.stats.preemptions += 1
        self.stats.preempt_delay_total += delay
        for r in rid_set:
            self.stats.per_request[r] = self.stats.per_request.get(r, 0) + 1


class KernelPreempt(ComputePolicy):
    """Iteration-granularity switch (CUDA-graph boundary)."""
    name = 'KernelPreempt'

    def preempt_delay(self, inflight_remaining: float) -> float:
        return inflight_remaining          # drain the whole offline iteration

    def offline_may_start(self, now: float) -> bool:
        return True                        # backfills any idle instant


class GPreempt(ComputePolicy):
    """Driver-timeslice preemption: switching happens at timeslice
    boundaries, and offline wakes in every decode gap."""
    name = 'GPreempt'
    SWITCH = 30e-6                          # context-switch cost
    TIMESLICE = 1.0e-3                      # offline slice before yield

    def preempt_delay(self, inflight_remaining: float) -> float:
        return self.SWITCH + min(inflight_remaining, self.TIMESLICE)

    def offline_may_start(self, now: float) -> bool:
        return True


class Channel(ComputePolicy):
    """Valve §4: sub-ms channel preemption + T_cool-gated wake-ups."""
    name = 'Channel'
    DISABLE = 0.5e-3                        # channel-disable ioctl (patched)
    CHUNK_RESIDUAL = 0.5e-3                 # bounded in-flight sub-layer chunk

    def __init__(self, t_cool_init: float = 0.010):
        super().__init__()
        self.lifecycle = OnlineLifecycleTracker(t_cool_init=t_cool_init)

    def preempt_delay(self, inflight_remaining: float) -> float:
        return self.DISABLE + min(inflight_remaining, self.CHUNK_RESIDUAL)

    def offline_may_start(self, now: float) -> bool:
        return self.lifecycle.may_wake_offline(now)

    def on_online_request_start(self, rid, now):
        self.lifecycle.request_start(rid, now)

    def on_online_request_end(self, rid, now):
        self.lifecycle.request_end(rid, now)

    def on_online_iter(self, now_start, now_end):
        self.lifecycle.iteration_start(now_start)
        self.lifecycle.iteration_end(now_end)


# ---------------------------------------------------------------------------
# Memory policies
# ---------------------------------------------------------------------------

@dataclass
class MemStats:
    online_stall_total: float = 0.0
    stall_events: int = 0
    offline_tokens_lost: float = 0.0
    offline_kills: int = 0
    reclamations: int = 0


@dataclass
class AllocResult:
    ok: bool
    delay: float = 0.0
    # offline request ids whose KV was invalidated (token cost handled by
    # the offline engine's recompute queue)
    invalidated: Dict[str, List[int]] = field(default_factory=dict)
    killed: Set[str] = field(default_factory=set)
    # rid → surviving-prefix tokens (memory-plane partial invalidation:
    # the victim resumes prefill here instead of token 0; absent/0 for
    # whole-request policies)
    surviving: Dict[str, int] = field(default_factory=dict)
    # -- reclamation facts (the sim publishes these as typed events, so all
    # consumers observe the same stream the live runtime emits) --
    reclaimed: bool = False          # a reclamation/eviction pass ran
    gate_closed: bool = False        # offline compute was disabled first
    #                                  (§5 ordering — only OurMem holds it)
    reclaimed_handles: int = 0
    deficit_pages: int = 0           # shortfall that triggered the pass


class MemoryPolicy:
    """Page accounting over a shared pool of ``total_pages``."""
    name = 'base'
    # True when the policy runs the memory plane: leases with prefix
    # sharing, fill tracking and surviving-prefix (partial) invalidation
    supports_leases = False

    def __init__(self, total_pages: int, page_tokens: int = 16):
        self.total = total_pages
        self.page_tokens = page_tokens
        self.online_pages: Dict[str, int] = {}
        self.offline_pages: Dict[str, int] = {}
        self.stats = MemStats()

    # -- shared helpers -----------------------------------------------------
    @property
    def used(self) -> int:
        return sum(self.online_pages.values()) + sum(
            self.offline_pages.values())

    def free_pages(self) -> int:
        return self.total - self.used

    def offline_headroom(self, now: float) -> int:
        """Pages offline may occupy right now."""
        return self.free_pages()

    def alloc_online(self, rid: str, pages: int, now: float) -> AllocResult:
        raise NotImplementedError

    def free_online(self, rid: str) -> None:
        self.online_pages.pop(rid, None)

    def alloc_offline(self, rid: str, pages: int, now: float,
                      prefix=None) -> bool:
        """``prefix`` (token ids shared across a batch) is consumed only by
        lease-capable policies; whole-request policies ignore it."""
        if pages <= self.offline_headroom(now):
            self.offline_pages[rid] = self.offline_pages.get(rid, 0) + pages
            return True
        return False

    def free_offline(self, rid: str) -> None:
        self.offline_pages.pop(rid, None)

    # -- lease hooks (no-ops without a memory plane) ------------------------
    def note_filled(self, rid: str, tokens: int) -> None: ...

    def resume_tokens(self, rid: str) -> int:
        """Valid-KV prefix of ``rid`` (shared/surviving): prefill starts
        here.  0 for whole-request policies."""
        return 0

    def held_pages(self, rid: str) -> int:
        return self.offline_pages.get(rid, 0)

    def tick(self, now: float) -> None: ...

    def _take_offline_victims(self, deficit: int, now: float
                              ) -> Tuple[Dict[str, List[int]], int]:
        """Default FIFO-ish victim grab: evict whole offline requests until
        ``deficit`` pages free up.  Returns (invalidated map, freed)."""
        freed = 0
        inv: Dict[str, List[int]] = {}
        for rid in list(self.offline_pages.keys()):
            if freed >= deficit:
                break
            p = self.offline_pages.pop(rid)
            freed += p
            inv[rid] = list(range(p))   # page ids are symbolic in the sim
        return inv, freed


class UVM(MemoryPolicy):
    """Unified-memory: reclaim by page fault on the online critical path.

    A 16-token KV page of a 7B model is ~8 MB; UVM demand-migration moves
    it at ~15 GB/s effective → ~0.5 ms per page, paid inside the online
    allocation (the paper's "naively relying on UVM … severe interference").
    """
    name = 'UVM'
    FAULT_PER_PAGE = 500e-6

    def alloc_online(self, rid, pages, now):
        r = AllocResult(ok=True)
        deficit = pages - self.free_pages()
        if deficit > 0:
            inv, freed = self._take_offline_victims(deficit, now)
            # UVM can't coordinate with the framework: victims are killed,
            # and pages move while offline compute still runs (the §5
            # ordering violation the event stream makes visible)
            r.killed = set(inv.keys())
            r.invalidated = inv
            r.delay = pages * self.FAULT_PER_PAGE
            r.reclaimed, r.gate_closed = True, False
            r.deficit_pages = deficit
            self.stats.offline_kills += len(inv)
            self.stats.reclamations += 1
            if freed < deficit:
                r.ok = False
        if r.ok:
            self.online_pages[rid] = self.online_pages.get(rid, 0) + pages
            self.stats.online_stall_total += r.delay
            self.stats.stall_events += r.delay > 0
        return r


class Prism(MemoryPolicy):
    """VMM sharing, no reclamation: online waits for offline completions."""
    name = 'Prism'

    def alloc_online(self, rid, pages, now):
        if pages <= self.free_pages():
            self.online_pages[rid] = self.online_pages.get(rid, 0) + pages
            return AllocResult(ok=True)
        return AllocResult(ok=False)       # caller queues the request


class StaticMem(MemoryPolicy):
    """Offline statically capped at trailing-min free memory; online bursts
    above the cap kill offline instantly."""
    name = 'StaticMem'

    def __init__(self, total_pages: int, page_tokens: int = 16,
                 offline_cap_frac: float = 0.35):
        super().__init__(total_pages, page_tokens)
        self.offline_cap = int(total_pages * offline_cap_frac)

    def offline_headroom(self, now):
        used_off = sum(self.offline_pages.values())
        return min(self.offline_cap - used_off, self.free_pages())

    def alloc_online(self, rid, pages, now):
        r = AllocResult(ok=True)
        deficit = pages - self.free_pages()
        if deficit > 0:
            inv, freed = self._take_offline_victims(deficit, now)
            r.killed = set(inv.keys())
            r.invalidated = inv
            r.reclaimed, r.gate_closed = True, False
            r.deficit_pages = deficit
            self.stats.offline_kills += len(inv)
            if freed < deficit:
                r.ok = False
        if r.ok:
            self.online_pages[rid] = self.online_pages.get(rid, 0) + pages
        return r


class OurMem(MemoryPolicy):
    """Valve §5 on the real pool + memory plane: sub-layer reclamation,
    MIAD reservation, selective (Algorithm 1) or FIFO victim selection —
    with lease-based allocation, so offline victims keep their surviving
    prefix (partial invalidation) and shared-prefix batches attach
    already-materialized prompt pages.

    ``partial=False`` / ``sharing=False`` turn the plane features off
    (whole-request invalidation, no prefix index) — the benchmark baseline
    for the recompute-tax comparison.
    """
    name = 'OurMem'
    supports_leases = True
    RECLAIM_LATENCY = 1.0e-3       # disable-first + remap + callback

    def __init__(self, total_pages: int, page_tokens: int = 16,
                 pages_per_handle: int = 64, policy: str = 'valve',
                 miad: Optional[MIADConfig] = None, *,
                 partial: bool = True, sharing: bool = True):
        super().__init__(total_pages, page_tokens)
        n_handles = max(total_pages // pages_per_handle, 1)
        self.pool = KVPool(n_handles, pages_per_handle,
                           page_size=page_tokens, reserved_handles=1)
        self.plane = MemoryPlane(self.pool, sharing=sharing, partial=partial)
        self.miad = MIADReservation(h_init=1, cfg=miad or MIADConfig(
            t_init=0.5, target_rate=0.2, h_max=n_handles))
        self._gate_closed = False
        # partial=False is the pre-plane baseline end to end: whole-request
        # invalidation AND the old COST(r) = allocated tokens (the plane's
        # filled-aware marginal cost would already dodge unfilled victims,
        # which is part of what the comparison measures)
        legacy_cost = None if partial else (
            lambda r: len(self.pool.pages_of.get(r, ())) * page_tokens)
        self.reclaimer = ReclamationController(
            self.pool, gate_is_closed=lambda: self._gate_closed,
            policy=policy, cost_of=legacy_cost)

    def free_pages(self):                   # pool is the source of truth
        return (self.pool.free_pages_for('online')
                + self.pool.free_pages_for('offline'))

    def offline_headroom(self, now):
        return self.pool.free_pages_for('offline')

    def alloc_online(self, rid, pages, now):
        got = self.plane.admit(rid, pages, 'online')
        r = AllocResult(ok=got is not None)
        if got is None:
            deficit = pages - self.pool.free_pages_for('online')
            n_handles = -(-deficit // self.pool.pph)
            self._gate_closed = True        # compute-first ordering (§5)
            try:
                inv = self.reclaimer.reclaim(n_handles, now)
            finally:
                self._gate_closed = False
            self.miad.note_reclamation(now)
            r.invalidated = inv             # surfaced, NOT killed: recompute
            r.surviving = {k: v.resume for k, v in inv.items()}
            r.delay = self.RECLAIM_LATENCY
            r.reclaimed, r.gate_closed = True, True
            r.reclaimed_handles = n_handles
            r.deficit_pages = deficit
            self.stats.reclamations += 1
            self.stats.online_stall_total += r.delay
            self.stats.stall_events += 1
            got = self.plane.admit(rid, pages, 'online')
            r.ok = got is not None
        if r.ok:
            self.online_pages[rid] = self.online_pages.get(rid, 0) + pages
        return r

    def free_online(self, rid):
        super().free_online(rid)
        self.plane.release_id(rid)

    def alloc_offline(self, rid, pages, now, prefix=None):
        """Ensure ``rid`` holds ``pages`` pages: fresh admissions attach
        any published shared ``prefix``; a surviving lease (partial
        invalidation victim) is *extended*, keeping its prefix."""
        lease = self.plane.admit(rid, pages, 'offline',
                                 prompt=prefix, scope='sim')
        if lease is None:
            return False
        for p in lease:
            self.reclaimer.note_handle_use(self.pool.handle_of(p), now)
        self.offline_pages[rid] = len(lease)
        return True

    def free_offline(self, rid):
        super().free_offline(rid)
        self.plane.release_id(rid)

    def note_filled(self, rid, tokens):
        lease = self.plane.get(rid)
        if lease is not None:
            lease.note_filled(tokens)

    def resume_tokens(self, rid):
        lease = self.plane.get(rid)
        return lease.resume_tokens if lease is not None else 0

    def held_pages(self, rid):
        lease = self.plane.get(rid)
        return len(lease) if lease is not None else 0

    def tick(self, now):
        h = self.miad.on_tick(now, self.pool.online_used_handles())
        # grow/shrink the reserved set toward H using empty handles only —
        # growth beyond empties happens lazily at the next pressure event
        while len(self.pool.reserved) < h:
            empties = self.pool.empty_offline_handles()
            if not empties:
                break
            self.pool.reserve_handle(empties[0], now)
        while len(self.pool.reserved) > h:
            if self.pool.release_reserved_handle() is None:
                break


COMPUTE_POLICIES = {
    'KernelPreempt': KernelPreempt,
    'GPreempt': GPreempt,
    'Channel': Channel,
}

MEMORY_POLICIES = {
    'UVM': UVM,
    'Prism': Prism,
    'StaticMem': StaticMem,
    'OurMem': OurMem,
}

# the paper's Fig. 10 strategy grid
STRATEGIES = [
    ('KernelPreempt', 'UVM'),
    ('GPreempt', 'UVM'),
    ('Channel', 'UVM'),
    ('Channel', 'Prism'),
    ('Channel', 'StaticMem'),
    ('Channel', 'OurMem'),        # = Valve
]
