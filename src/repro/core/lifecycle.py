"""Online request lifecycle awareness (paper §4.2).

Tracks when the online workload is busy and decides when offline work may be
woken.  The paper's guarantee: **at most one preemption per online request**.
The mechanism: never wake offline inside the short idle gaps between decode
iterations — wake only after a continuous-idle *cooldown*
``T_cool = 2 × max decode gap`` (gap telemetry measured by the runtime).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional, Set


@dataclass
class LifecycleStats:
    requests_seen: int = 0
    preemptions: int = 0
    wakeups: int = 0
    # per-request preemption counts (property: each value ≤ 1)
    preempted_requests: Dict[str, int] = field(default_factory=dict)


class OnlineLifecycleTracker:
    """Tracks online request lifetimes + decode-gap telemetry.

    The engine calls :meth:`request_start` / :meth:`request_end` and
    :meth:`iteration_start` / :meth:`iteration_end`; the runtime polls
    :meth:`busy` and :meth:`may_wake_offline`.
    """

    def __init__(self, *, t_cool_init: float = 0.010, gap_window: int = 4096,
                 cool_factor: float = 2.0):
        self.active: Set[str] = set()
        self.cool_factor = cool_factor
        self._t_cool = t_cool_init
        self._gaps: Deque[float] = deque(maxlen=gap_window)
        self._last_iter_end: Optional[float] = None
        self._last_busy_t: float = -1e30
        self._in_iteration = False
        self.stats = LifecycleStats()

    # -- engine-side notifications ----------------------------------------
    def request_start(self, req_id: str, now: float) -> None:
        if not self.active:
            # idle → busy boundary: the span since the last iteration is
            # idle time, not an inter-iteration gap — reset the gap chain
            # or a post-idle arrival would record the whole idle period
            # and ratchet T_cool unboundedly
            self._last_iter_end = None
        self.active.add(req_id)
        self._last_busy_t = now
        self.stats.requests_seen += 1

    def request_end(self, req_id: str, now: float) -> None:
        self.active.discard(req_id)
        self._last_busy_t = now

    def iteration_start(self, now: float) -> None:
        # a decode gap is the pause *between iterations of live requests*;
        # idle time between requests is not a gap (it would inflate T_cool
        # unboundedly and starve offline)
        if self._last_iter_end is not None and self.active:
            self._gaps.append(max(now - self._last_iter_end, 0.0))
        self._in_iteration = True
        self._last_busy_t = now

    def iteration_end(self, now: float) -> None:
        self._in_iteration = False
        self._last_iter_end = now
        self._last_busy_t = now

    def note_preemption(self, now: float) -> None:
        """A preemption fired while these requests were in flight."""
        self.stats.preemptions += 1
        for r in self.active:
            self.stats.preempted_requests[r] = \
                self.stats.preempted_requests.get(r, 0) + 1

    # -- telemetry ---------------------------------------------------------
    @property
    def max_gap(self) -> float:
        return max(self._gaps) if self._gaps else 0.0

    @property
    def t_cool(self) -> float:
        """T_cool = cool_factor × max observed decode gap (paper §4.2)."""
        g = self.max_gap
        return max(self.cool_factor * g, self._t_cool) if g > 0 else self._t_cool

    # -- runtime-side queries ----------------------------------------------
    def busy(self, now: float) -> bool:
        return bool(self.active) or self._in_iteration

    def idle_for(self, now: float) -> float:
        return now - self._last_busy_t

    def may_wake_offline(self, now: float) -> bool:
        """Continuously idle for ≥ T_cool — waking here cannot collide with a
        decode-iteration gap, so a running online request is never preempted
        more than once."""
        return not self.busy(now) and self.idle_for(now) >= self.t_cool
