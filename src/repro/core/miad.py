"""MIAD (Multiplicative-Increase, Additive-Decrease) dynamic online memory
reservation (paper §5).

Valve keeps a dynamic online KV-cache headroom ``H`` of pre-mapped handles:

- on a *pressure event* (online usage ≥ 90 % of H) → ``H ← ceil(α·H)``;
- absent pressure, release one handle every interval ``T``.

``T`` itself is MIAD-controlled against a user target pressure-event *rate*:
if the event rate over a sliding window exceeds the target, ``T`` increases
multiplicatively (hold reservations longer → fewer reclamations); otherwise it
decreases additively (return memory to offline faster).  The controller drives
the reclamation rate toward the target while maximizing offline memory.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional


@dataclass
class MIADConfig:
    alpha: float = 1.5              # multiplicative increase of H
    pressure_util: float = 0.90     # pressure-event threshold on H utilization
    h_min: int = 1
    h_max: int = 1 << 30            # cap at the pool's handle count
    t_init: float = 1.0             # initial release interval (s)
    t_min: float = 0.125
    # t_max must exceed the burst spacing for low targets to be reachable —
    # safe now that only ACTUAL reclamations (not H-growth ticks) feed the
    # rate estimate, so T cannot ratchet on a single burst
    t_max: float = 64.0
    t_beta: float = 1.5             # multiplicative increase of T
    t_step: float = 0.25            # additive decrease of T (per second)
    target_rate: float = 0.1        # target RECLAMATION events / s
    # long window: the target bounds the LONG-RUN reclamation rate; a short
    # window lets a single burst pin T at t_max for the whole window
    rate_window: float = 120.0


@dataclass
class MIADStats:
    pressure_events: int = 0
    releases: int = 0
    h_trajectory: List = field(default_factory=list)


class MIADReservation:
    """Controls the online reserved-handle headroom H and interval T."""

    def __init__(self, h_init: int, cfg: Optional[MIADConfig] = None):
        self.cfg = cfg or MIADConfig()
        self.h = max(h_init, self.cfg.h_min)
        self.t = self.cfg.t_init
        self._events: Deque[float] = deque()
        self._t_observe_start: Optional[float] = None
        self._last_release = -1e30
        self._last_t_update = -1e30
        self.stats = MIADStats()

    # ------------------------------------------------------------------
    def _event_rate(self, now: float) -> float:
        """Events per second over the *elapsed* horizon.

        During warm-up (first ``rate_window`` seconds of observation) the
        denominator is the time actually observed, not the full window —
        dividing by the window would underestimate the rate exactly when a
        burst starts, and T would fail to increase multiplicatively until a
        whole window had passed.
        """
        w = self.cfg.rate_window
        while self._events and self._events[0] < now - w:
            self._events.popleft()
        if len(self._events) < 2:
            # a single event over a near-zero elapsed horizon is
            # rate-indeterminate, not a burst — fall back to the full
            # window rather than reading one reclamation as 1000/s
            return len(self._events) / w
        start = self._t_observe_start if self._t_observe_start is not None \
            else self._events[0]
        horizon = min(w, max(now - start, 1e-3))
        return len(self._events) / horizon

    def note_reclamation(self, now: float) -> None:
        """An actual reclamation fired — the interference event whose rate
        the T controller drives toward the user target."""
        if self._t_observe_start is None:
            self._t_observe_start = now
        self._events.append(now)

    def on_tick(self, now: float, online_used: int) -> int:
        """Advance the controller; returns the new reservation H.

        ``online_used``: handles currently consumed by online KV cache.
        """
        c = self.cfg
        if self._t_observe_start is None:
            self._t_observe_start = now
        pressured = online_used >= c.pressure_util * self.h
        if pressured:
            # multiplicative increase: pre-map more handles ahead of demand
            self.h = min(int(math.ceil(self.h * c.alpha)), c.h_max)
            self.stats.pressure_events += 1
            self._last_release = now          # restart the release timer
        elif now - self._last_release >= self.t:
            # additive decrease: return one handle to offline
            if self.h > max(c.h_min, online_used):
                self.h -= 1
                self.stats.releases += 1
            self._last_release = now

        # adapt T against the target reclamation rate (MIAD on T)
        if now - self._last_t_update >= 1.0:
            self._last_t_update = now
            if self._event_rate(now) > c.target_rate:
                self.t = min(self.t * c.t_beta, c.t_max)
            else:
                self.t = max(self.t - c.t_step, c.t_min)

        self.stats.h_trajectory.append((now, self.h))
        return self.h

    @property
    def reservation(self) -> int:
        return self.h
