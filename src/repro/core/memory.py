"""Memory-plane API v1 — lease-based KV allocation (paper §5, ConServe/HyGen).

The physical pool (:class:`~repro.serving.kvpool.KVPool`) deals in handles
and raw page ids; this module is the **logical** layer every consumer now
talks to.  A :class:`KVLease` is the opaque, refcounted handle a framework
holds for one request's KV:

    lease = plane.admit(rid, n_pages, klass='offline', prompt=tokens)
    lease.note_filled(n)        # KV materialized for tokens [0, n)
    lease.extend(k)             # grow (tail re-allocation after reclaim)
    child = lease.fork(rid2)    # CoW-share the filled prefix
    lease.release()             # drop refs; pages free at refcount zero

Three properties the raw pool could not express:

- **Refcounted prefix sharing** — page-aligned prompt prefixes are chained
  through a content-hash index (scoped per session, so different models
  never alias).  A later request with the same prompt prefix *attaches* the
  published pages instead of re-allocating and re-prefilling them; physical
  pages free only when their refcount reaches zero.  Writes are
  copy-on-write by construction: a lease's resume point is always at or
  beyond its shared prefix, so divergent tokens land in private pages and
  a fork never mutates its parent's pages.  Zero-ref published pages stay
  in a retention cache (evicted LRU under allocation pressure), so
  sequential same-prefix batches share too.
- **(layer, position)-addressed partial invalidation** — pages are tracked
  by logical position; the pool remaps reclaimed pages of *all* layers for
  a position range, so reclaiming a handle invalidates a lease only from
  the first remapped position on.  The invalidation callback now carries a
  :class:`LeaseInvalidation` per request — ``keep``/``resume``
  is the **surviving prefix** the scheduler resumes prefill from, instead
  of restarting at token 0.
- **Marginal recompute cost** — Algorithm 1's COST(r) becomes the tokens
  actually recomputed (``filled − surviving``), so victim selection
  prefers handles holding unfilled tails and zero-ref cached prefixes.

Ids allocated *around* the plane (direct ``pool.alloc``) keep the legacy
whole-request invalidation semantics — the plane passes them through with
``keep == 0`` and frees their survivors, exactly like the pre-lease
pool did.
"""
from __future__ import annotations

from collections import OrderedDict
from collections.abc import Sequence as _Sequence
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

from repro.serving.kvpool import KVPool

__all__ = ['KVLease', 'LeaseInvalidation', 'MemoryPlane', 'MemoryPlaneStats',
           'MigrationRefusal']


class MigrationRefusal:
    """Falsy, explicit result of a :meth:`MemoryPlane.migrate` that did
    NOT move the lease (the source is untouched).  Callers that only care
    about success keep truthiness (``if not moved: ...``); callers that
    need the cause — the disagg handoff scheduler deferring vs erroring,
    tests pinning the shared-page rule — read ``reason``:

    ``'unknown-lease'`` — no live lease under that id on this plane;
    ``'self-target'``   — destination is the source plane;
    ``'shared-pages'``  — ≥ 1 page is referenced by another lease or held
    under a foreign pool id (``pinned_pages`` lists them): moving it would
    tear KV out from under the co-referencing lease, so the caller must
    fall through to partial truncation;
    ``'no-capacity'``   — the destination pool could not fit the lease.
    """

    __slots__ = ('reason', 'pinned_pages')

    def __init__(self, reason: str, pinned_pages: Iterable[int] = ()):
        self.reason = reason
        self.pinned_pages = tuple(pinned_pages)

    def __bool__(self) -> bool:
        return False

    def __repr__(self) -> str:
        pins = f', pinned_pages={list(self.pinned_pages)}' \
            if self.pinned_pages else ''
        return f'MigrationRefusal({self.reason!r}{pins})'


class LeaseInvalidation(_Sequence):
    """One request's share of a reclamation: the physically remapped page
    ids plus the surviving prefix.  Sequence-compatible with the legacy
    ``List[int]`` payload (iterating/len yields the invalidated pages), so
    un-migrated callbacks keep working.

    ``keep``        — logical pages still valid from position 0 (the
    surviving prefix: the framework truncates its page list to this);
    ``resume``      — the resume token position: tokens of valid KV
    (≤ ``keep × page_size``, clamped to what was actually materialized) —
    (re)prefill starts here instead of token 0.
    ``lost_tokens`` — materialized tokens that must be recomputed
    (fill before the hit − ``resume``).
    ``released``    — True when nothing survived and the lease was dropped
    (the request re-admits from scratch, legacy semantics).
    ``migrated_to`` — destination pool name when the victim was *rescued*:
    its whole lease moved to a less-loaded pool before the handles were
    physically taken, so nothing was lost (``lost_tokens == 0``) and the
    request re-admits against the destination pool's plane with its full
    prefix intact.  None for ordinary (truncating) invalidations."""

    __slots__ = ('pages', 'keep', 'resume', 'lost_tokens', 'released',
                 'migrated_to')

    def __init__(self, pages: Iterable[int], keep: int = 0,
                 resume: int = 0, released: bool = True,
                 lost_tokens: float = 0.0,
                 migrated_to: Optional[str] = None):
        self.pages = tuple(pages)
        self.keep = int(keep)
        self.resume = int(resume)
        self.lost_tokens = float(lost_tokens)
        self.released = bool(released)
        self.migrated_to = migrated_to

    def __len__(self) -> int:
        return len(self.pages)

    def __getitem__(self, i):
        return self.pages[i]

    def __eq__(self, other):
        if isinstance(other, LeaseInvalidation):
            return (self.pages, self.keep, self.resume) == \
                (other.pages, other.keep, other.resume)
        if isinstance(other, (list, tuple)):
            return list(self.pages) == list(other)
        return NotImplemented

    __hash__ = None

    def __repr__(self) -> str:
        mig = f', migrated_to={self.migrated_to!r}' if self.migrated_to \
            else ''
        return (f'LeaseInvalidation(pages={list(self.pages)}, '
                f'keep={self.keep}, resume={self.resume}{mig})')


class KVLease(_Sequence):
    """Opaque refcounted handle owning one request's KV page lifetime.

    Sequence-compatible with the legacy ``List[int]`` page list (iterating
    yields physical page ids in logical order), so call sites that treated
    the allocation result as a page list keep working unchanged.
    """

    __slots__ = ('plane', 'lease_id', 'klass', 'scope', 'filled',
                 'released', '_pages', '_pending_publish', '_clean')

    def __init__(self, plane: 'MemoryPlane', lease_id: str, klass: str,
                 scope: str):
        self.plane = plane
        self.lease_id = lease_id
        self.klass = klass
        self.scope = scope
        self.filled = 0          # tokens of valid KV from position 0
        self.released = False
        self._pages: List[int] = []
        # logical page idx → prefix-index key, published once filled
        self._pending_publish: Dict[int, object] = {}
        # True while every page is provably private: sole reference, held
        # under this lease's own pool id, unpublished.  Any path that can
        # share a page (prefix attach, publication, fork) clears it; the
        # release fast path keys off it.
        self._clean = True

    # -- sequence protocol (legacy page-list compatibility) -----------------
    def __len__(self) -> int:
        return len(self._pages)

    def __getitem__(self, i):
        return self._pages[i]

    def __eq__(self, other):
        if isinstance(other, KVLease):
            return self is other
        if isinstance(other, (list, tuple)):
            return self._pages == list(other)
        return NotImplemented

    __hash__ = object.__hash__

    # -- views --------------------------------------------------------------
    @property
    def pages(self) -> List[int]:
        """Physical page ids in logical (position) order."""
        return list(self._pages)

    @property
    def n_pages(self) -> int:
        return len(self._pages)

    @property
    def resume_tokens(self) -> int:
        """Where (re)compute starts: everything before is valid KV — the
        shared prefix at admission, the surviving prefix after a partial
        invalidation."""
        return self.filled

    # -- lifecycle ----------------------------------------------------------
    def extend(self, n_pages: int) -> bool:
        """Grow the lease by ``n_pages`` (tail re-allocation after a
        partial invalidation, or output growth)."""
        return self.plane.extend(self, n_pages) is not None

    def fork(self, new_id: str, n_pages: Optional[int] = None
             ) -> Optional['KVLease']:
        """CoW fork: the child shares this lease's *filled* full pages
        (refcounted) and allocates private pages for the rest — divergent
        writes never touch the parent's pages."""
        return self.plane.fork(self, new_id, n_pages)

    def note_filled(self, tokens: int) -> None:
        """Record that KV is materialized for tokens [0, ``tokens``) —
        monotone; publishes any now-covered prompt-prefix pages."""
        self.plane.note_filled(self, tokens)

    def release(self) -> None:
        """Drop this lease's reference on every page; physical pages free
        when their refcount reaches exactly zero."""
        self.plane.release(self)

    def __repr__(self) -> str:
        return (f'KVLease({self.lease_id!r}, klass={self.klass!r}, '
                f'pages={len(self._pages)}, filled={self.filled})')


@dataclass
class MemoryPlaneStats:
    leases_opened: int = 0
    forks: int = 0
    extends: int = 0
    releases: int = 0
    admit_failures: int = 0
    # prefix sharing
    shared_pages_attached: int = 0     # page attachments that skipped alloc
    shared_tokens_saved: float = 0.0   # prefill tokens skipped via sharing
    pages_published: int = 0
    cache_evictions: int = 0
    # partial invalidation
    invalidations: int = 0             # leases hit by reclamations
    partial_invalidations: int = 0     # … of which kept a surviving prefix
    tokens_preserved: float = 0.0      # Σ resume tokens (recompute saved)
    pages_preserved: int = 0           # Σ surviving pages
    # cross-pool rescue
    leases_migrated: int = 0           # victims re-homed to another pool
    pages_migrated: int = 0            # Σ pages moved cross-pool
    migration_refusals: int = 0        # explicit migrate() refusals


class MemoryPlane:
    """The logical memory plane over one physical :class:`KVPool`.

    One plane per pool (``MemoryPlane.of`` attaches it); every consumer —
    runtime sessions, the reclamation controller, NodeSim's OurMem policy —
    shares it, so refcounts and the prefix index are pool-global.

    ``partial=False`` disables surviving prefixes (every invalidation
    reports ``keep == 0`` — the pre-lease whole-request semantics,
    the benchmark baseline); ``sharing=False`` disables the prefix index.
    """

    def __init__(self, pool: KVPool, *, sharing: bool = True,
                 partial: bool = True):
        assert getattr(pool, '_memory_plane', None) is None, \
            'pool already has a memory plane (use MemoryPlane.of)'
        pool._memory_plane = self
        self.pool = pool
        self.sharing = sharing
        self.partial = partial
        self.leases: Dict[str, KVLease] = {}
        self.stats = MemoryPlaneStats()
        # fired with the lease id whenever a lease fully dies (release or
        # zero-survivor invalidation) — the runtime drops its delivery
        # route here, so route lifetime == lease lifetime by construction.
        # Migration also fires it: the lease leaves THIS plane, so the
        # local route must die exactly like a release.
        self.on_release: Optional[Callable[[str], None]] = None
        # planes a reclamation victim may be rescued to (cross-pool
        # migration); empty list = rescue disabled (truncate as before)
        self.migration_targets: List['MemoryPlane'] = []
        # -- per-page tracking (plane-managed pages only) -------------------
        self._page_users: Dict[int, Set[str]] = {}   # lease ids holding a ref
        self._page_owner: Dict[int, str] = {}        # pool owner id
        self._page_index: Dict[int, int] = {}        # logical position
        self._page_key: Dict[int, object] = {}       # published prefix key
        self._page_chunk: Dict[int, tuple] = {}      # published page tokens
        self._prefix_index: Dict[object, int] = {}   # key → physical page
        self._cache: 'OrderedDict[int, None]' = OrderedDict()  # zero-ref LRU
        self._block_seq = 0
        # husks of cleanly-released leases, reused by the next admit (the
        # session-alloc fast path: admit/release cycles on the serving hot
        # path stop paying object construction).  Only leases released
        # through the notifying path are pooled — an invalidation-released
        # lease may still be referenced by its framework request record
        # (e.g. a queued victim awaiting re-admission), and recycling it
        # would alias two requests onto one handle.
        self._lease_pool: List[KVLease] = []

    @classmethod
    def of(cls, pool: KVPool) -> 'MemoryPlane':
        """The pool's plane, created on first use (pool-global singleton)."""
        plane = getattr(pool, '_memory_plane', None)
        return plane if plane is not None else cls(pool)

    # ------------------------------------------------------------------
    # Prefix index
    # ------------------------------------------------------------------
    @staticmethod
    def _chain_keys(scope: str, prompt: Sequence[int], n: int,
                    page_size: int) -> List[object]:
        """Content-hash chain over page-aligned prompt prefixes: key i
        commits to *all* tokens [0, (i+1)·page_size), so an index hit at
        page i implies the full preceding prefix matches.  Returns
        ``(key, chunk)`` pairs — attachment re-verifies the actual chunk
        tokens against the published page (``hash()`` is not collision
        resistant; aliasing KV between different prompts would corrupt
        decode output silently).  Chunk-equality at every level of a
        contiguous attach implies full-prefix equality."""
        keys: List[object] = []
        acc = hash(scope)
        for i in range(n):
            chunk = tuple(prompt[i * page_size:(i + 1) * page_size])
            acc = hash((acc, chunk))
            keys.append(((scope, i, acc), chunk))
        return keys

    def _shareable_pages(self, prompt: Optional[Sequence[int]],
                         n_pages: int) -> int:
        """Full prompt pages eligible for sharing.  Strictly less than the
        prompt (≥1 token always remains to prefill, so the resumer computes
        the logits the first generated token needs)."""
        if not self.sharing or prompt is None or len(prompt) == 0:
            return 0
        return min((len(prompt) - 1) // self.pool.page_size, n_pages)

    def _publish(self, lease: KVLease) -> None:
        """Enter filled, still-pending prompt pages into the prefix index."""
        pg = self.pool.page_size
        for idx in sorted(lease._pending_publish):
            if (idx + 1) * pg > lease.filled:
                break
            key, chunk = lease._pending_publish.pop(idx)
            # filled ≤ len(pages)·page_size always (note_filled clamps and
            # invalidation truncates both together), so idx is in range
            assert idx < len(lease._pages), (idx, len(lease._pages))
            page = lease._pages[idx]
            if key in self._prefix_index or page in self._page_key:
                continue                      # someone else published first
            self._prefix_index[key] = page
            self._page_key[page] = key
            self._page_chunk[page] = chunk
            self.stats.pages_published += 1

    # ------------------------------------------------------------------
    # Page bookkeeping
    # ------------------------------------------------------------------
    def _track(self, page: int, owner: str, idx: int, lease_id: str) -> None:
        self._page_owner[page] = owner
        self._page_index[page] = idx
        self._page_users[page] = {lease_id}

    def _attach(self, page: int, lease_id: str) -> None:
        self._page_users[page].add(lease_id)
        self._cache.pop(page, None)           # cached → live again

    def _deref(self, page: int, lease_id: str,
               drops: Optional[Dict[str, List[int]]] = None) -> None:
        """Drop one reference.  With ``drops``, zero-ref pages are
        collected per pool owner instead of freed immediately — bulk
        releases flush them in one ``free_pages`` call per owner, keeping
        request completion O(pages) instead of O(pages²)."""
        users = self._page_users[page]
        users.discard(lease_id)
        if users:
            return
        owner = self._page_owner[page]
        if page in self._page_key \
                and self.pool.klass_of.get(owner) == 'offline':
            # published OFFLINE prefix page: retain (LRU) for later
            # same-prefix admissions; reclaimed under allocation pressure.
            # Online pages never retain — zero-ref pages pinning reserved
            # handles would block the MIAD additive decrease and starve
            # offline of handles forever
            self._cache[page] = None
            self._cache.move_to_end(page)
        elif drops is not None:
            drops.setdefault(owner, []).append(page)
        else:
            self._drop_page(page)

    def _flush_drops(self, drops: Dict[str, List[int]]) -> None:
        for owner, pages in drops.items():
            self.pool.free_pages(owner, pages)
            for p in pages:
                self._forget(p)

    def _drop_page(self, page: int) -> None:
        """Physically free a plane page and forget everything about it."""
        self.pool.free_pages(self._page_owner[page], [page])
        self._forget(page)

    def _forget(self, page: int) -> None:
        """Forget a page whose pool mapping is already gone (reclaimed)."""
        self._page_owner.pop(page, None)
        self._page_index.pop(page, None)
        self._page_users.pop(page, None)
        self._cache.pop(page, None)
        self._page_chunk.pop(page, None)
        key = self._page_key.pop(page, None)
        if key is not None:
            self._prefix_index.pop(key, None)

    def drop_cache(self) -> int:
        """Free every zero-ref retained prefix page (benchmark resets,
        memory-accounting tests); returns the number of pages freed."""
        n = len(self._cache)
        for page in list(self._cache):
            self._drop_page(page)
            self.stats.cache_evictions += 1
        return n

    def _evict_cached(self, klass: str, need: int) -> None:
        """Free zero-ref cached prefix pages (LRU) from the region ``klass``
        allocates from until ``need`` pages are free there."""
        for page in list(self._cache):
            if self.pool.free_pages_for(klass) >= need:
                return
            in_reserved = self.pool.handle_of(page) in self.pool.reserved
            if in_reserved == (klass == 'online'):
                self._drop_page(page)
                self.stats.cache_evictions += 1

    def _pool_alloc(self, owner: str, n: int, klass: str, *,
                    grow: bool) -> Optional[List[int]]:
        alloc = self.pool.alloc_more if grow else self.pool.alloc
        got = alloc(owner, n) if grow else alloc(owner, n, klass)
        if got is None and self._cache:
            self._evict_cached(klass, n)
            got = alloc(owner, n) if grow else alloc(owner, n, klass)
        return got

    # ------------------------------------------------------------------
    # Lease lifecycle
    # ------------------------------------------------------------------
    def _new_lease(self, lease_id: str, klass: str, scope: str) -> KVLease:
        if self._lease_pool:
            lease = self._lease_pool.pop()
            lease.lease_id = lease_id
            lease.klass = klass
            lease.scope = scope
            lease.filled = 0
            lease.released = False
            lease._clean = True
            # _pages / _pending_publish were emptied at release
            return lease
        return KVLease(self, lease_id, klass, scope)

    def get(self, lease_id: str) -> Optional[KVLease]:
        return self.leases.get(lease_id)

    def live_leases(self, klass: Optional[str] = None) -> List[str]:
        return sorted(l.lease_id for l in self.leases.values()
                      if klass is None or l.klass == klass)

    def admit(self, lease_id: str, n_pages: int, klass: str = 'offline', *,
              prompt: Optional[Sequence[int]] = None,
              scope: Optional[str] = None) -> Optional[KVLease]:
        """Ensure ``lease_id`` holds ``n_pages`` pages and return its lease.

        Fresh ids open a new lease (attaching any published shared prefix
        of ``prompt``); a live id — a partially-invalidated request being
        re-admitted — is *extended* to the target instead, keeping its
        surviving prefix.  Returns None (state unchanged) on exhaustion.
        """
        assert klass in ('online', 'offline'), klass
        lease = self.leases.get(lease_id)
        if lease is not None:
            assert lease.klass == klass, (lease.klass, klass)
            need = n_pages - len(lease._pages)
            if need > 0 and self.extend(lease, need) is None:
                return None
            return lease

        if prompt is None or not self.sharing:
            # session-alloc fast path: no prefix index to consult, so the
            # whole admit is one pool alloc plus inline page tracking
            got = self.pool.alloc(lease_id, n_pages, klass) \
                if n_pages > 0 else []
            if got is None and self._cache:
                self._evict_cached(klass, n_pages)
                got = self.pool.alloc(lease_id, n_pages, klass)
            if got is None:
                self.stats.admit_failures += 1
                return None
            lease = self._new_lease(lease_id, klass, scope or klass)
            owners, index = self._page_owner, self._page_index
            users = self._page_users
            for i, page in enumerate(got):
                owners[page] = lease_id
                index[page] = i
                users[page] = {lease_id}
            lease._pages.extend(got)
            self.leases[lease_id] = lease
            self.stats.leases_opened += 1
            return lease

        scope = scope or klass
        lease = self._new_lease(lease_id, klass, scope)
        pg = self.pool.page_size
        # 1. attach the published shared prefix (contiguous from page 0);
        #    a hash hit alone is not trusted — the page's published tokens
        #    must equal this prompt's chunk (collision insurance)
        n_share = self._shareable_pages(prompt, n_pages)
        keys = self._chain_keys(scope, prompt, n_share, pg) if n_share else []
        for idx, (key, chunk) in enumerate(keys):
            page = self._prefix_index.get(key)
            if page is None or self._page_index.get(page) != idx \
                    or self._page_chunk.get(page) != chunk:
                break
            self._attach(page, lease_id)
            lease._pages.append(page)
        shared = len(lease._pages)
        if keys:
            # attached pages and/or pending publications → pages of this
            # lease may gain outside references; no release fast path
            lease._clean = False
        # 2. allocate the private tail under the lease's own id
        n_priv = n_pages - shared
        got = self._pool_alloc(lease_id, n_priv, klass, grow=False) \
            if n_priv > 0 else []
        if got is None:
            for idx in range(shared - 1, -1, -1):   # roll the attach back
                self._deref(lease._pages[idx], lease_id)
            self.stats.admit_failures += 1
            del lease._pages[:]                     # recycle the husk
            lease.released = True
            if len(self._lease_pool) < 64:
                self._lease_pool.append(lease)
            return None
        for i, page in enumerate(got):
            self._track(page, lease_id, shared + i, lease_id)
        lease._pages.extend(got)
        # 3. shared KV is valid: the resume point skips it entirely
        lease.filled = shared * pg
        # 4. remember the prompt-page keys this lease may publish once it
        #    fills them (the pages behind a miss, or re-filled after loss)
        for idx in range(shared, len(keys)):
            lease._pending_publish[idx] = keys[idx]
        self.leases[lease_id] = lease
        self.stats.leases_opened += 1
        if shared:
            self.stats.shared_pages_attached += shared
            self.stats.shared_tokens_saved += shared * pg
        return lease

    def extend(self, lease: KVLease, n_pages: int) -> Optional[List[int]]:
        assert not lease.released, f'lease {lease.lease_id} released'
        if n_pages <= 0:
            return []
        grow = lease.lease_id in self.pool.pages_of
        got = self._pool_alloc(lease.lease_id, n_pages, lease.klass,
                               grow=grow)
        if got is None:
            self.stats.admit_failures += 1
            return None
        base = len(lease._pages)
        for i, page in enumerate(got):
            self._track(page, lease.lease_id, base + i, lease.lease_id)
        lease._pages.extend(got)
        self.stats.extends += 1
        return got

    def fork(self, parent: KVLease, new_id: str,
             n_pages: Optional[int] = None) -> Optional[KVLease]:
        assert not parent.released
        assert new_id not in self.leases, f'lease id {new_id!r} live'
        pg = self.pool.page_size
        n_pages = n_pages if n_pages is not None else len(parent._pages)
        child = self._new_lease(new_id, parent.klass, parent.scope)
        n_share = min(parent.filled // pg, len(parent._pages), n_pages)
        if n_share:
            parent._clean = child._clean = False
        for idx in range(n_share):
            self._attach(parent._pages[idx], new_id)
            child._pages.append(parent._pages[idx])
        n_priv = n_pages - n_share
        got = self._pool_alloc(new_id, n_priv, child.klass, grow=False) \
            if n_priv > 0 else []
        if got is None:
            for idx in range(n_share - 1, -1, -1):
                self._deref(child._pages[idx], new_id)
            self.stats.admit_failures += 1
            return None
        for i, page in enumerate(got):
            self._track(page, new_id, n_share + i, new_id)
        child._pages.extend(got)
        child.filled = n_share * pg
        self.leases[new_id] = child
        self.stats.leases_opened += 1
        self.stats.forks += 1
        if n_share:
            self.stats.shared_pages_attached += n_share
            self.stats.shared_tokens_saved += n_share * pg
        return child

    def note_filled(self, lease: KVLease, tokens: int) -> None:
        if lease.released:
            return
        cap = len(lease._pages) * self.pool.page_size
        tokens = min(int(tokens), cap)
        if tokens <= lease.filled:
            return
        lease.filled = tokens
        if lease._pending_publish:
            self._publish(lease)

    def release(self, lease: KVLease, notify: bool = True) -> None:
        """``notify=False`` is the invalidation path: the reclamation
        callback must still find the dying lease's delivery route, so the
        caller (the runtime) drops routes *after* delivery instead."""
        if lease.released:
            return
        lease.released = True
        lid = lease.lease_id
        # Fast path — the hot serving shape: ``_clean`` proves every page
        # is private (sole reference, held under this lease's own pool id,
        # unpublished — sharing requires publication or a fork, both of
        # which clear the flag), so release is one bulk pool free plus
        # three dict deletes per page: no per-page retention checks, no
        # drop batching, no survivor transfer.
        if lease._clean:
            pages = lease._pages
            if pages:
                self.pool.free(lid)
                owners, index = self._page_owner, self._page_index
                users = self._page_users
                for p in pages:
                    del owners[p]
                    del index[p]
                    del users[p]
                del pages[:]
            self.leases.pop(lid, None)
            self.stats.releases += 1
            if notify:
                if len(self._lease_pool) < 64:
                    self._lease_pool.append(lease)
                if self.on_release is not None:
                    self.on_release(lid)
            return
        drops: Dict[str, List[int]] = {}
        for page in reversed(lease._pages):
            self._deref(page, lease.lease_id, drops)
        self._flush_drops(drops)
        lease._pages = []
        lease._pending_publish.clear()
        self.leases.pop(lease.lease_id, None)
        # pages that outlived us (shared with live leases, or retained in
        # the prefix cache) move to an internal block id so this request
        # id can be re-admitted without colliding in the pool
        left = self.pool.pages_of.get(lease.lease_id)
        if left:
            block = f'~blk{self._block_seq}'
            self._block_seq += 1
            self.pool.transfer_pages(lease.lease_id, list(left), block)
            for p in self.pool.pages_of[block]:
                self._page_owner[p] = block
        self.stats.releases += 1
        if notify:
            if len(self._lease_pool) < 64:
                self._lease_pool.append(lease)
            if self.on_release is not None:
                self.on_release(lease.lease_id)

    def release_id(self, lease_id: str) -> None:
        lease = self.leases.get(lease_id)
        if lease is not None:
            self.release(lease)
        elif lease_id in self.pool.pages_of:
            self.pool.free(lease_id)          # legacy id around the plane

    # ------------------------------------------------------------------
    # Cross-pool migration (reclamation-victim rescue)
    # ------------------------------------------------------------------
    def migrate(self, lease_id: str, dst: 'MemoryPlane'
                ) -> 'KVLease | MigrationRefusal':
        """Re-home a live lease to ``dst``'s pool with all KV bookkeeping
        intact (same filled/resume point — zero recompute for the owner).

        Only *privately held* leases move: every page must be solely
        referenced by this lease and held under its own pool id (shared
        prefix pages are pinned by other leases' references).  Published
        pages a lease still solely holds DO move — their prefix-index
        entries are withdrawn, so no later admission can attach a page
        that left the pool.  Returns the (same) lease object, now owned
        by ``dst``, or a falsy :class:`MigrationRefusal` naming why the
        lease did not move (source untouched on refusal)."""
        lease = self.leases.get(lease_id)
        if lease is None or lease.released:
            return self._refuse('unknown-lease')
        if dst is self:
            return self._refuse('self-target')
        lid = lease.lease_id
        assert lid not in dst.leases, f'lease id {lid!r} live in target'
        pages = list(lease._pages)
        pinned = [p for p in pages
                  if self._page_users.get(p) != {lid}
                  or self._page_owner.get(p) != lid]
        if pinned:
            return self._refuse('shared-pages', pinned)
        got = self.pool.transfer_pages(lid, pages, lid, dst_pool=dst.pool)
        if got is None:
            return self._refuse('no-capacity')
        for p in pages:
            self._forget(p)
        del self.leases[lid]
        # page ids are pool-local: the lease's logical order is preserved,
        # the physical ids are the destination allocation
        lease._pages = list(got)
        lease._pending_publish.clear()
        lease._clean = True
        lease.plane = dst
        dst.leases[lid] = lease
        for i, p in enumerate(got):
            dst._track(p, lid, i, lid)
        self.stats.leases_migrated += 1
        self.stats.pages_migrated += len(got)
        if self.on_release is not None:
            self.on_release(lid)          # the local route dies with us
        return lease

    def _refuse(self, reason: str,
                pinned: Iterable[int] = ()) -> MigrationRefusal:
        self.stats.migration_refusals += 1
        return MigrationRefusal(reason, pinned)

    def _pick_migration_target(self, lease: KVLease
                               ) -> Optional['MemoryPlane']:
        """Least-loaded target with room for the whole lease, or None."""
        best, best_free = None, -1
        need = len(lease._pages)
        for dst in self.migration_targets:
            if dst is self:
                continue
            free = dst.pool.free_pages_for(lease.klass)
            if free >= need and free > best_free:
                best, best_free = dst, free
        return best

    def _rescue_victims(self, handles: Sequence[int]
                        ) -> Dict[str, LeaseInvalidation]:
        """Migrate would-be reclamation victims out of ``handles`` before
        the pages are physically taken.  A rescued lease frees its source
        pages (the reclaimer still gets its handles) but keeps every token
        of KV in the destination pool — the invalidation entry records the
        hit pages with ``lost_tokens == 0`` and ``migrated_to`` set."""
        hit: Dict[str, List[int]] = {}
        for h in handles:
            for p in self.pool._handle_pages(h):
                if self.pool.owner[p] is None:
                    continue
                users = self._page_users.get(p)
                if users:
                    for lid in users:
                        hit.setdefault(lid, []).append(p)
        out: Dict[str, LeaseInvalidation] = {}
        for lid, hit_pages in hit.items():
            lease = self.leases[lid]
            dst = self._pick_migration_target(lease)
            # a refusal (shared pages, destination filled up mid-batch) is
            # explicit but non-fatal here: the victim falls through to the
            # ordinary partial-truncation path below
            if dst is None or not self.migrate(lid, dst):
                continue
            out[lid] = LeaseInvalidation(
                hit_pages, keep=len(lease._pages), resume=lease.filled,
                released=False, lost_tokens=0.0,
                migrated_to=dst.pool.name)
        return out

    # ------------------------------------------------------------------
    # Reclamation (partial invalidation)
    # ------------------------------------------------------------------
    def reclaim_handles(self, handles: Sequence[int], now: float = 0.0
                        ) -> Dict[str, LeaseInvalidation]:
        """Physically reclaim ``handles`` and translate the raw page map
        into per-lease invalidations with surviving prefixes.  The caller
        (ReclamationController) must hold the compute gate closed.

        With ``migration_targets`` set, victims are first offered a
        cross-pool rescue (:meth:`_rescue_victims`); the remaining hits
        take the ordinary truncation path."""
        migrated: Dict[str, LeaseInvalidation] = {}
        if self.migration_targets:
            migrated = self._rescue_victims(handles)
        raw = self.pool.reclaim_handles(handles, now, free_survivors=False)
        out = self.apply_pool_invalidation(raw)
        # a rescued lease left this pool whole, so the truncation pass
        # cannot also have hit it — if it ever did, merging would let one
        # victim's lost_tokens be charged under both labels
        assert not set(out) & set(migrated), \
            (sorted(set(out) & set(migrated)), 'victim both rescued and '
             'truncated in one reclamation')
        out.update(migrated)
        return out

    def apply_pool_invalidation(self, raw: Dict[str, List[int]]
                                ) -> Dict[str, LeaseInvalidation]:
        pg = self.pool.page_size
        hit: Dict[str, List[int]] = {}        # lease id → remapped pages
        legacy: Dict[str, List[int]] = {}     # ids allocated around us
        for owner, pages in raw.items():
            for p in pages:
                users = self._page_users.get(p)
                if users is None:
                    legacy.setdefault(owner, []).append(p)
                else:
                    for lid in users:
                        hit.setdefault(lid, []).append(p)
                # the pool already dropped the mapping — forget the page
                # (removes cached/published entries for reclaimed pages)
                self._forget(p)

        out: Dict[str, LeaseInvalidation] = {}
        for lid, pages in hit.items():
            lease = self.leases[lid]
            cut = min(self._lease_pos(lease, p) for p in pages)
            keep = cut if self.partial else 0
            keep_tokens = min(keep * pg, lease.filled)
            lost_tokens = lease.filled - keep_tokens
            # drop everything from the first remapped position on: the
            # remapped pages themselves plus the now-unreachable tail
            # (deref — shared tails may survive under other leases)
            gone = set(pages)
            drops: Dict[str, List[int]] = {}
            for page in reversed(lease._pages[keep:]):
                if page not in gone:
                    self._deref(page, lid, drops)
            self._flush_drops(drops)
            del lease._pages[keep:]
            lease.filled = keep_tokens
            self.stats.invalidations += 1
            if keep > 0:
                self.stats.partial_invalidations += 1
                self.stats.tokens_preserved += keep_tokens
                self.stats.pages_preserved += keep
                released = False
            else:
                self.release(lease, notify=False)
                released = True
            out[lid] = LeaseInvalidation(pages, keep, resume=keep_tokens,
                                         released=released,
                                         lost_tokens=lost_tokens)
        for owner, pages in legacy.items():
            # legacy whole-request semantics: survivors die too, and the
            # loss is counted as the remapped pages' tokens (pre-plane rule)
            self.pool.free(owner)
            self.stats.invalidations += 1
            out[owner] = LeaseInvalidation(
                pages, 0, 0, released=True,
                lost_tokens=len(pages) * pg)
        return out

    def _lease_pos(self, lease: KVLease, page: int) -> int:
        # shared pages sit at the same logical position for every user, so
        # the page's recorded index is the lease's position — but a page
        # reclaimed and forgotten loses its index; fall back to a scan
        idx = self._page_index.get(page)
        if idx is not None:
            return idx
        return lease._pages.index(page)

    # ------------------------------------------------------------------
    # Eviction support (Algorithm 1's marginal recompute cost)
    # ------------------------------------------------------------------
    def impact_of(self, handle: int) -> Dict[str, int]:
        """{request id: min logical page index lost} if ``handle`` were
        reclaimed.  Zero-ref cached prefix pages impact nobody (free to
        take); legacy ids lose everything (index 0)."""
        out: Dict[str, int] = {}
        for p in self.pool._handle_pages(handle):
            owner = self.pool.owner[p]
            if owner is None:
                continue
            users = self._page_users.get(p)
            if users:
                idx = self._page_index[p]
                for lid in users:
                    if idx < out.get(lid, 1 << 30):
                        out[lid] = idx
            elif p not in self._page_owner:
                out[owner] = 0                # legacy: full restart
        return out

    def recompute_cost(self, rid: str, min_idx: int) -> float:
        """Marginal recompute tokens if ``rid`` loses pages from logical
        index ``min_idx`` on (COST(r) for Algorithm 1)."""
        lease = self.leases.get(rid)
        if lease is None:                     # legacy id: full restart cost
            return len(self.pool.pages_of.get(rid, ())) * self.pool.page_size
        keep = min_idx if self.partial else 0
        return max(0.0, lease.filled - keep * self.pool.page_size)

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        self.pool.check_invariants()
        seen_refs: Dict[int, int] = {}
        for lid, lease in self.leases.items():
            assert not lease.released
            assert lease.filled <= len(lease._pages) * self.pool.page_size
            for idx, p in enumerate(lease._pages):
                assert self._page_index[p] == idx, (lid, p, idx)
                assert lid in self._page_users[p], (lid, p)
                seen_refs[p] = seen_refs.get(p, 0) + 1
        for p, users in self._page_users.items():
            assert len(users) == seen_refs.get(p, 0), \
                (p, users, seen_refs.get(p))
            assert self.pool.owner[p] == self._page_owner[p], \
                (p, self.pool.owner[p], self._page_owner[p])
            if not users:
                assert p in self._cache, f'zero-ref page {p} not cached'
        for p in self._cache:
            assert not self._page_users[p], f'cached page {p} has users'
            assert p in self._page_key, f'cached page {p} never published'
        for key, p in self._prefix_index.items():
            assert self._page_key.get(p) == key, (key, p)
            assert p in self._page_chunk, f'published page {p} lacks tokens'
