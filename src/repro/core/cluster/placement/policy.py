"""Placement-policy strategy interface over the cluster scheduler.

A policy decides *where a batch of jobs goes*; the scheduler owns the
bookkeeping (busy GPUs, pending queue, eviction counters).  Both registered
policies score candidates through the same :func:`score_candidate` — the
Eq. 1 model plus the heterogeneity scalar and the topology lockstep factor
— so swapping policies never changes which telemetry fields are consumed
(asserted in tests/test_placement.py with an access-recording telemetry
proxy).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple, Type

from repro.core.cluster.perfmodel import (
    NodeTelemetry, admissible, predict_normalized_throughput)
from repro.core.cluster.scheduler import OfflineJob, Placement


def score_candidate(job: OfflineJob, node: NodeTelemetry,
                    gpu_indices: Tuple[int, ...], *, sla_slack: float = 0.0,
                    topology=None) -> Optional[float]:
    """Admissibility-gated Eq. 1 score of one (job, node, GPU-set)
    candidate; ``None`` = inadmissible or below the job's SLA.  The single
    scoring path every placement policy goes through."""
    gset = [node.gpus[i] for i in gpu_indices]
    if not admissible(job.profile, gset):
        return None
    pred = predict_normalized_throughput(job.profile, gset)
    if len(gset) > 1 and topology is not None:
        pred *= topology.intra_efficiency(node.name)
    if pred < job.sla + sla_slack:
        return None
    return pred


class PlacementPolicy:
    """Strategy interface: place a batch of jobs on a scheduler's fleet.

    Implementations must leave the scheduler consistent: commit successful
    placements (``sched._commit``) and queue failures (``sched.pending``).
    ``avoid`` maps job_id → node names that job must skip this round (the
    evicted-job one-shot avoid-list).
    """
    name = 'base'

    def place_batch(self, sched, jobs: Sequence[OfflineJob],
                    avoid: Optional[Dict[str, Set[str]]] = None
                    ) -> List[Placement]:
        raise NotImplementedError


PLACEMENT_POLICIES: Dict[str, Type[PlacementPolicy]] = {}


def register_policy(cls: Type[PlacementPolicy]) -> Type[PlacementPolicy]:
    PLACEMENT_POLICIES[cls.name] = cls
    return cls


def resolve_policy(policy) -> PlacementPolicy:
    """Accept a registered name, a policy class, or an instance."""
    if isinstance(policy, PlacementPolicy):
        return policy
    if isinstance(policy, type) and issubclass(policy, PlacementPolicy):
        return policy()
    return PLACEMENT_POLICIES[policy]()


@register_policy
class GreedyEq1Policy(PlacementPolicy):
    """The original per-job greedy path: each job independently takes the
    best-scoring admissible GPU set at submission time (first-come
    first-served over the shared free-GPU pool)."""
    name = 'greedy-eq1'

    def place_batch(self, sched, jobs, avoid=None):
        placed = []
        for job in jobs:
            bad = (avoid or {}).get(job.job_id)
            p = sched.place(job, avoid=bad)
            if p is not None:
                placed.append(p)
        return placed
