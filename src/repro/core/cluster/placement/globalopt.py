"""Global placement optimizer: joint assignment over (job × node × GPU-set).

The Helix layout-synthesis recipe (SNIPPETS.md) applied to harvested
capacity: admissibility pruning cuts the candidate space (top-k per job by
Eq. 1 score), a greedy warm start seeds the solution, a min-cost assignment
solve (``scipy.optimize.linear_sum_assignment``, gated — skipped if scipy
is absent) rearranges the single-GPU jobs optimally against the slots the
multi-GPU warm start left free, and deterministic local search
(upgrade / eject-relocate / displace) improves across GPU-set sizes.
Every move strictly increases the objective Σ score·n_gpus — exactly the
numerator of ``ClusterScheduler.utilization_gain`` — so the final solution
is ≥ the warm start by construction, and the greedy baseline can only be
matched or beaten on the predicted objective.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

try:
    from scipy.optimize import linear_sum_assignment
except ImportError:                                    # pragma: no cover
    linear_sum_assignment = None

from repro.core.cluster.scheduler import Placement
from repro.core.cluster.placement.policy import (
    PlacementPolicy, register_policy)

Cand = Tuple[float, str, Tuple[int, ...]]              # (score, node, gpus)


@dataclass
class GlobalOptConfig:
    """Pruning / effort knobs (the Helix ``ilp_args`` analog)."""
    max_candidates_per_job: int = 24   # top-k candidates kept per job
    score_floor: float = 0.0           # drop candidates scoring below this
    max_rounds: int = 8                # local-search improvement rounds
    use_assignment: bool = True        # scipy LSA core for single-GPU jobs


@dataclass
class SolveReport:
    jobs: int
    candidates: int                    # admissible candidates generated
    pruned: int                        # dropped by the top-k cut
    warm_start_value: float            # Σ score·n_gpus after greedy seed
    value: float                       # final objective (≥ warm start)
    placed: int
    rounds: int                        # local-search rounds used
    wall_time_s: float
    method: str


@register_policy
class GlobalPlacementPolicy(PlacementPolicy):
    name = 'global-opt'

    def __init__(self, cfg: Optional[GlobalOptConfig] = None):
        self.cfg = cfg or GlobalOptConfig()
        self.reports: List[SolveReport] = []

    @property
    def last_report(self) -> Optional[SolveReport]:
        return self.reports[-1] if self.reports else None

    # ------------------------------------------------------------------
    def place_batch(self, sched, jobs, avoid=None):
        t_start = time.perf_counter()
        cfg = self.cfg
        job_by_id = {j.job_id: j for j in jobs}

        # 1. pruned candidate generation (same scoring path as greedy)
        per_job: Dict[str, List[Cand]] = {}
        n_cands = n_pruned = 0
        for job in jobs:
            bad = (avoid or {}).get(job.job_id) or set()
            cl: List[Cand] = []
            for node in sched.nodes.values():
                if node.name in bad:
                    continue
                for gpus in sched._candidate_sets(node, job.profile.n_gpus):
                    s = sched._score(job, node, gpus)
                    if s is None or s < cfg.score_floor:
                        continue
                    cl.append((s, node.name, gpus))
            cl.sort(key=lambda c: (-c[0], c[1], c[2]))
            n_cands += len(cl)
            n_pruned += max(0, len(cl) - cfg.max_candidates_per_job)
            per_job[job.job_id] = cl[:cfg.max_candidates_per_job]

        assign: Dict[str, Cand] = {}
        taken: Dict[Tuple[str, int], str] = {}

        def wt(jid: str, cand: Cand) -> float:
            return cand[0] * job_by_id[jid].profile.n_gpus

        def conflicts(cand: Cand, jid: str) -> Set[str]:
            return {taken[(cand[1], g)] for g in cand[2]
                    if (cand[1], g) in taken and taken[(cand[1], g)] != jid}

        def unassign(jid: str) -> None:
            old = assign.pop(jid, None)
            if old is not None:
                for g in old[2]:
                    taken.pop((old[1], g), None)

        def do_assign(jid: str, cand: Cand) -> None:
            unassign(jid)
            assign[jid] = cand
            for g in cand[2]:
                taken[(cand[1], g)] = jid

        def value() -> float:
            return sum(wt(j, c) for j, c in assign.items())

        # 2. greedy warm start: heaviest (job, candidate) first
        flat = [(wt(jid, c), jid, c)
                for jid, cl in per_job.items() for c in cl]
        flat.sort(key=lambda x: (-x[0], x[1], x[2][1], x[2][2]))
        for _, jid, cand in flat:
            if jid not in assign and not conflicts(cand, jid):
                do_assign(jid, cand)
        # second seed: the EXACT greedy-eq1 baseline decision, obtained by
        # running the scheduler's own place path and rolling it back
        # (pruning to top-k can starve late jobs that full-scan greedy
        # would still place).  Keeping the better of the two seeds — and
        # only ever improving from there — guarantees the optimizer never
        # scores below the greedy baseline on identical telemetry.
        pend0 = list(sched.pending)
        greedy_placed = []
        for job in jobs:
            p = sched.place(job, avoid=(avoid or {}).get(job.job_id))
            if p is not None:
                greedy_placed.append(p)
        for p in greedy_placed:
            sched._release(p.job.job_id)
        sched.pending[:] = pend0
        greedy_value = sum(p.predicted * p.job.profile.n_gpus
                           for p in greedy_placed)
        if greedy_value > value():
            for jid in list(assign):
                unassign(jid)
            for p in greedy_placed:
                cand = (p.predicted, p.node, p.gpu_indices)
                if cand not in per_job[p.job.job_id]:
                    # below the top-k cut: append so local search can
                    # still move off it (scores ≤ every kept candidate,
                    # so the sorted-prefix early-exit stays valid)
                    per_job[p.job.job_id].append(cand)
                do_assign(p.job.job_id, cand)
        warm_value = value()

        # 3. assignment core: re-solve the single-GPU jobs optimally over
        # the slots the multi-GPU assignments left free
        method = 'warm'
        if cfg.use_assignment and linear_sum_assignment is not None:
            method += '+lsa'
            self._refine_singles(per_job, job_by_id, assign, taken,
                                 conflicts, unassign, do_assign)

        # 4. deterministic local search across GPU-set sizes
        rounds = self._local_search(per_job, job_by_id, assign,
                                    conflicts, unassign, do_assign, wt)
        method += '+ls'

        # 5. commit (scheduler bookkeeping identical to the greedy path)
        placed: List[Placement] = []
        for job in jobs:
            cand = assign.get(job.job_id)
            if cand is None:
                if all(j.job_id != job.job_id for j in sched.pending):
                    sched.pending.append(job)
                continue
            p = Placement(job, cand[1], cand[2], cand[0])
            sched._commit(p)
            placed.append(p)

        self.reports.append(SolveReport(
            jobs=len(jobs), candidates=n_cands, pruned=n_pruned,
            warm_start_value=warm_value, value=value(), placed=len(placed),
            rounds=rounds, wall_time_s=time.perf_counter() - t_start,
            method=method))
        return placed

    # ------------------------------------------------------------------
    def _refine_singles(self, per_job, job_by_id, assign, taken,
                        conflicts, unassign, do_assign) -> None:
        """Hungarian solve of single-GPU jobs × free single-GPU slots;
        adopted only if it beats the warm start's single-GPU portion."""
        singles = sorted(jid for jid, cl in per_job.items()
                         if cl and job_by_id[jid].profile.n_gpus == 1)
        multi_taken = {k for k, jid in taken.items()
                       if job_by_id[jid].profile.n_gpus > 1}
        slots = sorted({(c[1], c[2][0]) for jid in singles
                        for c in per_job[jid]} - multi_taken)
        if not singles or not slots:
            return
        mat = np.full((len(singles), len(slots)), -1.0)
        slot_idx = {s: k for k, s in enumerate(slots)}
        by_slot: Dict[Tuple[str, Tuple[str, int]], Cand] = {}
        for r, jid in enumerate(singles):
            for c in per_job[jid]:
                k = slot_idx.get((c[1], c[2][0]))
                if k is not None:
                    mat[r, k] = c[0]
                    by_slot[(jid, (c[1], c[2][0]))] = c
        rows, cols = linear_sum_assignment(mat, maximize=True)
        new: Dict[str, Cand] = {}
        for r, k in zip(rows, cols):
            if mat[r, k] > 0:          # admissible scores are > 0 (SLA > 0)
                new[singles[r]] = by_slot[(singles[r], slots[k])]
        lsa_value = sum(c[0] for c in new.values())
        old_value = sum(assign[j][0] for j in singles if j in assign)
        if lsa_value > old_value + 1e-12:
            for jid in singles:
                unassign(jid)
            for jid, cand in new.items():
                do_assign(jid, cand)

    # ------------------------------------------------------------------
    def _local_search(self, per_job, job_by_id, assign,
                      conflicts, unassign, do_assign, wt) -> int:
        """First-improvement moves, deterministic order, objective strictly
        increasing: upgrade (better free candidate), eject-relocate (bump a
        blocker to its best alternative), displace (replace a lighter
        blocker outright)."""
        rounds = 0
        improved = True
        while improved and rounds < self.cfg.max_rounds:
            improved = False
            rounds += 1
            # upgrade: move any job to a strictly better conflict-free slot
            for jid in sorted(per_job):
                cur = assign.get(jid)
                cur_w = wt(jid, cur) if cur is not None else 0.0
                for cand in per_job[jid]:
                    w = wt(jid, cand)
                    if w <= cur_w + 1e-12:
                        break                  # sorted: no better left
                    if not conflicts(cand, jid):
                        do_assign(jid, cand)
                        improved = True
                        break
            # eject-relocate / displace for still-unplaced jobs
            for jid in sorted(per_job):
                if jid in assign:
                    continue
                for cand in per_job[jid]:
                    blockers = conflicts(cand, jid)
                    if len(blockers) != 1:
                        continue
                    b = next(iter(blockers))
                    gain = wt(jid, cand)
                    b_w = wt(b, assign[b])
                    alt = next(
                        (a for a in per_job[b]
                         if not (a[1] == cand[1] and set(a[2]) & set(cand[2]))
                         and not conflicts(a, b)), None)
                    if alt is not None and gain + wt(b, alt) > b_w + 1e-12:
                        do_assign(b, alt)      # relocate the blocker…
                        do_assign(jid, cand)   # …and take its slot
                        improved = True
                        break
                    if alt is None and gain > b_w + 1e-12:
                        unassign(b)            # displace outright
                        do_assign(jid, cand)
                        improved = True
                        break
        return rounds
