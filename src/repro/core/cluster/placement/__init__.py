"""Fleet placement plane: heterogeneous GPU catalog, interconnect topology,
and pluggable placement policies over the §6 Eq. 1 performance model.

The greedy per-job path (``ClusterScheduler.place``) is registered as the
``greedy-eq1`` baseline; ``global-opt`` solves the whole batch jointly —
pruned (job × node × GPU-set) candidates, a greedy warm start, a min-cost
assignment core for the single-GPU jobs, and deterministic local-search
improvement — the Helix (ASPLOS'25) layout-synthesis recipe applied to
harvested-capacity placement.  Both policies consume identical measured
telemetry (``GPUTelemetry.source == 'nodesim'``).
"""
from repro.core.cluster.placement.profiles import (      # noqa: F401
    GPU_CATALOG, GPUProfile, TopologyModel, make_fleet_profiles)
from repro.core.cluster.placement.policy import (        # noqa: F401
    PLACEMENT_POLICIES, GreedyEq1Policy, PlacementPolicy, register_policy,
    resolve_policy, score_candidate)
from repro.core.cluster.placement.globalopt import (     # noqa: F401
    GlobalOptConfig, GlobalPlacementPolicy, SolveReport)
