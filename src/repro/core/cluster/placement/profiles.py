"""GPU profile catalog + interconnect topology for a heterogeneous fleet.

``GPUProfile`` is catalog *data* (the Helix ``machine_profiles`` idiom): a
memory fraction and a normalized-throughput scalar relative to the
reference GPU the workload profiles were measured on, plus the intra-node
link the card sits behind.  ``scale_sim`` derives the per-GPU ``SimConfig``
so the harness *measures* a slow card being slow — the catalog scalar then
re-enters Eq. 1 as a multiplier so predictions stay in the same normalized
units as achieved throughput.

``TopologyModel`` prices the links a placement crosses: NVLink/PCIe inside
a node, node-local vs cross-rack between nodes (the Baichuan
topology-aware-scheduling motivation).  Multi-GPU lockstep jobs pay the
intra-node efficiency of the node they land on; the disagg plane asks
``cheapest_pair`` where to put the prefill→decode handoff copy.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sim.colocation import SimConfig

# relative cost of moving KV bytes across each link tier (lower = cheaper);
# the absolute scale is arbitrary — only the ordering and ratios matter to
# placement decisions
LINK_COSTS: Dict[str, float] = {
    'nvlink': 1.0,       # intra-node NVLink
    'pcie': 4.0,         # intra-node PCIe
    'node-local': 12.0,  # different nodes, same rack (ToR switch)
    'cross-rack': 40.0,  # rack-to-rack (spine)
}

# lockstep efficiency of a multi-GPU job behind each intra-node link: the
# all-reduce per decode step is latency-bound, so PCIe shaves a few percent
# off the pair's effective throughput
INTRA_EFFICIENCY: Dict[str, float] = {'nvlink': 1.0, 'pcie': 0.94}


@dataclass(frozen=True)
class GPUProfile:
    """One catalog entry.  ``norm_throughput`` and ``mem_frac`` are relative
    to the reference GPU (the one workload profiles are measured on)."""
    model: str
    mem_frac: float          # KV pool size as a fraction of the reference
    norm_throughput: float   # step rate relative to the reference
    intra_link: str          # 'nvlink' | 'pcie'

    def scale_sim(self, base: SimConfig) -> SimConfig:
        """The per-GPU sim config this card actually runs: smaller KV pool,
        proportionally slower compute (host-side decode gap unchanged)."""
        return replace(
            base,
            total_pages=max(int(base.total_pages * self.mem_frac), 64),
            t_prefill_per_token=base.t_prefill_per_token / self.norm_throughput,
            t_decode_iter=base.t_decode_iter / self.norm_throughput)


GPU_CATALOG: Dict[str, GPUProfile] = {
    'A100': GPUProfile('A100', mem_frac=1.0, norm_throughput=1.0,
                       intra_link='nvlink'),
    'L4': GPUProfile('L4', mem_frac=0.5, norm_throughput=0.5,
                     intra_link='pcie'),
    'T4': GPUProfile('T4', mem_frac=0.375, norm_throughput=0.3,
                     intra_link='pcie'),
}


@dataclass
class TopologyModel:
    """Link-cost model over the fleet: node → rack and node → intra-link."""
    rack_of: Dict[str, int] = field(default_factory=dict)
    intra_link_of: Dict[str, str] = field(default_factory=dict)
    link_costs: Dict[str, float] = field(
        default_factory=lambda: dict(LINK_COSTS))

    def link_tier(self, a: str, b: str) -> str:
        if a == b:
            return self.intra_link_of.get(a, 'nvlink')
        if self.rack_of.get(a, 0) == self.rack_of.get(b, 1):
            return 'node-local'
        return 'cross-rack'

    def link_cost(self, a: str, b: str) -> float:
        return self.link_costs[self.link_tier(a, b)]

    def intra_efficiency(self, node: str) -> float:
        """Lockstep efficiency for a multi-GPU placement on ``node``."""
        return INTRA_EFFICIENCY[self.intra_link_of.get(node, 'nvlink')]

    def cheapest_pair(self, srcs: Sequence[str], dsts: Sequence[str]
                      ) -> Tuple[str, str, str, float]:
        """The (src, dst) node pair whose link is cheapest — where the
        disagg plane should put the prefill→decode handoff copy.  Distinct
        nodes preferred; src == dst (two pools on one node) is allowed only
        when it is the single option.  Deterministic: ties break on name.
        """
        assert srcs and dsts, 'need candidates on both sides'
        best = None
        for s in sorted(srcs):
            for d in sorted(dsts):
                if s == d and (len(srcs) > 1 or len(dsts) > 1):
                    continue
                c = self.link_cost(s, d)
                if best is None or c < best[3]:
                    best = (s, d, self.link_tier(s, d), c)
        return best


def make_fleet_profiles(node_names: Sequence[str], gpus_per_node: int, *,
                        mix: Sequence[Tuple[str, float]] = (
                            ('A100', 0.3), ('L4', 0.4), ('T4', 0.3)),
                        nodes_per_rack: int = 16,
                        seed: int = 0) -> Tuple[
                            Dict[str, Tuple[GPUProfile, ...]], TopologyModel]:
    """Assign catalog profiles to a fleet (homogeneous within a node, as in
    real procurement) and lay nodes out in racks.

    Seeding is isolated per node via ``SeedSequence.spawn`` — growing the
    fleet never re-rolls the profile of an existing node.
    """
    names = [m for m, _ in mix]
    weights = np.asarray([w for _, w in mix], dtype=float)
    weights = weights / weights.sum()
    children = np.random.SeedSequence(seed).spawn(len(node_names))
    profiles: Dict[str, Tuple[GPUProfile, ...]] = {}
    topo = TopologyModel()
    for i, name in enumerate(node_names):
        rng = np.random.default_rng(children[i])
        model = names[int(rng.choice(len(names), p=weights))]
        prof = GPU_CATALOG[model]
        profiles[name] = (prof,) * gpus_per_node
        topo.rack_of[name] = i // nodes_per_rack
        topo.intra_link_of[name] = prof.intra_link
    return profiles, topo
