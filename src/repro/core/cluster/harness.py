"""Closed-loop cluster simulation harness — the §6 scheduler driven by
measured NodeSim telemetry (the repo's first end-to-end take on the paper's
top-line claim).

Before this harness, the ``ClusterScheduler`` scored placements against
hand-written synthetic telemetry and never saw what a colocated node
actually does.  Here the loop is closed:

1. **scout** — every GPU of every node runs one online-only ``NodeSim``
   epoch; its measured busy intervals and free-memory trace become the
   ``NodeTelemetry`` the Eq. 1 model scores (``source='nodesim'``, never
   hand-written).  Per-epoch runtime counters (preemptions, reclamations)
   are read from each sim's :class:`~repro.core.telemetry.TelemetryRegistry`
   — the fold over the typed event stream of :mod:`repro.core.events` —
   so the harness observes the same ordered facts as the live node;
2. **profile** — each offline workload's memory→throughput curve is
   measured by sweeping ``NodeSim`` at different pool sizes
   (:func:`profile_workload_from_sim`), not synthesized;
3. **place** — the scheduler places jobs with the Eq. 1 model over the
   measured telemetry;
4. **run an epoch** — every GPU runs a real colocated ``NodeSim`` over its
   epoch slice of the online trace, with the placed job's offline workload;
5. **report** — each job's achieved normalized throughput (actual offline
   tokens / measured standalone max) goes to ``report_throughput``;
   persistent SLA violators are evicted;
6. **refresh + retry** — node telemetry is replaced with this epoch's
   measurements and pending (incl. evicted) jobs are rescheduled.

Epoch after epoch, admission, monitoring, eviction and ``retry_pending``
all operate on *simulated-measured* data.  Non-stationary nodes (quiet when
scouted, hot afterwards — ``make_fleet_workloads``'s ramp nodes) exercise
the eviction/reschedule path the paper's production story depends on.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster.perfmodel import (
    GPUTelemetry, NodeTelemetry, WorkloadProfile, profile_workload_from_curve)
from repro.core.cluster.placement.profiles import (
    GPUProfile, TopologyModel, make_fleet_profiles)
from repro.core.cluster.scheduler import (
    ClusterScheduler, OfflineJob, Placement, SchedulerConfig)
from repro.core.sim.colocation import (
    NodeSim, SimConfig, SimResult, run_offline_standalone,
    run_online_standalone)
from repro.core.sim import strategies as S
from repro.core.sim.strategies import OurMem
from repro.core.sim.workload import (
    NodeWorkload, OfflineWorkload, OnlineWorkload, WorkloadPair,
    make_fleet_workloads, slice_trace)


# ---------------------------------------------------------------------------
# SimResult → perf-model telemetry
# ---------------------------------------------------------------------------

def telemetry_from_sim(res: SimResult, *,
                       window: Optional[float] = None) -> GPUTelemetry:
    """Extract the Eq. 1 inputs from a finished ``NodeSim`` run: measured
    online-busy intervals (P_compute, P_multi) and the measured
    not-held-by-online memory trace (P_memory)."""
    t1 = float(window if window is not None else res.horizon)
    return GPUTelemetry(list(res.busy_intervals),
                        np.asarray(res.mem_trace_t, dtype=float),
                        np.asarray(res.mem_trace_free, dtype=float),
                        window=(0.0, t1), source='nodesim')


def profile_workload_from_sim(off: OfflineWorkload, sim_cfg: SimConfig, *,
                              name: Optional[str] = None, n_gpus: int = 1,
                              fractions: Sequence[float] = (
                                  0.1, 0.2, 0.35, 0.55, 0.8, 1.0),
                              horizon_s: float = 15.0) -> WorkloadProfile:
    """Measure a workload's memory→throughput curve by running the offline
    engine standalone in ``NodeSim`` at swept pool sizes (the profiling run
    the paper performs once at job submission)."""
    mems, thrs = [], []
    for f in fractions:
        pages = max(int(sim_cfg.total_pages * f), 32)
        sub = replace(sim_cfg, total_pages=pages)
        pair = WorkloadPair(off.name, OnlineWorkload('empty', [], horizon_s),
                            off)
        res = run_offline_standalone(pair, sub)
        mems.append(float(pages))
        thrs.append(res.offline_throughput)
    return profile_workload_from_curve(name or off.name, mems, thrs,
                                       n_gpus=n_gpus)


# ---------------------------------------------------------------------------
# Jobs
# ---------------------------------------------------------------------------

@dataclass
class HarvestJob:
    """A schedulable offline job plus the actual workload its NodeSim runs
    (the scheduler sees only the profile; the harness runs the real thing)."""
    job: OfflineJob
    workload: OfflineWorkload


def make_harvest_jobs(n_jobs: int, sim_cfg: SimConfig, *, seed: int = 0,
                      gpus_per_node: int = 2,
                      multi_gpu_every: int = 4,
                      sla_range: Tuple[float, float] = (0.2, 0.35)
                      ) -> List[HarvestJob]:
    """A mix of single- and multi-GPU offline jobs over a few workload
    archetypes, each profiled from the sim (profiles cached per archetype —
    profiling is the expensive once-per-submission step).

    Seeding is isolated per job (``SeedSequence.spawn``): job *j*'s SLA
    depends only on ``(seed, j)``, so growing ``n_jobs`` never re-rolls
    existing jobs and a large submission batch is byte-reproducible."""
    children = np.random.SeedSequence(seed).spawn(max(n_jobs, 1))
    archetypes = [
        OfflineWorkload('arch-small', prompt_tokens=256, output_tokens=128,
                        max_batch=32),
        OfflineWorkload('arch-med', prompt_tokens=512, output_tokens=256,
                        max_batch=48),
        OfflineWorkload('arch-mixed', prompt_tokens=512, output_tokens=256,
                        max_batch=48, prompt_choices=(256, 512, 1024),
                        output_choices=(128, 256)),
        # HyGen-style dominant harvest shape: one system prompt shared by
        # the whole batch — exercises the memory plane's prefix sharing
        # and keeps the partial-invalidation surviving prefixes long
        OfflineWorkload('arch-prefix', prompt_tokens=512, output_tokens=192,
                        max_batch=48, shared_prefix_tokens=256),
    ]
    prof_cache: Dict[str, WorkloadProfile] = {}
    jobs: List[HarvestJob] = []
    for j in range(n_jobs):
        arch = archetypes[j % len(archetypes)]
        if arch.name not in prof_cache:
            prof_cache[arch.name] = profile_workload_from_sim(arch, sim_cfg)
        base = prof_cache[arch.name]
        n_gpus = gpus_per_node if (multi_gpu_every
                                   and j % multi_gpu_every == multi_gpu_every - 1) else 1
        prof = WorkloadProfile(f'job{j}', base.mem_points, base.thrput_points,
                               base.m_req, base.mac, n_gpus)
        sla = float(np.random.default_rng(children[j]).uniform(*sla_range))
        jobs.append(HarvestJob(OfflineJob(prof, sla, job_id=f'job{j}'), arch))
    return jobs


# ---------------------------------------------------------------------------
# The harness
# ---------------------------------------------------------------------------

@dataclass
class HarnessConfig:
    n_nodes: int = 8
    gpus_per_node: int = 2
    epoch_s: float = 60.0
    n_epochs: int = 4                 # colocated epochs after the scout
    seed: int = 0
    # strategy under test (run_strategy-compatible names)
    compute: str = 'Channel'
    memory: str = 'OurMem'
    eviction_policy: str = 'valve'
    sim: SimConfig = field(default_factory=lambda: SimConfig(
        total_pages=1024))
    sched: SchedulerConfig = field(default_factory=lambda: SchedulerConfig(
        violation_patience=2))
    # non-stationary fleet knobs (see make_fleet_workloads)
    n_ramp_nodes: int = 1
    ramp_mult: float = 60.0
    aligned_frac: float = 0.68
    # placement plane: policy name ('greedy-eq1' | 'global-opt' | any
    # registered PlacementPolicy) and an optional heterogeneous GPU mix
    # (catalog-name → weight, see placement.profiles.make_fleet_profiles);
    # None = homogeneous reference-GPU fleet, no topology model
    placement: str = 'greedy-eq1'
    gpu_mix: Optional[Tuple[Tuple[str, float], ...]] = None
    nodes_per_rack: int = 16
    # also run each colocated epoch slice online-standalone for TTFT/TPOT
    # interference deltas (doubles the sim count)
    measure_baseline: bool = True


@dataclass
class EpochReport:
    epoch: int
    placements: int
    pending: int
    evictions_total: int
    reschedules_total: int
    utilization_gain_measured: float
    gpus_saved_measured: float
    achieved: Dict[str, float] = field(default_factory=dict)
    predicted: Dict[str, float] = field(default_factory=dict)
    offline_tokens: float = 0.0
    recompute_tokens: float = 0.0     # Algorithm-1 vs FIFO victim cost
    compute_preemptions: int = 0
    reclamations: int = 0
    max_preempt_per_request: int = 0  # paper invariant: ≤ 1 (any GPU, epoch)
    solver_wall_s: float = 0.0        # placement-policy solve time (retry)
    ttft_delta: Optional[float] = None    # mean relative vs standalone
    tpot_delta: Optional[float] = None


class ClusterHarness:
    """Epoch-driven closed loop over a fleet of NodeSim-backed nodes."""

    def __init__(self, fleet: List[NodeWorkload], jobs: List[HarvestJob],
                 cfg: Optional[HarnessConfig] = None, *,
                 profiles: Optional[Dict[str, Tuple[GPUProfile, ...]]] = None,
                 topology: Optional[TopologyModel] = None):
        self.cfg = cfg or HarnessConfig()
        self.fleet = fleet
        self.jobs = jobs
        self.profiles = profiles        # node → per-GPU catalog entries
        self.topology = topology
        self._workload_of = {h.job.job_id: h.workload for h in jobs}
        self._thrput_max = {h.job.job_id: h.job.profile.thrput_max
                            for h in jobs}
        self.scheduler: Optional[ClusterScheduler] = None
        self.reports: List[EpochReport] = []
        self.scout_telemetry: Dict[str, NodeTelemetry] = {}

    # ------------------------------------------------------------ plumbing
    def _gpu_sim(self, node: str, gi: int) -> SimConfig:
        """The sim config this GPU actually runs: the base config scaled by
        its catalog profile (heterogeneous fleets), or the base as-is."""
        if self.profiles is None:
            return self.cfg.sim
        return self.profiles[node][gi].scale_sim(self.cfg.sim)

    def _gpu_profile(self, node: str, gi: int) -> Optional[GPUProfile]:
        return self.profiles[node][gi] if self.profiles is not None else None

    def _rack_of(self, node: str) -> int:
        return self.topology.rack_of.get(node, 0) if self.topology else 0

    def _mem_policy(self, sim_cfg: SimConfig):
        c = self.cfg
        if c.memory == 'OurMem':
            return OurMem(sim_cfg.total_pages, sim_cfg.page_tokens,
                          policy=c.eviction_policy)
        return S.MEMORY_POLICIES[c.memory](sim_cfg.total_pages,
                                           sim_cfg.page_tokens)

    def _run_gpu_epoch(self, trace: OnlineWorkload,
                       off: Optional[OfflineWorkload],
                       sim_cfg: SimConfig) -> SimResult:
        pair = WorkloadPair(trace.name, trace,
                            off or OfflineWorkload('idle'))
        cp = S.COMPUTE_POLICIES[self.cfg.compute]()
        sim = NodeSim(pair, cp, self._mem_policy(sim_cfg), sim_cfg,
                      offline_enabled=off is not None)
        return sim.run()

    def _job_on_gpu(self) -> Dict[Tuple[str, int], Placement]:
        out: Dict[Tuple[str, int], Placement] = {}
        for p in self.scheduler.placements.values():
            for gi in p.gpu_indices:
                out[(p.node, gi)] = p
        return out

    # ------------------------------------------------------------- phases
    def scout(self) -> ClusterScheduler:
        """Epoch 0: online-only runs measure every node's telemetry; the
        scheduler is constructed from those measurements alone."""
        c = self.cfg
        teles = []
        for node in self.fleet:
            gpus = []
            for gi, trace in enumerate(node.gpu_traces):
                sl = slice_trace(trace, 0.0, c.epoch_s)
                res = run_online_standalone(
                    WorkloadPair(sl.name, sl, OfflineWorkload('idle')),
                    self._gpu_sim(node.name, gi))
                g = telemetry_from_sim(res, window=c.epoch_s)
                g.profile = self._gpu_profile(node.name, gi)
                gpus.append(g)
            tele = NodeTelemetry(node.name, gpus,
                                 rack=self._rack_of(node.name))
            teles.append(tele)
            self.scout_telemetry[node.name] = tele
        self.scheduler = ClusterScheduler(teles, c.sched,
                                          policy=c.placement,
                                          topology=self.topology)
        return self.scheduler

    def submit_all(self) -> int:
        placed = self.scheduler.place_all([h.job for h in self.jobs])
        return len(placed)

    def run_epoch(self, epoch: int) -> EpochReport:
        """One closed-loop round: run every GPU's NodeSim over this epoch's
        trace slice (colocated where a job is placed), report measured
        achieved throughput, refresh telemetry, retry pending jobs."""
        c = self.cfg
        t0, t1 = epoch * c.epoch_s, (epoch + 1) * c.epoch_s
        on_gpu = self._job_on_gpu()
        rep = EpochReport(
            epoch=epoch, placements=len(self.scheduler.placements),
            pending=len(self.scheduler.pending),
            evictions_total=self.scheduler.evictions,
            reschedules_total=self.scheduler.reschedules,
            utilization_gain_measured=0.0, gpus_saved_measured=0.0)

        job_tokens: Dict[str, List[float]] = {}
        ttft_d, tpot_d = [], []
        new_teles = []
        for node in self.fleet:
            gpus = []
            for gi, trace in enumerate(node.gpu_traces):
                scfg = self._gpu_sim(node.name, gi)
                sl = slice_trace(trace, t0, t1)
                p = on_gpu.get((node.name, gi))
                off = self._workload_of[p.job.job_id] if p else None
                res = self._run_gpu_epoch(sl, off, scfg)
                g = telemetry_from_sim(res, window=c.epoch_s)
                g.profile = self._gpu_profile(node.name, gi)
                gpus.append(g)
                rep.offline_tokens += res.offline_tokens
                rep.recompute_tokens += res.recompute_tokens
                # counters come from the sim's TelemetryRegistry (the fold
                # over its typed event stream — the same surface the live
                # node exposes), not from per-policy stat objects
                tel = res.telemetry.counters
                rep.compute_preemptions += tel.preemptions
                rep.reclamations += tel.reclamations
                rep.max_preempt_per_request = max(
                    rep.max_preempt_per_request, res.max_preempt_per_request)
                if p is not None:
                    job_tokens.setdefault(p.job.job_id, []).append(
                        res.offline_tokens / max(res.horizon, 1e-9))
                if c.measure_baseline and sl.requests:
                    base = run_online_standalone(
                        WorkloadPair(sl.name, sl, OfflineWorkload('idle')),
                        scfg)
                    ttft_d += [(res.ttft[k] - base.ttft[k])
                               / max(base.ttft[k], 1e-9)
                               for k in base.ttft if k in res.ttft]
                    tpot_d += [(res.tpot[k] - base.tpot[k])
                               / max(base.tpot[k], 1e-9)
                               for k in base.tpot if k in res.tpot]
            new_teles.append(NodeTelemetry(node.name, gpus,
                                           rack=self._rack_of(node.name)))

        # report achieved normalized throughput (model-parallel jobs run in
        # lockstep → the slowest shard sets the job's rate)
        for job_id, rates in job_tokens.items():
            achieved = min(rates) / max(self._thrput_max[job_id], 1e-9)
            p = self.scheduler.placements.get(job_id)
            if p is not None:
                rep.achieved[job_id] = achieved
                rep.predicted[job_id] = p.predicted
            self.scheduler.report_throughput(job_id, achieved)

        rep.utilization_gain_measured = self.scheduler.utilization_gain(
            measured=True)
        rep.gpus_saved_measured = self.scheduler.gpus_saved(measured=True)

        # telemetry refresh + retry (evicted jobs avoid their old node);
        # every Eq. 1 input the policies consume must be sim-measured —
        # the provenance invariant policy swaps are asserted against
        for tele in new_teles:
            assert all(g.source == 'nodesim' for g in tele.gpus), \
                'placement must only ever see measured telemetry'
            self.scheduler.update_node(tele)
        n_reports = len(getattr(self.scheduler.policy, 'reports', []))
        self.scheduler.retry_pending()
        rep.solver_wall_s = sum(
            r.wall_time_s for r in
            getattr(self.scheduler.policy, 'reports', [])[n_reports:])

        rep.evictions_total = self.scheduler.evictions
        rep.reschedules_total = self.scheduler.reschedules
        if ttft_d:
            rep.ttft_delta = float(np.mean(ttft_d))
        if tpot_d:
            rep.tpot_delta = float(np.mean(tpot_d))
        self.reports.append(rep)
        return rep

    def run(self) -> List[EpochReport]:
        c = self.cfg
        self.scout()
        self.submit_all()
        for e in range(1, c.n_epochs + 1):
            self.run_epoch(e)
        return self.reports


def make_harness(cfg: Optional[HarnessConfig] = None,
                 n_jobs: Optional[int] = None) -> ClusterHarness:
    """Convenience: fleet + jobs + harness from one config (the benchmark
    and the CI smoke both build through here)."""
    cfg = cfg or HarnessConfig()
    horizon = cfg.epoch_s * (cfg.n_epochs + 1)
    fleet = make_fleet_workloads(
        cfg.n_nodes, cfg.gpus_per_node, horizon_s=horizon, seed=cfg.seed,
        n_ramp_nodes=cfg.n_ramp_nodes, ramp_at_s=cfg.epoch_s,
        ramp_mult=cfg.ramp_mult, aligned_frac=cfg.aligned_frac)
    if n_jobs is None:
        n_jobs = max(cfg.n_nodes * cfg.gpus_per_node // 2, 2)
    jobs = make_harvest_jobs(n_jobs, cfg.sim, seed=cfg.seed,
                             gpus_per_node=cfg.gpus_per_node)
    profiles = topo = None
    if cfg.gpu_mix is not None:
        profiles, topo = make_fleet_profiles(
            [n.name for n in fleet], cfg.gpus_per_node, mix=cfg.gpu_mix,
            nodes_per_rack=cfg.nodes_per_rack, seed=cfg.seed)
    return ClusterHarness(fleet, jobs, cfg, profiles=profiles, topology=topo)
