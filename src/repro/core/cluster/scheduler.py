"""Cluster-level offline-job scheduler (paper §6 "Scheduling").

Placement: for each submitted offline job, score every candidate GPU set
with the Eq. 1 performance model, admit on the best node whose predicted
normalized throughput meets the job's SLA (a fraction of standalone
throughput) and whose multi-GPU alignment passes the 0.95 gate.

Monitoring: achieved throughput is reported periodically; jobs that
persistently violate their SLA are evicted and rescheduled elsewhere.

Placement strategy is pluggable (``placement.policy.PlacementPolicy``):
``place``/``_score``/``_candidate_sets`` are the per-job primitives every
policy builds on; ``place_all`` and ``retry_pending`` route through the
configured policy, so the greedy path ('greedy-eq1') and the global
optimizer ('global-opt') run on identical telemetry and bookkeeping.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cluster.perfmodel import NodeTelemetry, WorkloadProfile


@dataclass
class OfflineJob:
    profile: WorkloadProfile
    sla: float                       # required fraction of Thrput_max
    job_id: str = ''

    def __post_init__(self):
        if not self.job_id:
            self.job_id = self.profile.name


@dataclass
class Placement:
    job: OfflineJob
    node: str
    gpu_indices: Tuple[int, ...]
    predicted: float
    achieved: Optional[float] = None     # last reported normalized thrput


@dataclass
class SchedulerConfig:
    violation_patience: int = 3      # consecutive violating reports → evict
    sla_slack: float = 0.0           # admit only if predicted ≥ sla + slack


class ClusterScheduler:
    def __init__(self, nodes: Sequence[NodeTelemetry],
                 cfg: Optional[SchedulerConfig] = None, *,
                 policy='greedy-eq1', topology=None):
        # runtime import: placement builds on this module's types
        from repro.core.cluster.placement.policy import (
            resolve_policy, score_candidate)
        self._score_candidate = score_candidate
        self.policy = resolve_policy(policy)
        self.topology = topology                 # placement.TopologyModel
        self.nodes: Dict[str, NodeTelemetry] = {n.name: n for n in nodes}
        self.cfg = cfg or SchedulerConfig()
        self.placements: Dict[str, Placement] = {}
        self.pending: List[OfflineJob] = []
        self._busy_gpus: Dict[str, set] = {n: set() for n in self.nodes}
        self._violations: Dict[str, int] = {}
        self._evicted_from: Dict[str, str] = {}   # job → node, one-shot avoid
        self._awaiting_reschedule: set = set()    # evicted, not yet replaced
        self.evictions = 0
        self.reschedules = 0

    # ----------------------------------------------------------- telemetry
    def update_node(self, tele: NodeTelemetry) -> None:
        """Refresh (or register) one node's telemetry — the closed-loop
        harness calls this with freshly measured traces every epoch, so
        placement and retry decisions track what nodes actually did."""
        self.nodes[tele.name] = tele
        self._busy_gpus.setdefault(tele.name, set())

    # ------------------------------------------------------------- placing
    def _candidate_sets(self, node: NodeTelemetry, k: int
                        ) -> List[Tuple[int, ...]]:
        free = [i for i in range(len(node.gpus))
                if i not in self._busy_gpus[node.name]]
        if k == 1:
            return [(i,) for i in free]
        # bounded enumeration: contiguous groups first (rack locality), then
        # a few combinations — production uses topology-aware grouping
        cands = [tuple(free[i:i + k]) for i in range(len(free) - k + 1)]
        extra = list(itertools.islice(itertools.combinations(free, k), 16))
        return list(dict.fromkeys(cands + extra))

    def _score(self, job: OfflineJob, node: NodeTelemetry,
               gpus: Tuple[int, ...]) -> Optional[float]:
        return self._score_candidate(job, node, gpus,
                                     sla_slack=self.cfg.sla_slack,
                                     topology=self.topology)

    def place(self, job: OfflineJob,
              avoid: Optional[set] = None) -> Optional[Placement]:
        """Place on the best-scoring admissible GPU set.  ``avoid`` skips
        named nodes (a just-evicted job must not land straight back on the
        node it was violating on before fresh telemetry shows recovery)."""
        best: Optional[Placement] = None
        for node in self.nodes.values():
            if avoid and node.name in avoid:
                continue
            for gpus in self._candidate_sets(node, job.profile.n_gpus):
                score = self._score(job, node, gpus)
                if score is None:
                    continue
                if best is None or score > best.predicted:
                    best = Placement(job, node.name, gpus, score)
        if best is None:
            # compare by job_id: dataclass equality would compare the
            # profile's numpy arrays and raise on ambiguous truth value
            if all(j.job_id != job.job_id for j in self.pending):
                self.pending.append(job)
            return None
        self._commit(best)
        return best

    def place_all(self, jobs: Sequence[OfflineJob]) -> List[Placement]:
        """Place a submission batch through the configured policy (the
        global optimizer decides jointly; greedy falls back to per-job
        ``place`` in submission order)."""
        return self.policy.place_batch(self, jobs)

    def _commit(self, p: Placement) -> None:
        self.placements[p.job.job_id] = p
        self._busy_gpus[p.node].update(p.gpu_indices)
        self._violations[p.job.job_id] = 0

    def _release(self, job_id: str) -> Optional[Placement]:
        p = self.placements.pop(job_id, None)
        if p is not None:
            self._busy_gpus[p.node].difference_update(p.gpu_indices)
            self._violations.pop(job_id, None)
        return p

    # ------------------------------------------------------------ monitor
    def report_throughput(self, job_id: str, achieved_norm: float) -> None:
        """Periodic achieved-throughput report (normalized to standalone).
        Persistent violators are evicted for rescheduling."""
        p = self.placements.get(job_id)
        if p is None:
            return
        p.achieved = achieved_norm
        if achieved_norm + 1e-9 < p.job.sla:
            self._violations[job_id] = self._violations.get(job_id, 0) + 1
        else:
            self._violations[job_id] = 0
        if self._violations[job_id] >= self.cfg.violation_patience:
            self._release(job_id)
            self.evictions += 1
            self._evicted_from[job_id] = p.node
            self._awaiting_reschedule.add(job_id)
            self.pending.append(p.job)

    def retry_pending(self) -> List[Placement]:
        """Re-attempt pending jobs through the configured policy (called
        after telemetry refresh) — eviction/reschedule consults the same
        optimizer as submission.  Evicted jobs avoid the node they violated
        on for this one retry; the avoid is consumed whether or not
        placement succeeds — holding it forever would starve a job whose
        only viable node is the (possibly recovered) one it was evicted
        from."""
        todo, self.pending = self.pending, []
        avoid = {}
        for job in todo:
            bad_node = self._evicted_from.pop(job.job_id, None)
            if bad_node is not None:
                avoid[job.job_id] = {bad_node}
        placed = self.policy.place_batch(self, todo, avoid=avoid)
        for p in placed:
            if p.job.job_id in self._awaiting_reschedule:
                self._awaiting_reschedule.discard(p.job.job_id)
                self.reschedules += 1
        return placed

    # ------------------------------------------------------------- stats
    def _norm_thrput(self, p: Placement, measured: bool) -> float:
        if measured and p.achieved is not None:
            return p.achieved
        return p.predicted

    def utilization_gain(self, measured: bool = False) -> float:
        """Fraction of cluster GPU-time given to offline work — the paper's
        "improved GPU utilization" metric.  ``measured=True`` uses the last
        reported achieved throughput instead of the Eq. 1 prediction (the
        closed-loop harness reports sim-measured values)."""
        total = sum(len(n.gpus) for n in self.nodes.values())
        gained = sum(self._norm_thrput(p, measured) * p.job.profile.n_gpus
                     for p in self.placements.values())
        return gained / max(total, 1)

    def gpus_saved(self, measured: bool = False) -> float:
        """Σ offline throughput normalized by standalone — each unit is one
        GPU's worth of offline work done on harvested capacity."""
        return sum(self._norm_thrput(p, measured) * p.job.profile.n_gpus
                   for p in self.placements.values())
