"""Offline-on-harvested-GPU performance model (paper §6, Eq. 1–2).

    Thrput(w,N) / Thrput(w,max) = P_compute · P_memory · P_multi

- ``P_compute``: idle compute fraction of the node (timeslices available to
  offline), measured by the colocation runtime.
- ``P_memory`` (Eq. 2): expected throughput over the node's free-memory
  trace through the workload's profiled memory→throughput curve, minus
  ``MAC_w · E[ΔM]`` for dips below the required memory.
- ``P_multi``: pairwise busy-time alignment across the node's GPUs —
  ``T_∩ / T_∪`` of busy intervals; model-parallel offline jobs run in
  lockstep, so misaligned online activity creates stragglers.  Admission
  requires every pair ≥ 0.95.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

import numpy as np

if TYPE_CHECKING:                                     # pragma: no cover
    from repro.core.cluster.placement.profiles import GPUProfile

MULTI_ADMIT_THRESHOLD = 0.95


# ---------------------------------------------------------------------------
# Workload profile (measured once at submission)
# ---------------------------------------------------------------------------

@dataclass
class WorkloadProfile:
    """Memory→throughput curve + recompute sensitivity for one offline job."""
    name: str
    mem_points: np.ndarray          # available memory samples (pages)
    thrput_points: np.ndarray       # tokens/s at each sample
    m_req: float                    # memory for full throughput
    mac: float                      # Eq. 2 MAC_w: tokens/s lost per page of
                                    # expected deficit
    n_gpus: int = 1                 # model-parallel degree

    @property
    def thrput_max(self) -> float:
        return float(self.thrput_points[-1])

    def thrput_at(self, mem: np.ndarray) -> np.ndarray:
        return np.interp(mem, self.mem_points, self.thrput_points)


def profile_workload(name: str, *, thrput_max: float, m_req: float,
                     n_gpus: int = 1, mac: Optional[float] = None,
                     n_points: int = 8) -> WorkloadProfile:
    """Synthesize a concave saturating memory→throughput curve (the shape a
    profiling run of a batch-inference job produces: throughput ∝ batch
    size ∝ KV memory until compute-bound)."""
    mems = np.linspace(0, m_req * 1.5, n_points)
    sat = np.minimum(mems / m_req, 1.0) ** 0.7    # concave ramp, saturates
    thr = thrput_max * sat
    return WorkloadProfile(name, mems, thr, m_req,
                           mac if mac is not None else thrput_max / m_req,
                           n_gpus)


def profile_workload_from_curve(name: str, mem_points, thrput_points, *,
                                n_gpus: int = 1, sat_frac: float = 0.95,
                                mac: Optional[float] = None
                                ) -> WorkloadProfile:
    """Build a profile from a MEASURED memory→throughput sweep (e.g. a
    ``NodeSim`` run per pool size — see ``cluster.harness.
    profile_workload_from_sim``).

    ``m_req`` is the knee: the smallest measured memory reaching
    ``sat_frac`` of peak throughput.  ``mac`` (Eq. 2's tokens/s lost per
    page of deficit) defaults to the mean curve slope below the knee.
    """
    order = np.argsort(np.asarray(mem_points, dtype=float))
    mems = np.asarray(mem_points, dtype=float)[order]
    thrs = np.asarray(thrput_points, dtype=float)[order]
    assert len(mems) >= 2, 'need ≥2 sweep points'
    # enforce monotone non-decreasing throughput (more memory never hurts a
    # batch job; sim noise can produce tiny inversions)
    thrs = np.maximum.accumulate(thrs)
    peak = float(thrs[-1])
    sat_idx = int(np.argmax(thrs >= sat_frac * peak))
    m_req = float(mems[sat_idx])
    if mac is None:
        below = max(sat_idx, 1)
        rise = float(thrs[below] - thrs[0])
        run = max(float(mems[below] - mems[0]), 1e-9)
        mac = rise / run
    return WorkloadProfile(name, mems, thrs, m_req, float(mac), n_gpus)


# ---------------------------------------------------------------------------
# Node telemetry
# ---------------------------------------------------------------------------

@dataclass
class GPUTelemetry:
    """Busy intervals + free-memory trace for one GPU over a window.

    ``source`` records provenance: 'synthetic' for hand-written curves,
    'nodesim' when extracted from a real ``NodeSim`` run — the closed-loop
    harness tags (and its benchmark asserts) the latter, so no Eq. 1 input
    is hand-written.
    """
    busy_intervals: List[Tuple[float, float]]
    mem_trace_t: np.ndarray         # sample times
    mem_trace_free: np.ndarray      # free pages at each sample
    window: Tuple[float, float] = (0.0, 600.0)
    source: str = 'synthetic'
    # heterogeneous fleets: the catalog entry this GPU was measured under
    # (placement.profiles.GPUProfile); None = the reference GPU, scalar 1.0
    profile: Optional['GPUProfile'] = None

    def idle_fraction(self) -> float:
        t0, t1 = self.window
        busy = sum(min(b, t1) - max(a, t0)
                   for a, b in self.busy_intervals if b > t0 and a < t1)
        return max(0.0, 1.0 - busy / max(t1 - t0, 1e-9))


@dataclass
class NodeTelemetry:
    name: str
    gpus: List[GPUTelemetry]
    rack: int = 0                   # topology coordinate (placement plane)

    def free_gpu_indices(self) -> List[int]:
        return list(range(len(self.gpus)))


# ---------------------------------------------------------------------------
# The three factors
# ---------------------------------------------------------------------------

def p_compute(gpu: GPUTelemetry) -> float:
    return gpu.idle_fraction()


def p_memory(w: WorkloadProfile, gpu: GPUTelemetry) -> float:
    """Eq. 2 over the node's free-memory trace."""
    free = gpu.mem_trace_free
    e_thr = float(np.mean(w.thrput_at(free)))
    deficit = np.maximum(0.0, w.m_req - free)
    e_def = float(np.mean(deficit))
    val = (e_thr - w.mac * e_def) / max(w.thrput_max, 1e-9)
    return float(np.clip(val, 0.0, 1.0))


def _union_intersection(a: List[Tuple[float, float]],
                        b: List[Tuple[float, float]],
                        window: Tuple[float, float]) -> Tuple[float, float]:
    """(T_∩, T_∪) of two busy-interval sets over the window."""
    t0, t1 = window
    grid = sorted({t0, t1}
                  | {max(t0, min(x, t1)) for iv in a for x in iv}
                  | {max(t0, min(x, t1)) for iv in b for x in iv})

    def busy_at(ivs, lo, hi):
        mid = 0.5 * (lo + hi)
        return any(s <= mid < e for s, e in ivs)

    inter = union = 0.0
    for lo, hi in zip(grid, grid[1:]):
        if hi <= lo:
            continue
        ba, bb = busy_at(a, lo, hi), busy_at(b, lo, hi)
        if ba and bb:
            inter += hi - lo
        if ba or bb:
            union += hi - lo
    return inter, union


def p_multi(gpus: Sequence[GPUTelemetry]) -> float:
    """Minimum pairwise T_∩/T_∪ alignment score across the GPU set."""
    if len(gpus) <= 1:
        return 1.0
    score = 1.0
    for i in range(len(gpus)):
        for j in range(i + 1, len(gpus)):
            inter, union = _union_intersection(
                gpus[i].busy_intervals, gpus[j].busy_intervals,
                gpus[i].window)
            s = 1.0 if union == 0 else inter / union
            score = min(score, s)
    return score


def predict_normalized_throughput(w: WorkloadProfile,
                                  gpus: Sequence[GPUTelemetry]) -> float:
    """Eq. 1 for a candidate GPU set (len == w.n_gpus).

    Heterogeneous fleets: each GPU's catalog ``norm_throughput`` scalar
    rescales the prediction to the reference GPU the workload profile was
    measured on (lockstep jobs run at the slowest card's rate), keeping
    predictions in the same normalized units as achieved throughput.
    """
    pc = min(p_compute(g) for g in gpus)
    pm = min(p_memory(w, g) for g in gpus)
    px = p_multi(gpus)
    scale = min((g.profile.norm_throughput if g.profile is not None else 1.0)
                for g in gpus)
    return pc * pm * px * scale


def admissible(w: WorkloadProfile, gpus: Sequence[GPUTelemetry]) -> bool:
    if len(gpus) != w.n_gpus:
        return False
    return w.n_gpus == 1 or p_multi(gpus) >= MULTI_ADMIT_THRESHOLD
