"""Typed runtime event stream — the control-plane API's observation surface.

Every consequential control-plane action in the Valve runtime (and in the
§7.2 ``NodeSim``) is published as exactly one immutable, sequence-numbered
event on an :class:`EventBus`.  Consumers — the node orchestrator, the
simulator, the cluster harness, telemetry — subscribe instead of poking
counters, so all of them observe the *same ordered facts*:

- :class:`PreemptionEvent`      — offline compute gates closed (paper §4);
- :class:`ReclamationEvent`     — offline KV handles reclaimed (paper §5);
- :class:`WakeupEvent`          — offline compute re-enabled after T_cool;
- :class:`ReservationChangeEvent` — MIAD moved the reserved-handle set H;
- :class:`MemoryPressureEvent`  — an online allocation overflowed H;
- :class:`PageMigration`        — KV pages changed owner/pool (cross-pool
  rescue of a reclamation victim, or an intra-pool ownership re-key);
- :class:`PrefillHandoff`       — a finished prefill's KV lease moved to
  the decode pool of a disaggregated plane (serving/disagg).

The paper's §5 ordering rule ("compute first") and the §4.2 rate bound
("≤ 1 preemption per request", wake only after T_cool) become *checkable
properties of the event log* — see :func:`check_event_ordering` and
``TelemetryRegistry.check_invariants`` — instead of hand-synchronized
counter fields.

Events are ``NamedTuple`` records, not dataclasses: they sit on the
serving/sim hot path (one construction per preemption/reclamation), and
tuple construction is ~3× cheaper than a frozen-dataclass ``__init__`` —
``benchmarks/api_overhead.py`` holds the whole bus under 10 % of NodeSim
wall time.  They are still immutable, typed, and keyword-constructible.
"""
from __future__ import annotations

import abc
from collections import deque
from typing import (
    Callable, Deque, Dict, List, NamedTuple, Optional, Tuple, Type)

__all__ = [
    'RuntimeEvent', 'PreemptionEvent', 'ReclamationEvent', 'WakeupEvent',
    'ReservationChangeEvent', 'MemoryPressureEvent', 'PageMigration',
    'PrefillHandoff', 'EventBus', 'EVENT_TYPES', 'check_event_ordering',
]


class PreemptionEvent(NamedTuple):
    """Offline compute gates closed (online activity or memory pressure).

    ``latency_s`` is the measured/modeled gate-flip latency for the whole
    group flip; ``device_latencies_s`` carries each device's own measured
    flip latency (indexed by gate, so fanout == max, serial == Σ is
    checkable from the log); ``requests`` are the online requests in
    flight (the §4.2 bound is per-request); ``trigger`` distinguishes
    lifecycle closes from memory-pressure closes.
    """
    seq: int
    t: float
    latency_s: float = 0.0
    requests: Tuple[str, ...] = ()
    trigger: str = 'lifecycle'          # 'lifecycle' | 'memory'
    device_latencies_s: Tuple[float, ...] = ()


class ReclamationEvent(NamedTuple):
    """Offline KV handles remapped to quarantine for online use.

    ``gate_closed`` records whether offline compute was disabled when the
    pages moved — the §5 ordering invariant requires True; baseline
    strategies (UVM/StaticMem in the sim) publish False, which is exactly
    the fault-risk the paper's ordering rule exists to prevent.
    """
    seq: int
    t: float
    n_handles: int = 0
    requests: Tuple[str, ...] = ()      # invalidated (or killed) request ids
    pages: int = 0
    gate_closed: bool = True
    killed: bool = False                # baselines kill instead of invalidate
    # victims rescued by cross-pool migration instead of truncated: each
    # must have an earlier cross-pool PageMigration in the same log (the
    # data-plane copy runs at that publish, before this event's freed
    # source pages can be reallocated) — checked by check_event_ordering
    rescued: Tuple[str, ...] = ()


class WakeupEvent(NamedTuple):
    """Offline compute gates re-enabled after continuous online idle.

    ``idle_for_s`` ≥ ``t_cool_s`` is the §4.2 wake rule; both are recorded
    so the property is checkable from the log alone.
    """
    seq: int
    t: float
    idle_for_s: float = 0.0
    t_cool_s: float = 0.0


class ReservationChangeEvent(NamedTuple):
    """The MIAD reserved-handle set H changed size."""
    seq: int
    t: float
    h_before: int = 0
    h_after: int = 0
    reason: str = 'miad'                # 'miad' | 'pressure'


class MemoryPressureEvent(NamedTuple):
    """An online allocation exceeded the current reservation headroom."""
    seq: int
    t: float
    req_id: str = ''
    deficit_pages: int = 0


class PageMigration(NamedTuple):
    """KV pages moved between owners and/or pools.

    Published by ``KVPool.transfer_pages`` (when the pool has a bus), so
    page movement is observable instead of silent bookkeeping.
    ``cross_pool=True`` is the Valve rescue path: a reclamation victim's
    surviving prefix re-homed to a less-loaded pool with zero recompute;
    ``cross_pool=False`` is an intra-pool ownership re-key (e.g. shared
    prefix pages outliving their lease).

    ``src_pages``/``dst_pages`` are the page ids in logical order (equal
    for intra-pool re-keys; pool-local on each side for cross-pool moves)
    — the orchestrator's data-plane copy reads them to move the actual KV
    cache rows between the engines' caches, synchronously at publish time,
    before the freed source pages can be reallocated and overwritten.
    """
    seq: int
    t: float
    owner: str = ''                     # request/lease id that owns the pages
    n_pages: int = 0
    src_pool: str = ''
    dst_pool: str = ''
    cross_pool: bool = False
    src_pages: Tuple[int, ...] = ()
    dst_pages: Tuple[int, ...] = ()


class PrefillHandoff(NamedTuple):
    """A finished prefill's whole KV lease moved to the decode pool.

    Published by the disaggregated serving plane
    (``repro.serving.disagg.DisaggPlane``) on *both* pools' buses once the
    ``MemoryPlane.migrate`` / ``PageMigration`` data-plane copy has
    re-homed the request onto a decode engine.  ``recompute_tokens`` is
    the number of already-materialized prefill tokens the decode side
    will compute again — the disaggregation contract requires 0 (the
    lease carries its fill point, so decode admission resumes at
    ``lease.resume_tokens``).  ``latency_s`` measures first-token time →
    handoff completion (how long finished-prefill KV waited on the
    prefill pool); the queue depths snapshot both online engines
    (waiting + running) at publish time for interference analysis.
    """
    seq: int
    t: float
    req_id: str = ''
    src_pool: str = ''
    dst_pool: str = ''
    pages_copied: int = 0
    latency_s: float = 0.0
    recompute_tokens: int = 0
    prefill_queue_depth: int = 0
    decode_queue_depth: int = 0


EVENT_TYPES: Tuple[type, ...] = (
    PreemptionEvent, ReclamationEvent, WakeupEvent, ReservationChangeEvent,
    MemoryPressureEvent, PageMigration, PrefillHandoff)


class RuntimeEvent(abc.ABC):
    """Abstract marker for the event union: ``isinstance(ev, RuntimeEvent)``
    holds for every registered event type.  Every event carries ``seq``
    (bus sequence number) and ``t`` (runtime-clock timestamp) first."""


for _cls in EVENT_TYPES:
    RuntimeEvent.register(_cls)

Subscriber = Callable[[RuntimeEvent], None]


class EventBus:
    """Ordered, typed pub/sub with a bounded replay log.

    ``publish`` assigns a monotonically increasing sequence number and
    delivers synchronously in subscription order (the runtime is
    single-threaded on its control path; determinism matters more than
    parallel delivery).  The replay log is a bounded deque — long sim and
    harness runs must not grow memory linearly — while cumulative counters
    live in :class:`repro.core.telemetry.TelemetryRegistry`, which consumes
    events as they are published and never needs the full log.

    The registry attaches through :meth:`set_fold` — a single fast-path
    consumer checked with one branch per publish — so the common case
    (telemetry only, no ad-hoc subscribers) stays off the generic
    subscriber loop.
    """

    def __init__(self, clock=None, *, log_maxlen: int = 65536):
        self.clock = clock
        self.log: Deque[RuntimeEvent] = deque(maxlen=log_maxlen)
        self._seq = 0
        self._counts: Dict[type, int] = {}
        self._fold: Optional[Subscriber] = None
        self._subs: List[Tuple[Optional[type], Subscriber]] = []

    # ------------------------------------------------------------------
    def set_fold(self, callback: Optional[Subscriber]) -> None:
        """Install the single fast-path consumer (one per bus — telemetry)."""
        assert callback is None or self._fold is None, 'fold already set'
        self._fold = callback

    def subscribe(self, callback: Subscriber,
                  event_type: Optional[type] = None
                  ) -> Callable[[], None]:
        """Register ``callback`` for ``event_type`` (None = all events).
        Returns an unsubscribe thunk."""
        entry = (event_type, callback)
        self._subs.append(entry)

        def unsubscribe() -> None:
            if entry in self._subs:
                self._subs.remove(entry)
        return unsubscribe

    def publish(self, event_cls: type, *,
                t: Optional[float] = None, **fields) -> RuntimeEvent:
        """Construct and deliver one event; ``t`` defaults to the bus clock."""
        if t is None:
            t = self.clock.now() if self.clock is not None else 0.0
        seq = self._seq
        self._seq = seq + 1
        ev = event_cls(seq, t, **fields)
        self.log.append(ev)
        self._counts[event_cls] = self._counts.get(event_cls, 0) + 1
        if self._fold is not None:
            self._fold(ev)
        if self._subs:
            for etype, cb in tuple(self._subs):
                if etype is None or type(ev) is etype \
                        or isinstance(ev, etype):
                    cb(ev)
        return ev

    # ------------------------------------------------------------------
    @property
    def published(self) -> Dict[str, int]:
        """Cumulative publish counts by event-type name."""
        return {cls.__name__: n for cls, n in self._counts.items()}

    def events(self, event_type: Optional[type] = None
               ) -> List[RuntimeEvent]:
        """Snapshot of the (bounded) replay log, optionally filtered."""
        if event_type is None:
            return list(self.log)
        return [e for e in self.log if isinstance(e, event_type)]

    def count(self, event_type: type) -> int:
        """Cumulative publish count (survives log truncation)."""
        return self._counts.get(event_type, 0)


def check_event_ordering(events: List[RuntimeEvent], *,
                         require_gate_closed: bool = True) -> None:
    """Assert the paper's ordering properties over an event log.

    - §5 compute-first: every :class:`ReclamationEvent` carries
      ``gate_closed=True`` (skipped when ``require_gate_closed=False`` —
      baseline strategies legitimately violate it, that's their flaw);
    - §4.2 wake rule: every :class:`WakeupEvent` satisfies
      ``idle_for_s ≥ t_cool_s`` (within float tolerance);
    - copy-before-reallocation: every victim a :class:`ReclamationEvent`
      reports as ``rescued`` has an *earlier* cross-pool
      :class:`PageMigration` with that owner — the data-plane KV copy
      runs synchronously at the migration publish, so migration-before-
      reclamation in the log proves the copy happened before the freed
      source pages could be reallocated and overwritten;
    - sequence numbers are strictly increasing and timestamps are
      monotonically non-decreasing (one ordered stream of facts).
    """
    last_seq, last_t = -1, float('-inf')
    migrated_owners: set = set()
    for ev in events:
        assert ev.seq > last_seq, (ev.seq, last_seq)
        assert ev.t >= last_t - 1e-9, (ev.t, last_t)
        last_seq, last_t = ev.seq, ev.t
        if isinstance(ev, PageMigration) and ev.cross_pool:
            migrated_owners.add(ev.owner)
        if isinstance(ev, ReclamationEvent):
            if require_gate_closed:
                assert ev.gate_closed, \
                    f'reclamation at t={ev.t} with offline compute ' \
                    f'enabled (§5)'
            missing = set(ev.rescued) - migrated_owners
            assert not missing, \
                f'reclamation at t={ev.t} reports rescued={sorted(missing)}' \
                f' with no prior cross-pool PageMigration (the data-plane ' \
                f'copy must precede the reclamation that frees the source)'
        if isinstance(ev, WakeupEvent):
            assert ev.idle_for_s >= ev.t_cool_s - 1e-9, \
                f'wake-up at t={ev.t} inside T_cool ({ev.idle_for_s} < ' \
                f'{ev.t_cool_s})'
