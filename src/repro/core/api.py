"""Valve control-plane API v1 — class-scoped sessions.

The paper's deployability claim (Table 1) is a *narrow integration
surface*: one driver line plus a < 20-LOC framework patch.  PRs 1–3 grew
three ad-hoc slices of that surface — klass strings passed to
``alloc_online``/``alloc_offline``, a per-request ``bind_invalidation``
route table engines had to maintain by hand, and an engine-instance id
discriminator to keep same-class engines from colliding.  A
:class:`ValveSession` replaces all three: it is *the* handle a serving
framework holds.

    session = runtime.open_session(klass='offline', name='batch-7b',
                                   on_invalidate=engine.on_pages_invalidated)
    rid = session.new_request_id()
    lease = session.admit(rid, n_pages, prompt)  # notify + lease + route
    session.iteration_start(); ...; session.iteration_end()
    if session.may_dispatch(): ...
    session.finish(rid)                     # release lease + route + notify

Because allocation goes *through* the session, the runtime always knows
which session owns a request id: invalidation delivery routes by ownership
(route lifetime == lease lifetime, so no terminal path can leak a route
entry), same-class sessions cannot mis-route each other's callbacks, and
request ids are minted under the session's unique name (no discriminator).

**Memory-plane API v1** (``docs/API.md`` §memory): ``admit`` returns a
:class:`~repro.core.memory.KVLease` — an opaque refcounted handle that
owns page lifetime (``extend``/``fork``/``release``), shares page-aligned
prompt prefixes copy-on-write (pass ``prompt=`` to opt in; the share scope
is the session name, so different models never alias KV), and survives
partial invalidation: re-admitting a live id *extends* the lease, keeping
the surviving prefix, and ``lease.resume_tokens`` is where prefill
resumes.  The lease iterates as the legacy page-id list.

:class:`PoolSession` gives a bare :class:`~repro.serving.kvpool.KVPool`
the same shape (no runtime, no gating, no events — but the same
pool-global memory plane) so the engine holds one session unconditionally.

``api_surface()`` renders the public control-plane API as stable text —
``tests/test_api_surface.py`` pins it against ``tests/api_surface.txt`` so
surface changes are deliberate (regenerate via ``scripts/ci.sh
--regen-api``).
"""
from __future__ import annotations

import inspect
import itertools
from typing import Dict, List, Optional, Sequence

from repro.core.memory import KVLease, MemoryPlane
from repro.core.reclamation import InvalidationCallback

__all__ = ['ValveSession', 'PoolSession', 'api_surface']

# PoolSession keeps the engine-instance discriminator the runtime sessions
# no longer need: without a runtime there is no node-wide owner registry,
# so uniqueness of minted ids falls back to a process-global sequence.
_POOL_SESSION_SEQ = itertools.count()


class ValveSession:
    """A class-scoped handle on one :class:`ValveRuntime`.

    One session per engine (or per framework integration).  The session
    owns the engine's entire control-plane interaction: request-id minting,
    admission (lifecycle notification + allocation), iteration
    notifications, the dispatch-gate check, per-session invalidation
    delivery, and terminal release.  Constructed only by
    ``ValveRuntime.open_session`` — the runtime registers the session under
    a unique name and routes invalidations to it by request ownership.
    """

    def __init__(self, runtime, klass: str, name: str,
                 on_invalidate: Optional[InvalidationCallback] = None):
        assert klass in ('online', 'offline'), klass
        self.runtime = runtime
        self.klass = klass
        self.name = name
        self.on_invalidate = on_invalidate
        self.closed = False
        self._ids = itertools.count()

    # -- request ids --------------------------------------------------------
    def new_request_id(self) -> str:
        """Mint a node-unique request id (session names are unique per
        runtime, so same-class sessions cannot collide)."""
        return f'{self.name}-{next(self._ids)}'

    # -- memory plane -------------------------------------------------------
    def alloc(self, req_id: str, n_pages: int,
              prompt: Optional[Sequence[int]] = None) -> Optional[KVLease]:
        """Lease ``n_pages`` pages for ``req_id`` in this session's class;
        on success the session becomes the request's invalidation route.

        A live ``req_id`` (a partially-invalidated request re-admitting) is
        *extended* to the target, keeping its surviving prefix.  With
        ``prompt``, page-aligned prompt prefixes already materialized under
        this session are attached copy-on-write instead of re-allocated —
        ``lease.resume_tokens`` tells the engine where prefill starts."""
        assert not self.closed, f'session {self.name} is closed'
        return self.runtime._session_alloc(self, req_id, n_pages,
                                           prompt=prompt)

    def free(self, req_id: str) -> None:
        """Release the request's pages and its invalidation route."""
        self.runtime._session_free(self, req_id)

    # -- lifecycle notifications (no-ops for offline sessions) --------------
    def request_start(self, req_id: str) -> None:
        if self.klass == 'online':
            self.runtime.on_online_request_start(req_id)

    def request_end(self, req_id: str) -> None:
        if self.klass == 'online':
            self.runtime.on_online_request_end(req_id)

    def iteration_start(self) -> None:
        if self.klass == 'online':
            self.runtime.on_online_iteration_start()

    def iteration_end(self) -> None:
        if self.klass == 'online':
            self.runtime.on_online_iteration_end()

    # -- bundles (what shrinks the framework patch) -------------------------
    def admit(self, req_id: str, n_pages: int,
              prompt: Optional[Sequence[int]] = None) -> Optional[KVLease]:
        """Admission bundle: lifecycle start, then the lease; a failed
        allocation rolls the lifecycle notification back.  The start fires
        *before* the allocation so the request's arrival closes the gates
        before any reclamation it triggers (one preemption covers both)."""
        self.request_start(req_id)
        lease = self.alloc(req_id, n_pages, prompt)
        if lease is None:
            self.request_end(req_id)
        return lease

    def finish(self, req_id: str) -> None:
        """Terminal bundle: free pages + release route + lifecycle end."""
        self.free(req_id)
        self.request_end(req_id)

    # -- compute plane ------------------------------------------------------
    def may_dispatch(self) -> bool:
        """Online sessions always dispatch; offline sessions only while the
        node's gates are open (the preemption mechanism, paper §4)."""
        if self.klass == 'online':
            return True
        return self.runtime.offline_may_dispatch()

    # -- teardown -----------------------------------------------------------
    def owned_requests(self) -> List[str]:
        """Request ids currently routed to this session (hold live pages)."""
        return self.runtime._session_owned(self)

    def close(self) -> None:
        """Release every owned request and deregister the session."""
        for rid in self.owned_requests():
            self.finish(rid)
        self.closed = True
        self.runtime._session_closed(self)

    def __repr__(self) -> str:
        return f'ValveSession({self.name!r}, klass={self.klass!r})'


class PoolSession:
    """Session-shaped adapter over a bare :class:`KVPool` (no runtime).

    Standalone engines (tests, the serving-plane benchmark drain) keep the
    exact session call sites — lifecycle notifications and the gate check
    degenerate to no-ops; allocation goes through the pool's memory plane,
    so leases, prefix sharing and partial invalidation behave identically.
    """

    runtime = None

    def __init__(self, pool, klass: str, name: Optional[str] = None):
        assert klass in ('online', 'offline'), klass
        self.pool = pool
        self.plane = MemoryPlane.of(pool)
        self.klass = klass
        self.name = name or f'{klass}{next(_POOL_SESSION_SEQ)}'
        self._ids = itertools.count()

    def new_request_id(self) -> str:
        return f'{self.name}-{next(self._ids)}'

    def alloc(self, req_id: str, n_pages: int,
              prompt: Optional[Sequence[int]] = None) -> Optional[KVLease]:
        return self.plane.admit(req_id, n_pages, self.klass,
                                prompt=prompt, scope=self.name)

    def free(self, req_id: str) -> None:
        self.plane.release_id(req_id)

    def request_start(self, req_id: str) -> None: ...
    def request_end(self, req_id: str) -> None: ...
    def iteration_start(self) -> None: ...
    def iteration_end(self) -> None: ...

    admit = alloc

    def finish(self, req_id: str) -> None:
        self.free(req_id)

    def may_dispatch(self) -> bool:
        return True

    def owned_requests(self) -> List[str]:
        # ids are minted as f'{name}-{n}': match the full name segment so
        # 'offline1' never claims 'offline10-...'
        return [r for r in self.plane.leases
                if r.startswith(self.name + '-')]

    def close(self) -> None: ...


# ---------------------------------------------------------------------------
# Public-API snapshot (tests/test_api_surface.py pins this text)
# ---------------------------------------------------------------------------

def _surface_of(obj, prefix: str) -> List[str]:
    lines = []
    for name, member in sorted(vars(obj).items()):
        if name.startswith('_'):
            continue
        if callable(member) and not inspect.isclass(member):
            try:
                sig = str(inspect.signature(member))
            except (TypeError, ValueError):
                sig = '(...)'
            lines.append(f'{prefix}.{name}{sig}')
        elif isinstance(member, property):
            lines.append(f'{prefix}.{name} [property]')
    return lines


def api_surface() -> List[str]:
    """Render the public control- and memory-plane API v1 as sorted
    signature lines."""
    from repro.core import events as E
    from repro.core import memory as M
    from repro.core import telemetry as T
    from repro.core.cluster.placement import (
        GlobalPlacementPolicy, GPUProfile, PlacementPolicy, TopologyModel)
    from repro.core.cluster.scheduler import ClusterScheduler
    from repro.core.runtime import ValveRuntime

    lines: List[str] = []
    for cls in (ValveSession, PoolSession, ValveRuntime, M.MemoryPlane,
                M.KVLease, E.EventBus, T.TelemetryRegistry,
                T.LatencySummary, ClusterScheduler, PlacementPolicy,
                GlobalPlacementPolicy, GPUProfile, TopologyModel):
        lines.append(f'{cls.__module__}.{cls.__name__}')
        lines += _surface_of(cls, f'  {cls.__name__}')
    lines.append(f'{M.LeaseInvalidation.__module__}.LeaseInvalidation'
                 f'({", ".join(M.LeaseInvalidation.__slots__)})')
    for ev in E.EVENT_TYPES:
        lines.append(f'{ev.__module__}.{ev.__name__}'
                     f'({", ".join(ev._fields)})')
    return lines


if __name__ == '__main__':          # scripts/ci.sh --regen-api
    # re-import under the canonical module name (running via -m makes this
    # file __main__, which would leak into the snapshot's qualnames)
    from repro.core import api as _canonical
    print('\n'.join(_canonical.api_surface()))
