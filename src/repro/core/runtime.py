"""ValveRuntime — the node-level GPU-colocation-runtime analogue (paper §3–5).

Composes the four mechanisms into the joint bound the paper is named for:

- **preemption latency**: :class:`GateGroup` fan-out flips all device gates in
  ~O(1); the offline engine's in-flight residual is one sub-layer chunk.
- **preemption rate**: :class:`OnlineLifecycleTracker` gates offline wake-ups
  behind ``T_cool`` (≤ 1 compute preemption per online request); MIAD keeps
  memory-reclamation frequency at the user target.
- **memory safety**: reclamation goes through :class:`ReclamationController`
  (compute-first ordering, quarantine remap, invalidated-ID callback).

**Control-plane API v1** (see ``docs/API.md``): frameworks integrate through
:meth:`open_session` (class-scoped :class:`~repro.core.api.ValveSession`
handles that own alloc/notify/gate-check/invalidation routing) and observe
through :meth:`subscribe` (the typed event stream of
:mod:`repro.core.events`).  Every counter in ``runtime.stats`` /
``lifecycle.stats`` is *derived from the event stream* by the
:class:`~repro.core.telemetry.TelemetryRegistry` at ``runtime.telemetry`` —
the hot path publishes facts, never hand-syncs counters, and
:meth:`check_invariants` checks the event log.

**Memory-plane API v1** (``repro.core.memory``): allocation goes through
``runtime.memory`` — sessions return :class:`~repro.core.memory.KVLease`
handles (refcounted, prefix-sharing, partially invalidatable), and the
invalidation callback carries per-request surviving prefixes.  The
klass-string methods (``alloc_online``/``alloc_offline``/``free_*``) and
the per-request invalidation route table (``bind_invalidation``/
``unbind_invalidation``) are **deprecated shims** over hidden legacy
sessions/leases; new integrations should hold a session.

The runtime is clock-agnostic: a :class:`RealClock` drives the live demo and
a :class:`VirtualClock` drives the discrete-event simulator, so the paper's
§7.2 experiments exercise *this* code, not a model of it.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Type

from repro.core.clock import RealClock
from repro.core.events import (
    EventBus, MemoryPressureEvent, PreemptionEvent, ReservationChangeEvent,
    RuntimeEvent, WakeupEvent)
from repro.core.gate import DeviceGate, GateGroup
from repro.core.lifecycle import OnlineLifecycleTracker
from repro.core.memory import KVLease, MemoryPlane
from repro.core.miad import MIADConfig, MIADReservation
from repro.core.reclamation import InvalidationCallback, ReclamationController
from repro.core.telemetry import TelemetryRegistry
from repro.serving.kvpool import KVPool


@dataclass
class RuntimeConfig:
    n_devices: int = 1
    # Device topology: a jax.sharding.Mesh (the one the engine shards
    # over).  When set, the runtime instantiates one DeviceGate per mesh
    # device — overriding n_devices — so the gate fan-out is the real
    # flip across the serving mesh, not a modeled count.
    mesh: Optional[object] = None
    gate_mode: str = 'fanout'          # 'fanout' (patched driver) | 'serial'
    gate_op_latency_s: float = 0.0
    policy: str = 'valve'              # eviction policy: 'valve' | 'fifo'
    miad: MIADConfig = field(default_factory=MIADConfig)
    t_cool_init: float = 0.010
    # bounded replay log / latency reservoir sizes (telemetry memory bound)
    event_log_maxlen: int = 65536
    latency_reservoir: int = 512
    # memory mode (paper §7.2 baselines live in core/sim/strategies.py; the
    # real runtime always runs the paper's OurMem path)


@dataclass
class RuntimeStats:
    """Legacy counter mirror — populated by the TelemetryRegistry from the
    event stream (never mutated by the runtime hot path).  Reads are fine;
    new code should prefer ``runtime.telemetry.snapshot()``.
    ``preemption_latencies`` is a bounded
    :class:`~repro.core.telemetry.LatencySummary` (list-like while small;
    ``.raw``/``.summary()`` for tests and reports)."""
    compute_preemptions: int = 0
    offline_wakeups: int = 0
    preemption_latencies: object = field(default_factory=list)
    memory_pressure_events: int = 0


class ValveRuntime:
    """One node: one online engine, ≥0 offline engines, one shared KV pool."""

    def __init__(self, pool: KVPool, cfg: Optional[RuntimeConfig] = None,
                 *, clock=None,
                 on_invalidate: Optional[InvalidationCallback] = None):
        self.cfg = cfg or RuntimeConfig()
        self.clock = clock or RealClock()
        self.pool = pool
        # -- memory plane: lease-based allocation over the physical pool --
        self.memory = MemoryPlane.of(pool)
        # route lifetime == lease lifetime: whenever a lease fully dies
        # (finish, close, zero-survivor invalidation, spill) its delivery
        # route dies with it — one mechanism for every terminal path
        self.memory.on_release = self._lease_released
        # -- control plane: event stream + derived telemetry ------------
        self.bus = EventBus(self.clock, log_maxlen=self.cfg.event_log_maxlen)
        # the pool publishes PageMigration on the runtime bus (cross-pool
        # rescue observability); aux pools registered by the orchestrator
        # share the same bus so node-wide folds see every migration
        if getattr(pool, 'bus', None) is None:
            pool.bus = self.bus
        self.lifecycle = OnlineLifecycleTracker(
            t_cool_init=self.cfg.t_cool_init)
        self.stats = RuntimeStats()
        self.telemetry = TelemetryRegistry(
            self.bus, stats=self.stats, lifecycle=self.lifecycle,
            latency_cap=self.cfg.latency_reservoir)
        # -- sessions: name → session; request id → owning session ------
        self.sessions: Dict[str, object] = {}
        self._session_seq = itertools.count()
        self._owner: Dict[str, object] = {}
        self._legacy_sessions: Dict[str, object] = {}
        # deprecated per-request invalidation route table (bind/unbind);
        # ids with neither a session owner nor a bound route fall back to
        # the legacy single ``on_invalidate`` callback (if any)
        self._invalidation_route: Dict[str, InvalidationCallback] = {}
        self._invalidation_fallback = on_invalidate
        # gates share the runtime clock so sim runs record modeled (and
        # deterministic) flip latencies, not wall-clock noise.  With a
        # mesh, one gate per mesh device: preemption is the real fan-out
        # across the serving mesh, and each PreemptionEvent folds the
        # measured per-device flip latencies into the stream.
        n_dev = self.cfg.n_devices
        if self.cfg.mesh is not None:
            n_dev = self.cfg.mesh.devices.size
        self.n_devices = n_dev
        self.gates = GateGroup(
            [DeviceGate(i, self.cfg.gate_op_latency_s, clock=self.clock)
             for i in range(n_dev)],
            mode=self.cfg.gate_mode, clock=self.clock)
        miad_cfg = dataclasses.replace(
            self.cfg.miad, h_max=min(self.cfg.miad.h_max, pool.n_handles))
        self.miad = MIADReservation(h_init=len(pool.reserved), cfg=miad_cfg)
        self.reclaimer = ReclamationController(
            pool,
            gate_is_closed=lambda: self.gates.all_disabled,
            on_invalidate=self._route_invalidation,
            policy=self.cfg.policy,
            bus=self.bus)

    # ------------------------------------------------------------------
    # Control-plane API v1: sessions + event subscription
    # ------------------------------------------------------------------
    def open_session(self, klass: str, name: Optional[str] = None, *,
                     on_invalidate: Optional[InvalidationCallback] = None):
        """Open a class-scoped session (the framework integration handle).

        ``name`` must be unique per runtime (it prefixes minted request
        ids); defaults to ``{klass}{n}`` in open order (monotonic — names
        are never reissued after a close).
        """
        from repro.core.api import ValveSession
        if name is None:
            name = f'{klass}{next(self._session_seq)}'
        assert name not in self.sessions, f'duplicate session name {name!r}'
        sess = ValveSession(self, klass, name, on_invalidate=on_invalidate)
        self.sessions[name] = sess
        return sess

    def subscribe(self, callback: Callable[[RuntimeEvent], None],
                  event_type: Optional[Type[RuntimeEvent]] = None
                  ) -> Callable[[], None]:
        """Observe the typed event stream; returns an unsubscribe thunk."""
        return self.bus.subscribe(callback, event_type)

    def invalidation_routes(self) -> List[str]:
        """Live request ids with a delivery route (session ownership or a
        legacy bound callback).  Terminal paths must drain this to empty —
        pinned by the node-run regression test."""
        return sorted(set(self._owner) | set(self._invalidation_route))

    # -- session internals (called by ValveSession) ---------------------
    def _session_alloc(self, sess, req_id: str, n_pages: int,
                       prompt=None) -> Optional[KVLease]:
        if sess.klass == 'online':
            got = self._alloc_online(req_id, n_pages, prompt=prompt,
                                     scope=sess.name)
        else:
            got = self._alloc_offline(req_id, n_pages, prompt=prompt,
                                      scope=sess.name)
        if got is not None:
            self._owner[req_id] = sess
        return got

    def _session_free(self, sess, req_id: str) -> None:
        self.memory.release_id(req_id)
        self._owner.pop(req_id, None)

    def _lease_released(self, req_id: str) -> None:
        self._owner.pop(req_id, None)

    def _session_owned(self, sess) -> List[str]:
        return sorted(r for r, s in self._owner.items() if s is sess)

    def _session_closed(self, sess) -> None:
        self.sessions.pop(sess.name, None)

    def _legacy_session(self, klass: str):
        """Hidden sessions backing the deprecated klass-string methods."""
        sess = self._legacy_sessions.get(klass)
        if sess is None:
            from repro.core.api import ValveSession
            sess = ValveSession(self, klass, f'legacy-{klass}')
            self._legacy_sessions[klass] = sess
        return sess

    def _legacy_alloc(self, klass: str, req_id: str, n_pages: int
                      ) -> Optional[KVLease]:
        """Shim fast path: jump straight to the session internals instead
        of re-entering through ``ValveSession.alloc`` (the shims used to
        pay the public wrapper a second time on every call)."""
        sess = self._legacy_sessions.get(klass) or self._legacy_session(klass)
        return self._session_alloc(sess, req_id, n_pages)

    # ------------------------------------------------------------------
    # Invalidation fan-out: one reclamation's {req: pages} is split by the
    # OWNING SESSION (allocation records ownership, so same-class engines
    # cannot mis-route) and delivered once per session callback.
    # ------------------------------------------------------------------
    def bind_invalidation(self, req_id: str, cb: InvalidationCallback) -> None:
        """DEPRECATED — open a session with ``on_invalidate`` instead; the
        session routes by ownership and cannot leak route entries."""
        self._invalidation_route[req_id] = cb

    def unbind_invalidation(self, req_id: str) -> None:
        """DEPRECATED — see :meth:`bind_invalidation`."""
        self._invalidation_route.pop(req_id, None)

    def _route_invalidation(self, invalidated: Dict[str, List[int]]) -> None:
        groups: Dict[object, Dict[str, List[int]]] = {}
        unrouted: Dict[str, List[int]] = {}
        for rid, pages in invalidated.items():
            if getattr(pages, 'migrated_to', None) is not None:
                # rescued cross-pool: the lease (and its KV) moved intact
                # to another pool's plane — there is nothing for the local
                # engine to truncate or recompute, and the orchestrator
                # hands the request off via the PageMigration event.  The
                # local route already died in MemoryPlane.migrate.
                continue
            sess = self._owner.get(rid)
            # a session without its own callback (e.g. the hidden legacy
            # sessions behind the klass-string shims) must not shadow a
            # per-request bound route — fall through to it
            cb = (sess.on_invalidate if sess is not None else None) \
                or self._invalidation_route.get(rid)
            if cb is None:
                unrouted[rid] = pages
            else:
                groups.setdefault(cb, {})[rid] = pages
        for cb, group in groups.items():
            cb(group)
        if unrouted and self._invalidation_fallback is not None:
            self._invalidation_fallback(unrouted)
        # route lifetime == lease lifetime.  This pop is LOAD-BEARING for
        # every released lease: the invalidation path releases with
        # notify=False (the delivery above must still find the route), so
        # the plane's on_release hook deliberately did NOT fire — routes
        # for zero-survivor leases and legacy whole-freed ids drop here,
        # after delivery.  A request with a SURVIVING prefix keeps lease
        # and route: the next invalidation must still reach its session.
        for rid, inv in invalidated.items():
            if getattr(inv, 'released', True):
                self._owner.pop(rid, None)

    # ------------------------------------------------------------------
    # Online engine hooks (sessions call these; total patch surface on the
    # online side is request/iteration notifications).
    # ------------------------------------------------------------------
    def on_online_request_start(self, req_id: str) -> None:
        now = self.clock.now()
        self.lifecycle.request_start(req_id, now)
        self._preempt_offline_if_running(trigger='lifecycle')

    def on_online_request_end(self, req_id: str) -> None:
        self.lifecycle.request_end(req_id, self.clock.now())

    def on_online_iteration_start(self) -> None:
        now = self.clock.now()
        self.lifecycle.iteration_start(now)
        self._preempt_offline_if_running(trigger='lifecycle')

    def on_online_iteration_end(self) -> None:
        self.lifecycle.iteration_end(self.clock.now())

    def _preempt_offline_if_running(self, trigger: str) -> None:
        if not self.gates.all_disabled:
            latency = self.gates.disable_all()
            self.bus.publish(
                PreemptionEvent, latency_s=latency,
                requests=tuple(sorted(self.lifecycle.active)),
                trigger=trigger,
                device_latencies_s=self.gates.last_flip_latencies)

    # ------------------------------------------------------------------
    # Memory plane (session-internal; the klass-string methods below are
    # deprecated shims over hidden legacy sessions)
    # ------------------------------------------------------------------
    def _alloc_online(self, req_id: str, n_pages: int, *, prompt=None,
                      scope=None) -> Optional[KVLease]:
        """Lease online KV pages from the MIAD reservation; on shortfall,
        reclaim offline handles (compute-first) to cover it."""
        got = self.memory.admit(req_id, n_pages, 'online',
                                prompt=prompt, scope=scope)
        if got is not None:
            return got
        now = self.clock.now()
        held = self.memory.get(req_id)
        missing = n_pages - (len(held) if held is not None else 0)
        deficit = missing - self.pool.free_pages_for('online')
        self.bus.publish(MemoryPressureEvent, req_id=req_id,
                         deficit_pages=deficit)
        n_handles = -(-deficit // self.pool.pph)  # ceil
        self._with_gates_closed_reclaim(n_handles, now)
        return self.memory.admit(req_id, n_pages, 'online',
                                 prompt=prompt, scope=scope)

    def _alloc_offline(self, req_id: str, n_pages: int, *, prompt=None,
                       scope=None) -> Optional[KVLease]:
        got = self.memory.admit(req_id, n_pages, 'offline',
                                prompt=prompt, scope=scope)
        if got is not None and len(got._pages) > 0:
            # one recency note per distinct handle (pages cluster, so the
            # set is tiny) instead of one per page
            now = self.clock.now()
            handle_of = self.pool.handle_of
            for h in {handle_of(p) for p in got._pages}:
                self.reclaimer.note_handle_use(h, now)
        return got

    def alloc_online(self, req_id: str, n_pages: int) -> Optional[KVLease]:
        """DEPRECATED — use ``open_session('online').alloc`` instead.
        Returns the hidden lease (list-like: iterates as the page ids)."""
        return self._legacy_alloc('online', req_id, n_pages)

    def free_online(self, req_id: str) -> None:
        """DEPRECATED — use the owning session's ``free``/``finish``."""
        self.memory.release_id(req_id)
        self._owner.pop(req_id, None)

    def alloc_offline(self, req_id: str, n_pages: int) -> Optional[KVLease]:
        """DEPRECATED — use ``open_session('offline').alloc`` instead.
        Returns the hidden lease (list-like: iterates as the page ids)."""
        return self._legacy_alloc('offline', req_id, n_pages)

    def free_offline(self, req_id: str) -> None:
        """DEPRECATED — use the owning session's ``free``/``finish``."""
        self.memory.release_id(req_id)
        self._owner.pop(req_id, None)

    def _with_gates_closed_reclaim(self, n_handles: int, now: float
                                   ) -> Dict[str, List[int]]:
        """Paper §5 ordering: compute gate closes before any page moves."""
        was_open = not self.gates.all_disabled
        if was_open:
            self._preempt_offline_if_running(trigger='memory')
        try:
            inv = self.reclaimer.reclaim(n_handles, now)
            self.miad.note_reclamation(now)
            return inv
        finally:
            if was_open and self.lifecycle.may_wake_offline(now):
                self._wake_offline()

    def _wake_offline(self) -> None:
        """Re-enable offline compute — the ONLY path that opens the gates,
        so the WakeupEvent count always agrees with gate enable counts
        (both the tick path and the reclaim finally-branch go through it)."""
        now = self.clock.now()
        self.gates.enable_all()
        self.bus.publish(WakeupEvent,
                         idle_for_s=self.lifecycle.idle_for(now),
                         t_cool_s=self.lifecycle.t_cool)

    # ------------------------------------------------------------------
    # Periodic tick: MIAD reservation + offline wake-up
    # ------------------------------------------------------------------
    def tick(self) -> None:
        now = self.clock.now()
        h0 = len(self.pool.reserved)
        h_target = self.miad.on_tick(now, self.pool.online_used_handles())
        self._apply_reservation(h_target, now)
        if len(self.pool.reserved) != h0:
            self.bus.publish(ReservationChangeEvent, h_before=h0,
                             h_after=len(self.pool.reserved), reason='miad')
        if self.gates.all_disabled and self.lifecycle.may_wake_offline(now):
            self._wake_offline()

    def _apply_reservation(self, h_target: int, now: float) -> None:
        """Grow/shrink the pool's reserved-handle set toward MIAD's H."""
        cur = len(self.pool.reserved)
        while cur < h_target:
            empties = self.pool.empty_offline_handles()
            if empties:
                self.pool.reserve_handle(empties[0], now)
            else:
                # growth must come from offline-held handles → reclamation
                inv = self._with_gates_closed_reclaim(1, now)
                if not inv and not self.pool.empty_offline_handles():
                    break  # nothing reclaimable (pool exhausted by online)
            cur = len(self.pool.reserved)
        while cur > h_target:
            if self.pool.release_reserved_handle() is None:
                break  # all reserved handles hold online pages
            cur = len(self.pool.reserved)
        # sync MIAD's view (pool may have refused to shrink below usage)
        self.miad.h = max(self.miad.h, len(self.pool.reserved))

    # ------------------------------------------------------------------
    # Offline engine data plane
    # ------------------------------------------------------------------
    def offline_may_dispatch(self) -> bool:
        return all(g.enabled for g in self.gates.gates)

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """The paper's §4–5 invariants, checked against the EVENT LOG (the
        source every counter derives from) rather than hand-synced fields:
        ≤ 1 preemption per online request, wake-ups == gate enables, §5
        compute-first ordering, T_cool wake rule."""
        self.memory.check_invariants()        # includes pool invariants
        assert self.reclaimer.stats.ordering_violations == 0
        self.telemetry.check_invariants(gates=self.gates)
        # the legacy mirrors must agree with the event-derived counters
        # (they are written only by the registry, so drift means a bug)
        tel = self.telemetry.counters
        assert self.stats.compute_preemptions == tel.preemptions
        assert self.stats.offline_wakeups == tel.wakeups
        assert self.lifecycle.stats.wakeups == tel.wakeups

    def close(self) -> None:
        for sess in list(self.sessions.values()):
            sess.close()
        self.gates.close()
