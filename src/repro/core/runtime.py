"""ValveRuntime — the node-level GPU-colocation-runtime analogue (paper §3–5).

Composes the four mechanisms into the joint bound the paper is named for:

- **preemption latency**: :class:`GateGroup` fan-out flips all device gates in
  ~O(1); the offline engine's in-flight residual is one sub-layer chunk.
- **preemption rate**: :class:`OnlineLifecycleTracker` gates offline wake-ups
  behind ``T_cool`` (≤ 1 compute preemption per online request); MIAD keeps
  memory-reclamation frequency at the user target.
- **memory safety**: reclamation goes through :class:`ReclamationController`
  (compute-first ordering, quarantine remap, invalidated-ID callback).

The runtime is clock-agnostic: a :class:`RealClock` drives the live demo and
a :class:`VirtualClock` drives the discrete-event simulator, so the paper's
§7.2 experiments exercise *this* code, not a model of it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.clock import RealClock
from repro.core.gate import DeviceGate, GateGroup
from repro.core.lifecycle import OnlineLifecycleTracker
from repro.core.miad import MIADConfig, MIADReservation
from repro.core.reclamation import InvalidationCallback, ReclamationController
from repro.serving.kvpool import KVPool


@dataclass
class RuntimeConfig:
    n_devices: int = 1
    gate_mode: str = 'fanout'          # 'fanout' (patched driver) | 'serial'
    gate_op_latency_s: float = 0.0
    policy: str = 'valve'              # eviction policy: 'valve' | 'fifo'
    miad: MIADConfig = field(default_factory=MIADConfig)
    t_cool_init: float = 0.010
    # memory mode (paper §7.2 baselines live in core/sim/strategies.py; the
    # real runtime always runs the paper's OurMem path)


@dataclass
class RuntimeStats:
    compute_preemptions: int = 0
    offline_wakeups: int = 0
    preemption_latencies: List[float] = field(default_factory=list)
    memory_pressure_events: int = 0


class ValveRuntime:
    """One node: one online engine, ≥0 offline engines, one shared KV pool."""

    def __init__(self, pool: KVPool, cfg: Optional[RuntimeConfig] = None,
                 *, clock=None,
                 on_invalidate: Optional[InvalidationCallback] = None):
        self.cfg = cfg or RuntimeConfig()
        self.clock = clock or RealClock()
        self.pool = pool
        # invalidation fan-out: request id → the owning engine's callback.
        # Engines bind at submit / unbind at finish; ids with no binding fall
        # back to the legacy single ``on_invalidate`` callback (if any).
        self._invalidation_route: Dict[str, InvalidationCallback] = {}
        self._invalidation_fallback = on_invalidate
        # gates share the runtime clock so sim runs record modeled (and
        # deterministic) flip latencies, not wall-clock noise
        self.gates = GateGroup(
            [DeviceGate(i, self.cfg.gate_op_latency_s, clock=self.clock)
             for i in range(self.cfg.n_devices)],
            mode=self.cfg.gate_mode, clock=self.clock)
        self.lifecycle = OnlineLifecycleTracker(
            t_cool_init=self.cfg.t_cool_init)
        import dataclasses
        miad_cfg = dataclasses.replace(
            self.cfg.miad, h_max=min(self.cfg.miad.h_max, pool.n_handles))
        self.miad = MIADReservation(h_init=len(pool.reserved), cfg=miad_cfg)
        self.reclaimer = ReclamationController(
            pool,
            gate_is_closed=lambda: self.gates.all_disabled,
            on_invalidate=self._route_invalidation,
            policy=self.cfg.policy)
        self.stats = RuntimeStats()

    # ------------------------------------------------------------------
    # Invalidation fan-out (multi-engine nodes: each invalidated request
    # is surfaced to the engine that owns it, not one global callback)
    # ------------------------------------------------------------------
    def bind_invalidation(self, req_id: str, cb: InvalidationCallback) -> None:
        self._invalidation_route[req_id] = cb

    def unbind_invalidation(self, req_id: str) -> None:
        self._invalidation_route.pop(req_id, None)

    def _route_invalidation(self, invalidated: Dict[str, List[int]]) -> None:
        """Split one reclamation's {req: pages} by owning engine and deliver
        each group through that engine's bound callback (one call per engine,
        preserving the single-callback patch-surface contract per engine)."""
        groups: Dict[InvalidationCallback, Dict[str, List[int]]] = {}
        unrouted: Dict[str, List[int]] = {}
        for rid, pages in invalidated.items():
            cb = self._invalidation_route.get(rid)
            if cb is None:
                unrouted[rid] = pages
            else:
                groups.setdefault(cb, {})[rid] = pages
        for cb, group in groups.items():
            cb(group)
        if unrouted and self._invalidation_fallback is not None:
            self._invalidation_fallback(unrouted)

    # ------------------------------------------------------------------
    # Online engine hooks (the online framework calls these; total patch
    # surface on the online side is request/iteration notifications).
    # ------------------------------------------------------------------
    def on_online_request_start(self, req_id: str) -> None:
        now = self.clock.now()
        self.lifecycle.request_start(req_id, now)
        self._preempt_offline_if_running(now)

    def on_online_request_end(self, req_id: str) -> None:
        self.lifecycle.request_end(req_id, self.clock.now())

    def on_online_iteration_start(self) -> None:
        now = self.clock.now()
        self.lifecycle.iteration_start(now)
        self._preempt_offline_if_running(now)

    def on_online_iteration_end(self) -> None:
        self.lifecycle.iteration_end(self.clock.now())

    def _preempt_offline_if_running(self, now: float) -> None:
        if not self.gates.all_disabled:
            latency = self.gates.disable_all()
            self.stats.compute_preemptions += 1
            self.stats.preemption_latencies.append(latency)
            self.lifecycle.note_preemption(now)

    # ------------------------------------------------------------------
    # Memory plane
    # ------------------------------------------------------------------
    def alloc_online(self, req_id: str, n_pages: int) -> Optional[List[int]]:
        """Allocate online KV pages from the MIAD reservation; on shortfall,
        reclaim offline handles (compute-first) to cover it."""
        got = self.pool.alloc(req_id, n_pages, klass='online')
        if got is not None:
            return got
        now = self.clock.now()
        self.stats.memory_pressure_events += 1
        deficit = n_pages - self.pool.free_pages_for('online')
        n_handles = -(-deficit // self.pool.pph)  # ceil
        self._with_gates_closed_reclaim(n_handles, now)
        return self.pool.alloc(req_id, n_pages, klass='online')

    def free_online(self, req_id: str) -> None:
        self.pool.free(req_id)

    def alloc_offline(self, req_id: str, n_pages: int) -> Optional[List[int]]:
        got = self.pool.alloc(req_id, n_pages, klass='offline')
        if got is not None:
            now = self.clock.now()
            for p in got:
                self.reclaimer.note_handle_use(self.pool.handle_of(p), now)
        return got

    def free_offline(self, req_id: str) -> None:
        self.pool.free(req_id)

    def _with_gates_closed_reclaim(self, n_handles: int, now: float
                                   ) -> Dict[str, List[int]]:
        """Paper §5 ordering: compute gate closes before any page moves."""
        was_open = not self.gates.all_disabled
        if was_open:
            latency = self.gates.disable_all()
            self.stats.compute_preemptions += 1
            self.stats.preemption_latencies.append(latency)
            self.lifecycle.note_preemption(now)
        try:
            inv = self.reclaimer.reclaim(n_handles, now)
            self.miad.note_reclamation(now)
            return inv
        finally:
            if was_open and self.lifecycle.may_wake_offline(now):
                self._wake_offline()

    def _wake_offline(self) -> None:
        """Re-enable offline compute — the ONLY path that opens the gates,
        so ``stats.offline_wakeups`` always agrees with gate enable counts
        (both the tick path and the reclaim finally-branch go through it)."""
        self.gates.enable_all()
        self.stats.offline_wakeups += 1
        self.lifecycle.stats.wakeups += 1

    # ------------------------------------------------------------------
    # Periodic tick: MIAD reservation + offline wake-up
    # ------------------------------------------------------------------
    def tick(self) -> None:
        now = self.clock.now()
        h_target = self.miad.on_tick(now, self.pool.online_used_handles())
        self._apply_reservation(h_target, now)
        if self.gates.all_disabled and self.lifecycle.may_wake_offline(now):
            self._wake_offline()

    def _apply_reservation(self, h_target: int, now: float) -> None:
        """Grow/shrink the pool's reserved-handle set toward MIAD's H."""
        cur = len(self.pool.reserved)
        while cur < h_target:
            empties = self.pool.empty_offline_handles()
            if empties:
                self.pool.reserve_handle(empties[0], now)
            else:
                # growth must come from offline-held handles → reclamation
                inv = self._with_gates_closed_reclaim(1, now)
                if not inv and not self.pool.empty_offline_handles():
                    break  # nothing reclaimable (pool exhausted by online)
            cur = len(self.pool.reserved)
        while cur > h_target:
            if self.pool.release_reserved_handle() is None:
                break  # all reserved handles hold online pages
            cur = len(self.pool.reserved)
        # sync MIAD's view (pool may have refused to shrink below usage)
        self.miad.h = max(self.miad.h, len(self.pool.reserved))

    # ------------------------------------------------------------------
    # Offline engine data plane
    # ------------------------------------------------------------------
    def offline_may_dispatch(self) -> bool:
        return all(g.enabled for g in self.gates.gates)

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        self.pool.check_invariants()
        assert self.reclaimer.stats.ordering_violations == 0
        # wake-up accounting is unified: every gate enable is one counted
        # offline wake-up (gates start enabled without an enable() call)
        for g in self.gates.gates:
            assert g.stats.enables == self.stats.offline_wakeups, \
                (g.device_id, g.stats.enables, self.stats.offline_wakeups)
        assert self.stats.offline_wakeups == self.lifecycle.stats.wakeups
        # at-most-one compute preemption per online request (paper §4.2)
        for req, n in self.lifecycle.stats.preempted_requests.items():
            assert n <= 1, f'request {req} preempted {n}× (> 1)'

    def close(self) -> None:
        self.gates.close()
