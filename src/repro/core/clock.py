"""Clock abstraction so the Valve runtime runs identically under real
wall-clock (live colocation demo) and the discrete-event simulator."""
from __future__ import annotations

import time


class RealClock:
    virtual = False

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        time.sleep(max(dt, 0.0))


class VirtualClock:
    """Manually-advanced clock for deterministic simulation.

    ``virtual = True`` lets clock-domain-aware components (the gate group)
    switch from measuring wall time to charging modeled latencies, so
    sim-recorded timings are deterministic instead of wall-clock noise.
    """

    virtual = True

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        assert dt >= 0.0, dt
        self._t += dt

    def advance_to(self, t: float) -> None:
        assert t >= self._t - 1e-12, (t, self._t)
        self._t = max(self._t, t)

    def sleep(self, dt: float) -> None:
        self.advance(dt)
